"""Benchmark driver: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]``

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract). The
roofline table is produced separately from the dry-run artifacts
(``python -m repro.launch.dryrun --all --both-meshes``; summarized by
``python -m benchmarks.roofline_report``).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (decomposed_time, impact_of_c, impact_of_k,
                        impact_of_tau, kernel_bench, preprocessing_time)

SUITES = {
    "table1_impact_of_tau": impact_of_tau.run,
    "table2_preprocessing": preprocessing_time.run,
    "table3_decomposed": decomposed_time.run,
    "fig3_impact_of_k": impact_of_k.run,
    "fig4_impact_of_c": impact_of_c.run,
    "kernel_paths": kernel_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-speed)")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn(quick=args.quick)
        except Exception as e:               # keep the suite running
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
