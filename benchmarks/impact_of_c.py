"""Figure 4 — impact of c: time / accuracy / overall ratio for Ours vs
QSRP with c ∈ {1.5, 2.0, 2.5, 3.0}, k = 10. Ours must be c-insensitive
(step 1 dominates and ignores c); QSRP refines less as c grows."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BENCH_DATASETS, csv_row, load, timeit
from repro.core import ReverseKRanksEngine, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.qsrp import build_qsrp_index, qsrp_query
from repro.core.types import RankTableConfig

K = 10
CS = (1.5, 2.0, 2.5, 3.0)
N_EVAL = 6


def run(quick: bool = False) -> list[str]:
    rows = []
    datasets = BENCH_DATASETS[:1] if quick else BENCH_DATASETS[:2]
    cs = CS[:2] if quick else CS
    for ds in datasets:
        users, items = load(ds)
        cfg = RankTableConfig(tau=500, omega=10, s=64)
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1))
        qsrp_idx = build_qsrp_index(users, items, levels=1000)
        for c in cs:
            accs, ratios, qrefined = [], [], []
            t_q = timeit(lambda qq: eng.query(qq, k=K, c=c).indices,
                         items[11], iters=3)
            t_qsrp_tot = 0.0
            for qi in range(N_EVAL):
                q = items[qi * 53]
                truth = np.asarray(exact_ranks(users, items, q))
                ex_idx, _ = reverse_k_ranks(users, items, q, K)
                r = eng.query(q, k=K, c=c)
                accs.append(metrics.accuracy(np.asarray(r.indices),
                                             np.asarray(ex_idx), truth, c))
                ratios.append(metrics.overall_ratio(
                    np.asarray(r.indices), np.asarray(ex_idx), truth))
                t0 = time.perf_counter()
                _, _, nref = qsrp_query(qsrp_idx, users, items, q, K, c)
                t_qsrp_tot += time.perf_counter() - t0
                qrefined.append(nref)
            rows.append(csv_row(
                f"fig4/{ds.name}/c{c}/ours", t_q * 1e6,
                f"acc={np.mean(accs):.3f};ratio={np.mean(ratios):.3f}"))
            rows.append(csv_row(
                f"fig4/{ds.name}/c{c}/qsrp", t_qsrp_tot / N_EVAL * 1e6,
                f"refined={np.mean(qrefined):.0f}"))
    return rows


if __name__ == "__main__":
    run()
