"""Table 1 — impact of τ: query time, overall ratio, and index memory for
τ ∈ {100, 500, 1000} on every dataset replica."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BENCH_DATASETS, csv_row, load, timeit
from repro.core import ReverseKRanksEngine, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.types import RankTableConfig

K, C = 10, 2.0
TAUS = (100, 500, 1000)
N_EVAL = 8


def run(quick: bool = False) -> list[str]:
    rows = []
    datasets = BENCH_DATASETS[:1] if quick else BENCH_DATASETS
    taus = TAUS[:2] if quick else TAUS
    for ds in datasets:
        users, items = load(ds)
        for tau in taus:
            cfg = RankTableConfig(tau=tau, omega=10, s=64)
            eng = ReverseKRanksEngine.build(users, items, cfg,
                                            jax.random.PRNGKey(1))
            q = items[7]
            t = timeit(lambda qq: eng.query(qq, k=K, c=C).indices, q,
                       iters=3 if quick else 5)
            ratios = []
            for qi in range(N_EVAL):
                qq = items[qi * 37]
                truth = np.asarray(exact_ranks(users, items, qq))
                ex_idx, _ = reverse_k_ranks(users, items, qq, K)
                r = eng.query(qq, k=K, c=C)
                ratios.append(metrics.overall_ratio(
                    np.asarray(r.indices), np.asarray(ex_idx), truth))
            mem_gb = eng.memory_bytes() / 2**30
            rows.append(csv_row(
                f"table1/{ds.name}/tau{tau}", t * 1e6,
                f"ratio={np.mean(ratios):.3f};mem_gb={mem_gb:.4f}"))
    return rows


if __name__ == "__main__":
    run()
