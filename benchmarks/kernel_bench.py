"""Kernel-path benchmark (ours, beyond-paper): fused Pallas step 1 vs the
plain jnp step 1 at matched shapes, plus table-build. On CPU the kernels
run interpret=True (Python), so the numbers here validate PARITY and call
overhead only — the VMEM-tiling win is a TPU property argued in §Roofline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, load, BenchDataset, timeit
from repro.core.query import bound_ranks_batch, lookup_bounds
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig
from repro.kernels import ops

DS = BenchDataset("kernelbench", 4_096, 2_048, 128)
BATCH = 16


def run(quick: bool = False) -> list[str]:
    rows = []
    users, items = load(DS)
    cfg = RankTableConfig(tau=128, omega=8, s=32)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(0))
    q = items[3]

    @jax.jit
    def jnp_step1(qq):
        uq = (users @ qq).astype(jnp.float32)
        return lookup_bounds(rt, uq)

    t_jnp = timeit(jnp_step1, q, iters=3)
    rows.append(csv_row("kernel/step1/jnp", t_jnp * 1e6, ""))
    t_pl = timeit(lambda qq: ops.bound_ranks(
        users, qq, rt.thresholds, rt.table, m=int(rt.m)), q, iters=3)
    rows.append(csv_row("kernel/step1/pallas_interp", t_pl * 1e6,
                        f"parity_runtime_ratio={t_pl/t_jnp:.1f}"))

    # Batched step 1 (PR 1): one table pass for BATCH queries; report µs
    # per query so the amortization vs the single-query rows is direct.
    qs = items[3:3 + BATCH]
    t_jnp_b = timeit(lambda Q: bound_ranks_batch(rt, users, Q), qs, iters=3)
    rows.append(csv_row(f"kernel/step1_batch{BATCH}/jnp",
                        t_jnp_b / BATCH * 1e6,
                        f"amortization_x={t_jnp/(t_jnp_b/BATCH):.1f}"))
    t_pl_b = timeit(lambda Q: ops.bound_ranks_batched(
        users, Q, rt.thresholds, rt.table, m=int(rt.m)), qs, iters=3)
    rows.append(csv_row(f"kernel/step1_batch{BATCH}/pallas_interp",
                        t_pl_b / BATCH * 1e6,
                        f"amortization_x={t_pl/(t_pl_b/BATCH):.1f}"))
    return rows


if __name__ == "__main__":
    run()
