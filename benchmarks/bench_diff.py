"""Diff a perf_engine JSON artifact against committed baselines.

CI runs the smoke benches on every build (`bench_smoke*.json`) while the
repo commits full-size baselines per PR (`BENCH_PR4.json` …). This tool
makes the comparison part of the job output: flatten every NUMERIC leaf
under `modes`, join on the flattened key, and print a markdown table of
relative changes — WARN-ONLY (always exits 0): smoke-vs-full and
runner-vs-runner numbers differ legitimately, so the table is a signal
for a human (or a future gating pass with machine-matched provenance —
the artifacts now carry a `provenance` block for exactly that), not a
build gate.

    python -m benchmarks.bench_diff bench_smoke.json \
        --baseline BENCH_PR7.json --baseline BENCH_PR6.json \
        --threshold 0.10 --out summary.md

Baselines merge in the order given, FIRST file wins on key collisions —
list the newest baseline first. `--threshold` bolds rows whose relative
change exceeds it (default 0.10). `--out` appends the table to a file
(CI passes `$GITHUB_STEP_SUMMARY`).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Tuple


def flatten_modes(payload: dict) -> Dict[str, float]:
    """Every numeric leaf under `modes`, keyed by its `/`-joined path.
    Booleans are kept (as 0/1 acceptance flags); strings are dropped."""
    out: Dict[str, float] = {}

    def walk(node, path: str):
        if isinstance(node, dict):
            for key in sorted(node):
                walk(node[key], f"{path}/{key}" if path else str(key))
        elif isinstance(node, bool):
            out[path] = float(node)
        elif isinstance(node, (int, float)):
            out[path] = float(node)

    walk(payload.get("modes", {}), "")
    return out


def load_flat(path: str) -> Tuple[Dict[str, float], str]:
    with open(path) as f:
        payload = json.load(f)
    label = f"pr{payload.get('pr', '?')}"
    return flatten_modes(payload), label


def diff_table(current: Dict[str, float], baseline: Dict[str, float],
               threshold: float) -> Tuple[str, int]:
    """Markdown table over the shared keys; returns (table, n_flagged)."""
    shared = sorted(set(current) & set(baseline))
    lines = ["| metric | baseline | current | Δ |",
             "|---|---:|---:|---:|"]
    flagged = 0
    for key in shared:
        b, c = baseline[key], current[key]
        if b == c:
            delta = "0%"
        elif b == 0:
            delta = "n/a"
        else:
            rel = (c - b) / abs(b)
            delta = f"{rel:+.1%}"
            if abs(rel) > threshold:
                flagged += 1
                delta = f"**{delta}**"
        lines.append(f"| `{key}` | {b:.6g} | {c:.6g} | {delta} |")
    only_c = sorted(set(current) - set(baseline))
    for key in only_c:
        lines.append(f"| `{key}` | — | {current[key]:.6g} | new |")
    return "\n".join(lines), flagged


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly produced perf_engine JSON")
    ap.add_argument("--baseline", action="append", default=[],
                    metavar="PATH", required=True,
                    help="committed baseline(s); first wins on collisions")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative change that flags a row (default 0.10)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="append the markdown report to PATH "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()

    current, cur_label = load_flat(args.current)
    baseline: Dict[str, float] = {}
    labels = []
    for path in args.baseline:
        flat, label = load_flat(path)
        labels.append(label)
        for key, val in flat.items():
            baseline.setdefault(key, val)      # first file wins

    table, flagged = diff_table(current, baseline, args.threshold)
    n_shared = len(set(current) & set(baseline))
    report = (f"### Bench diff: `{args.current}` vs "
              f"{', '.join(labels)}\n\n"
              f"{n_shared} shared metrics, {flagged} beyond "
              f"±{args.threshold:.0%} (warn-only — smoke sizes and CI "
              f"runners are not the baseline machine)\n\n{table}\n")
    print(report)
    if args.out:
        with open(args.out, "a") as f:
            f.write(report + "\n")
    return 0            # warn-only by design


if __name__ == "__main__":
    raise SystemExit(main())
