"""Shared benchmark harness.

The paper's datasets are MF embeddings (d=200) of Amazon-K / MovieLens /
Netflix; the container is offline, so each benchmark runs a REDUCED-SCALE
replica with the same Gaussian-norm profile (paper Fig. 2) and the same
n:m aspect ratio. Full-scale shapes are exercised by the dry-run
(`python -m repro.launch.dryrun --engine`). Timings below are CPU trends,
not TPU wall-clock — §Roofline covers the TPU story.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import synthetic_embeddings


@dataclasses.dataclass(frozen=True)
class BenchDataset:
    name: str
    n: int
    m: int
    d: int = 200


# reduced replicas, n:m ratios ≈ paper's (3.3:1, 2.8:1, 27:1)
BENCH_DATASETS = (
    BenchDataset("amazon-k/64", 21_983, 6_727),
    BenchDataset("movielens/16", 10_158, 3_690),
    BenchDataset("netflix/32", 15_005, 555),
)


def load(ds: BenchDataset, seed: int = 0):
    users, items = synthetic_embeddings(jax.random.PRNGKey(seed), ds.n,
                                        ds.m, ds.d)
    return users, items


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (blocking on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row
