"""Shared benchmark harness.

The paper's datasets are MF embeddings (d=200) of Amazon-K / MovieLens /
Netflix; the container is offline, so each benchmark runs a REDUCED-SCALE
replica with the same Gaussian-norm profile (paper Fig. 2) and the same
n:m aspect ratio. Full-scale shapes are exercised by the dry-run
(`python -m repro.launch.dryrun --engine`). Timings below are CPU trends,
not TPU wall-clock — §Roofline covers the TPU story.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import synthetic_embeddings


@dataclasses.dataclass(frozen=True)
class BenchDataset:
    name: str
    n: int
    m: int
    d: int = 200


# reduced replicas, n:m ratios ≈ paper's (3.3:1, 2.8:1, 27:1)
BENCH_DATASETS = (
    BenchDataset("amazon-k/64", 21_983, 6_727),
    BenchDataset("movielens/16", 10_158, 3_690),
    BenchDataset("netflix/32", 15_005, 555),
)


def load(ds: BenchDataset, seed: int = 0):
    users, items = synthetic_embeddings(jax.random.PRNGKey(seed), ds.n,
                                        ds.m, ds.d)
    return users, items


def zipf_clustered(key, n, m, d, n_clusters=None, a=1.1, user_spread=0.05,
                   item_spread=0.5):
    """Zipf-sized Gaussian user clusters in CLUSTER-CONTIGUOUS row order
    (coherent summary blocks — the pruning-favorable layout an id-ordered
    production user table exhibits after any locality-preserving
    ingest), items drawn near the same centers with Zipf popularity.

    Users are tight around their center (coordinate boxes stay
    informative in high d), items spread wider (so the rank table
    resolves the top of each user's score range instead of cramming
    near-duplicate items into one grid cell). The cluster count scales
    with n so even the Zipf TAIL clusters span several 256-row summary
    blocks — a block mixing many micro-clusters has a uselessly loose
    box (that is the adversarial case, measured separately)."""
    if n_clusters is None:
        n_clusters = max(8, min(64, n // 4096))
    ranks = np.arange(1, n_clusters + 1, dtype=np.float64)
    w = ranks ** -a
    w /= w.sum()
    counts = np.floor(w * n).astype(int)
    counts[0] += n - counts.sum()
    kc, ku, ki, kn = jax.random.split(key, 4)
    centers = jax.random.normal(kc, (n_clusters, d), jnp.float32) * 2.0
    assign = np.repeat(np.arange(n_clusters), counts)
    users = (centers[jnp.asarray(assign)]
             + user_spread * jax.random.normal(ku, (n, d), jnp.float32))
    icl = np.asarray(jax.random.categorical(
        ki, jnp.log(jnp.asarray(w, jnp.float32)), shape=(m,)))
    items = (centers[jnp.asarray(icl)]
             + item_spread * jax.random.normal(kn, (m, d), jnp.float32))
    return users, items, icl


def mid_mixture(key, n, m, d, noise_frac=0.10, noise_scale=2.0):
    """The MID-ENTROPY user regime (PR 6): a Zipf-clustered core mixed
    with an i.i.d. Gaussian noise floor, then globally SHUFFLED in row
    order — the production-promoter workload shape where users have real
    cluster structure but the stored row order carries none of it.

    As given, every 256-row summary tile mixes clusters with noise and
    any per-tile sketch is uselessly loose (PR 4 falls back to the dense
    scan here, ≈ 1.0×); a build-time k-means reorder recovers the
    cluster contiguity for the (1 − noise_frac) core, leaving only the
    noise-floor tiles unprunable. Noise rows are UNPRUNABLE BY
    CONSTRUCTION, not merely unclustered: a user's reverse rank is
    scale-invariant in ‖u‖ and an isotropic direction can't be cone- or
    box-bounded away from any query, so every noise row survives phase A
    for ~any query — noise_frac is a floor on the kept fraction, which
    is exactly what a "mid-entropy" regime is supposed to pin. Items
    come from the clustered generator (so hot promoted-item queries
    exist); `icl` is their cluster assignment."""
    kz, kn, ks = jax.random.split(key, 3)
    n_core = int(round(n * (1.0 - noise_frac)))
    core, items, icl = zipf_clustered(kz, n_core, m, d)
    noise = noise_scale * jax.random.normal(kn, (n - n_core, d),
                                            jnp.float32)
    users = jnp.concatenate([core, noise])
    users = users[jax.random.permutation(ks, n)]
    return users, items, icl


def iid_users(key, n, m, d):
    """The fully adversarial regime: i.i.d. Gaussian users AND items —
    no block structure for any sketch to exploit at any layout."""
    ku, ki = jax.random.split(key)
    return (jax.random.normal(ku, (n, d), jnp.float32),
            jax.random.normal(ki, (m, d), jnp.float32), None)


REGIMES = ("clustered", "iid", "mid")


def make_regime(regime: str, key, n, m, d):
    """(users, items, item_cluster_or_None) for a named user-distribution
    regime — the `--regime` axis of `perf_engine --pruned`."""
    if regime == "clustered":
        return zipf_clustered(key, n, m, d)
    if regime == "mid":
        return mid_mixture(key, n, m, d)
    if regime == "iid":
        return iid_users(key, n, m, d)
    raise ValueError(f"unknown regime {regime!r}; one of {REGIMES}")


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (blocking on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row
