"""§Perf H4/H6 — engine-query hillclimb harness.

Part A (dry-run, 512 host devices): lowers the sharded query for each
(τ, storage_dtype) variant at full Amazon-K scale and reports the
three roofline terms. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --roofline

Part B (CPU, real execution): measures accuracy / overall-ratio of the
same variants on a reduced replica, proving the memory-term optimizations
don't cost quality. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --quality

Part C (CPU, real execution): the PR-1 acceptance benchmark — wall-time
per query of `query_batch` vs batch size B on the same backend. The
batched path reads the (n, τ) rank table and (n, d) user matrix ONCE per
batch, so ms/query must drop monotonically-ish with B (B=16 strictly
below B=1). Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --batched

Part D (CPU, real execution): the PR-2 serving benchmark — achieved
throughput and p50/p99 latency of the async MicroBatcher vs OFFERED load
(queries submitted one at a time on a paced clock), swept over several
`max_wait_ms` settings. Low max_wait_ms bounds latency but dispatches
emptier ticks; high max_wait_ms fills ticks (table-bandwidth
amortization) at the cost of queueing latency. The `rej` column shows
the back-pressure knee: with --serve the sweep runs a bounded queue
(max_depth), so past-capacity offered load turns into fail-fast
rejections instead of unbounded queueing latency. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --serve

Part F (CPU, real execution): the PR-4/PR-6 block-pruning benchmark —
B = 16 `query_batch` latency of the `"pruned:dense"` backend vs the
unpruned full scan, at n ∈ {64k, 256k} under `--regime`:
  clustered  Zipf-clustered users already in cluster-contiguous row
             order (the PR-4 favorable case), measured WITHOUT reorder.
  mid        Zipf core + i.i.d. noise floor, globally shuffled rows
             (PR 6): no layout structure as given — the pruned engine
             gets the build-time k-means reorder + cone sketches, and
             answers are translated back to pre-reorder coordinates
             through the snapshot's `user_remap`.
  iid        fully adversarial (informational; the dedicated
             adversarial block below always runs at n = 64k).
Acceptance: clustered ≥ 2.2× and mid ≥ 1.5× over dense at n = 256k for
k ≤ 16, ≤ 1.1× overhead in the adversarial no-skip case, bit-identical
selected indices vs the same-layout unpruned backend on every measured
batch, and (reordered regimes) remap-translated indices identical to the
original-layout scan up to bitwise-tied est positions. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --pruned --regime mid

Part G (CPU, real execution): the PR-5 storage-tier benchmark — B = 16
`query_batch` latency of the dense backend at StorageSpec ∈ {f32, bf16,
int8} on the SAME index data (paired min-of-rounds, like --pruned), plus
certified-containment and top-k-overlap checks on every measured batch.
int8 storage streams ~4× fewer bytes on the scan PR 4 showed is the cost
center. Acceptance: int8 ≥ 1.5× over f32-dense at n = 256k, d = 64,
τ = 128, B = 16, recorded in BENCH_PR5.json. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --quant

`--json PATH` dumps every executed mode's metrics machine-readably
(latencies, ratios, skip rates — the perf trajectory artifact; see
BENCH_PR4.json / BENCH_PR5.json); `--smoke` shrinks sizes for CI.

Part E (CPU, real execution): the PR-3 dynamic-index benchmark — B = 16
`query_batch` latency and rank quality of the DELTA PATH (streaming
inserts absorbed without rebuild, `repro.index`) vs the static index and
vs a from-scratch rebuild, swept over the delta ratio, on the
paper_engine table config (reduced-scale replica). Acceptance: at a 5%
insert delta the delta path stays ≤ 1.3× the static-index latency on the
dense and fused backends, and its overall-ratio against the exact oracle
on the MERGED item set stays within the configured slack of the
rebuild's. Also reports the rebuild cadence (full Algorithm 1 + hot-swap
wall time). Since PR 7 the mode ends with the compile-storm churn
replay: the same growing-n publish sequence served through the stock
backends (one retrace per n) and through `elastic:*` (one
capacity-padded program per backend — `repro.core.elastic`), reporting
per-backend compile counts, the first-query-at-new-n swap spike, and
steady-state p50/p99; `--smoke` runs ONLY the replay at CI sizes. Run
with:
    PYTHONPATH=src python -m benchmarks.perf_engine --updates

Part H (CPU, real execution): the PR-9 availability benchmark — the
full serving stack (MicroBatcher with deadlines + MaintenanceLoop) run
under a SEEDED fault plan (`repro.serve.faults`): two injected rebuild
failures, one injected dispatch failure, random injected tick latency.
Acceptance: ≥ 99% of non-shed, non-faulted requests resolve within
their deadline with valid certified (r↓, r↑) bounds; ZERO futures left
pending after close; injected failures surface as the typed
`InjectedFault`, never as wrong answers or torn futures; and the
maintenance loop recovers (consecutive-failures gauge back to 0)
WITHOUT a process restart. Run with:
    PYTHONPATH=src python -m benchmarks.perf_engine --faults
"""
from __future__ import annotations

import argparse
import dataclasses

# Machine-readable metrics, keyed by mode name; each *_mode() fills its
# entry and --json dumps the dict (the perf-trajectory artifact).
METRICS: dict = {}

VARIANTS = [
    ("baseline_tau500_f32", dict(tau=500, storage_dtype="float32")),
    ("tau128_f32", dict(tau=128, storage_dtype="float32")),
    ("tau500_bf16", dict(tau=500, storage_dtype="bfloat16")),
    ("tau128_bf16", dict(tau=128, storage_dtype="bfloat16")),
    ("tau500_int8", dict(tau=500, storage_dtype="int8")),
    ("tau128_int8", dict(tau=128, storage_dtype="int8")),
]


def roofline_mode():
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import jax.numpy as jnp
    from repro.configs.paper_engine import AMAZON_K, DEFAULT_TABLE
    from repro.core import distributed as D
    from repro.core.types import RankTable, RankTableConfig
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_production_mesh

    mesh = D.flat_mesh(make_production_mesh(multi_pod=True))
    chips = mesh.devices.size
    n = -(-AMAZON_K.n_users // chips) * chips
    d = AMAZON_K.d
    users_sds = jax.ShapeDtypeStruct((n, d), jnp.float32)
    q_sds = jax.ShapeDtypeStruct((d,), jnp.float32)
    print(f"amazon-k query on flat{chips}: n={n:,} d={d}")
    for name, kw in VARIANTS:
        cfg = dataclasses.replace(DEFAULT_TABLE, **kw)
        st = cfg.storage.table_dtype
        vec = jax.ShapeDtypeStruct((n, 1), jnp.float32)
        quant = ({f: vec for f in RankTable._QUANT_FIELDS}
                 if cfg.storage.kind == "int8" else {})
        rt_sds = RankTable(
            thresholds=jax.ShapeDtypeStruct((n, cfg.tau), st),
            table=jax.ShapeDtypeStruct((n, cfg.tau), st),
            m=jax.ShapeDtypeStruct((), jnp.int32), **quant)
        qfn = D.make_query_fn(mesh, k=10, n=n, c=2.0)
        compiled = jax.jit(qfn).lower(rt_sds, users_sds, q_sds).compile()
        roof = RL.analyze(compiled, chips=chips, model_flops=2.0 * n * d)
        print(f"{name:22s} mem={roof.memory_s*1e6:7.1f}µs "
              f"comp={roof.compute_s*1e6:6.1f}µs "
              f"coll={roof.collective_s*1e6:6.1f}µs "
              f"hbm/dev={roof.hbm_bytes/2**20:7.1f}MiB "
              f"→ {roof.bottleneck}")

    # §Perf H6: batched queries amortize the (users + table) stream
    for b in (16, 64):
        cfg = dataclasses.replace(DEFAULT_TABLE, tau=128)
        rt_sds = RankTable(
            thresholds=jax.ShapeDtypeStruct((n, cfg.tau), jnp.float32),
            table=jax.ShapeDtypeStruct((n, cfg.tau), jnp.float32),
            m=jax.ShapeDtypeStruct((), jnp.int32))
        qs_sds = jax.ShapeDtypeStruct((b, d), jnp.float32)
        bq = D.make_batch_query_fn(mesh, k=10, n=n, c=2.0)
        compiled = jax.jit(bq).lower(rt_sds, users_sds, qs_sds).compile()
        roof = RL.analyze(compiled, chips=chips,
                          model_flops=2.0 * n * d * b)
        print(f"tau128_batch{b:<3d}        mem={roof.memory_s/b*1e6:7.1f}µs"
              f"/q comp={roof.compute_s/b*1e6:5.1f}µs/q "
              f"coll={roof.collective_s/b*1e6:5.1f}µs/q "
              f"hbm/dev={roof.hbm_bytes/2**20:7.1f}MiB "
              f"→ {roof.bottleneck} (batch of {b})")


def quality_mode():
    import jax
    import numpy as np
    from repro.core import ReverseKRanksEngine, metrics
    from repro.core.exact import exact_ranks, reverse_k_ranks
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings

    users, items = synthetic_embeddings(jax.random.PRNGKey(0), 20_000,
                                        8_000, 200)
    for name, kw in VARIANTS:
        cfg = RankTableConfig(omega=10, s=64, **kw)
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1))
        accs, ratios = [], []
        for qi in range(12):
            q = items[qi * 71]
            truth = np.asarray(exact_ranks(users, items, q))
            ex_idx, _ = reverse_k_ranks(users, items, q, 10)
            r = eng.query(q, k=10, c=2.0)
            accs.append(metrics.accuracy(np.asarray(r.indices),
                                         np.asarray(ex_idx), truth, 2.0))
            ratios.append(metrics.overall_ratio(
                np.asarray(r.indices), np.asarray(ex_idx), truth))
        print(f"{name:22s} acc={np.mean(accs):.4f} "
              f"ratio={np.mean(ratios):.4f} "
              f"index={eng.memory_bytes()/2**20:.1f}MiB")
        METRICS.setdefault("quality", {})[name] = {
            "accuracy": float(np.mean(accs)),
            "overall_ratio": float(np.mean(ratios)),
            "index_mib": eng.memory_bytes() / 2**20}


def batched_mode():
    """Acceptance: ms/query at B=16 strictly below the B=1 per-query path
    on the same backend — the n·(d+2τ) stream is read once per batch."""
    import jax
    from benchmarks.common import timeit
    from repro.core import ReverseKRanksEngine
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings

    users, items = synthetic_embeddings(jax.random.PRNGKey(0), 16_384,
                                        4_096, 128)
    cfg = RankTableConfig(tau=128, omega=8, s=32)
    print(f"batched query_batch sweep: n={users.shape[0]:,} "
          f"m={items.shape[0]:,} d={users.shape[1]} tau={cfg.tau}")
    results = {}
    for backend in ("dense", "fused"):
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1),
                                        backend=backend)
        base = None
        for B in (1, 4, 16, 64):
            qs = items[:B]
            t = timeit(lambda Q: eng.query_batch(Q, k=10, c=2.0).indices,
                       qs, iters=3)
            per_q = t / B
            if base is None:
                base = per_q
            results[(backend, B)] = per_q
            print(f"{backend:6s} B={B:3d}  {per_q*1e3:8.3f} ms/query  "
                  f"{B/t:8.1f} q/s  amortization×{base/per_q:5.2f}")
            METRICS.setdefault("batched", {})[f"{backend}_B{B}"] = {
                "ms_per_q": per_q * 1e3}
    for backend in ("dense", "fused"):
        ok = results[(backend, 16)] < results[(backend, 1)]
        print(f"{backend}: B=16 per-query < B=1 per-query: "
              f"{'PASS' if ok else 'FAIL'}")
        METRICS["batched"][f"{backend}_amortizes"] = bool(ok)


def serve_mode():
    """Throughput vs offered load through the async scheduler, at several
    max_wait_ms settings (the latency/throughput knob)."""
    import time

    import jax
    from benchmarks.common import timeit
    from repro.core import ReverseKRanksEngine
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings
    from repro.serve import MicroBatcher, QueueFull

    users, items = synthetic_embeddings(jax.random.PRNGKey(0), 8_192,
                                        2_048, 64)
    cfg = RankTableConfig(tau=64, omega=8, s=32)
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    max_batch, n_queries = 16, 192
    qs = items[:max_batch]

    # calibrate offered load to this host: full-tick dispatch capacity
    t_tick = timeit(lambda Q: eng.query_batch(Q, k=10, c=2.0).indices, qs,
                    iters=3)
    capacity = max_batch / t_tick
    print(f"serve sweep: n={users.shape[0]:,} m={items.shape[0]:,} "
          f"d={users.shape[1]} tau={cfg.tau}  max_batch={max_batch}  "
          f"full-tick capacity ≈ {capacity:,.0f} q/s")
    print(f"{'max_wait_ms':>11s} {'offered q/s':>11s} {'achieved q/s':>12s} "
          f"{'fill':>5s} {'p50 ms':>8s} {'p99 ms':>8s}")

    _obs_overhead_check(eng, items, max_batch, n_queries)

    for max_wait_ms in (0.5, 2.0, 8.0):
        for load_frac in (0.25, 1.0, 4.0):
            rate = capacity * load_frac
            # bounded queue: past the overload knee, offered load shows
            # up as fail-fast rejections (rej column), not as unbounded
            # queueing latency
            with MicroBatcher(eng, max_batch=max_batch,
                              max_wait_ms=max_wait_ms,
                              max_depth=4 * max_batch) as mb:
                t0 = time.perf_counter()
                futs = []
                for i in range(n_queries):
                    target = t0 + i / rate        # paced open-loop arrivals
                    delay = target - time.perf_counter()
                    if delay > 0:
                        time.sleep(delay)
                    try:
                        futs.append(mb.submit(items[i % items.shape[0]],
                                              10, 2.0))
                    except QueueFull:
                        pass                      # counted in stats()
                for f in futs:
                    f.result()
                wall = time.perf_counter() - t0
                st = mb.stats()
            print(f"{max_wait_ms:11.1f} {rate:11,.0f} "
                  f"{len(futs) / wall:12,.0f} {st.mean_fill:5.2f} "
                  f"{st.p50_ms:8.2f} {st.p99_ms:8.2f} "
                  f"rej {st.rejected:4d} (hwm {st.depth_hwm})")
            METRICS.setdefault("serve", {})[
                f"wait{max_wait_ms}_load{load_frac}"] = {
                "offered_qps": rate, "achieved_qps": len(futs) / wall,
                "fill": st.mean_fill, "p50_ms": st.p50_ms,
                "p99_ms": st.p99_ms, "rejected": st.rejected}

    _near_dup_cache_sweep(eng, users, items)


def saturate_mode(smoke: bool = False):
    """PR-10 acceptance: offered-load ramp through the serving scheduler,
    locating the throughput KNEE — the highest offered load whose tail is
    still healthy (p99 ≤ 2×p50, no back-pressure rejects) — for the
    synchronous schedule (pipeline_depth=1) and the double-buffered
    default (pipeline_depth=2), plus each arm's overlap efficiency.

    The pre-PR comparison (BENCH_PR10 gate: knee ≥ 1.5× the pre-PR
    scheduler's) is produced by running THIS ramp against the parent
    commit's src and pointing `REPRO_SATURATE_BASELINE` at its dump:

        git worktree add .bench_baseline <parent-sha>
        PYTHONPATH=.bench_baseline/src:. python benchmarks/perf_engine.py \\
            --serve --saturate --json baseline.json
        git worktree remove .bench_baseline
        REPRO_SATURATE_BASELINE=baseline.json PYTHONPATH=src:. \\
            python benchmarks/perf_engine.py --serve --saturate \\
            --json BENCH_PR10.json

    On a pre-PR src the `pipeline_depth` kwarg does not exist; the ramp
    detects that and records the single legacy arm as "sync". On this
    CPU-only host the knee gain comes mostly from PR 10's device
    residency (host-side batch assembly, ONE H2D and ONE D2H per tick,
    zero-copy result views); the depth-2 overlap itself is ~neutral here
    because XLA-CPU compute already owns every core — it pays off where
    D2H latency is real (see launch/serve.py runbook).
    """
    import inspect
    import os
    import time

    import jax
    import numpy as np
    from benchmarks.common import timeit
    from repro.core import ReverseKRanksEngine
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings
    from repro.serve import MicroBatcher, QueueFull

    if smoke:
        n, m, d, tau, n_queries, rounds = 1_024, 512, 32, 32, 64, 1
        mults = (0.5, 1.0, 2.0)
    else:
        n, m, d, tau, n_queries, rounds = 4_096, 2_048, 64, 64, 256, 3
        # floor low enough to locate the PRE-PR scheduler's knee too (it
        # saturates an order of magnitude below the pipelined one);
        # best-of-`rounds` per point — single-run points swing ±15% on a
        # shared host and the knee detector needs a stable tail
        mults = (0.05, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0)
    users, items = synthetic_embeddings(jax.random.PRNGKey(0), n, m, d)
    cfg = RankTableConfig(tau=tau, omega=8, s=32)
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    max_batch = 16

    supports_pipeline = "pipeline_depth" in inspect.signature(
        MicroBatcher.__init__).parameters
    arms = ({"depth1": 1, "depth2": 2} if supports_pipeline
            else {"sync": None})

    # clients hold HOST queries (the PR-10 contract: submit is H2D-free;
    # the pre-PR scheduler pays its per-request jnp.asarray here instead)
    host_items = np.asarray(items)
    qs = items[:max_batch]
    t_tick = timeit(lambda Q: eng.query_batch(Q, k=10, c=2.0).indices, qs,
                    iters=3)
    capacity = max_batch / t_tick
    # warm the scheduler path once (tick-shape compile + thread spin-up)
    # so the first ramp point measures steady state, not warm-up
    with MicroBatcher(eng, max_batch=max_batch, max_wait_ms=2.0) as mb:
        for f in [mb.submit(host_items[i], 10, 2.0)
                  for i in range(2 * max_batch)]:
            f.result()
    print(f"saturate ramp: n={n:,} m={m:,} d={d} tau={tau} "
          f"max_batch={max_batch}  full-tick capacity ≈ {capacity:,.0f} q/s"
          f"  arms={list(arms)}")
    print(f"{'arm':>6s} {'offered q/s':>11s} {'achieved q/s':>12s} "
          f"{'p50 ms':>8s} {'p99 ms':>8s} {'rej':>4s} {'ovl':>5s}")

    out: dict = {"capacity_qps": capacity, "n": n, "m": m, "d": d,
                 "tau": tau, "max_batch": max_batch, "arms": {}}
    for arm, depth in arms.items():
        kw = {} if depth is None else {"pipeline_depth": depth}
        runs = []
        for load_frac in mults:
            rate = capacity * load_frac
            run = None
            for _ in range(rounds):
                with MicroBatcher(eng, max_batch=max_batch, max_wait_ms=2.0,
                                  max_depth=4 * max_batch, **kw) as mb:
                    t0 = time.perf_counter()
                    futs = []
                    for i in range(n_queries):
                        target = t0 + i / rate    # paced open-loop arrivals
                        delay = target - time.perf_counter()
                        if delay > 0:
                            time.sleep(delay)
                        try:
                            futs.append(mb.submit(
                                host_items[i % host_items.shape[0]],
                                10, 2.0))
                        except QueueFull:
                            pass                  # counted in stats()
                    for f in futs:
                        f.result()
                    wall = time.perf_counter() - t0
                    st = mb.stats()
                cand = {"offered_qps": rate,
                        "achieved_qps": len(futs) / wall,
                        "p50_ms": st.p50_ms, "p99_ms": st.p99_ms,
                        "rejected": st.rejected,
                        "overlap_efficiency":
                            getattr(st, "overlap_efficiency", 0.0),
                        "healthy": (st.p99_ms <= 2.0 * st.p50_ms
                                    and st.rejected == 0)}
                # best-of-rounds: prefer healthy, then higher throughput
                # (a shared-host hiccup in any single round must not
                # masquerade as this arm's knee)
                if run is None or (cand["healthy"], cand["achieved_qps"]) \
                        > (run["healthy"], run["achieved_qps"]):
                    run = cand
            runs.append(run)
            print(f"{arm:>6s} {rate:11,.0f} {run['achieved_qps']:12,.0f} "
                  f"{run['p50_ms']:8.2f} {run['p99_ms']:8.2f} "
                  f"{run['rejected']:4d} "
                  f"{run['overlap_efficiency']:5.2f}"
                  f"{'' if run['healthy'] else '   ← past knee'}")
        healthy = [r for r in runs if r["healthy"]]
        knee = max((r["achieved_qps"] for r in healthy), default=0.0)
        at_knee = max(healthy, key=lambda r: r["achieved_qps"],
                      default=None) if healthy else None
        out["arms"][arm] = {
            "runs": runs, "knee_qps": knee,
            "knee_p99_ms": at_knee["p99_ms"] if at_knee else None,
            "overlap_efficiency_at_knee":
                at_knee["overlap_efficiency"] if at_knee else None}
        print(f"{arm}: knee ≈ {knee:,.0f} q/s "
              f"(p99 {at_knee['p99_ms']:.2f} ms, "
              f"ovl {at_knee['overlap_efficiency']:.2f})" if at_knee
              else f"{arm}: no healthy run — knee below the ramp floor")

    if supports_pipeline:
        k1 = out["arms"]["depth1"]["knee_qps"]
        k2 = out["arms"]["depth2"]["knee_qps"]
        out["knee_speedup_depth2_vs_depth1"] = (k2 / k1) if k1 else None

    base_path = os.environ.get("REPRO_SATURATE_BASELINE")
    if base_path:
        import json
        try:
            with open(base_path) as f:
                base = json.load(f)
            base_sat = base["modes"]["serve_saturate"]
            base_runs = [r for a in base_sat["arms"].values()
                         for r in a["runs"]]
            cur_runs = [r for a in out["arms"].values() for r in a["runs"]]
            # Two equal-p99 readings of "≥ 1.5× the pre-PR knee":
            # (a) knee vs knee — each arm's best HEALTHY throughput
            #     (p99 ≤ 2×p50, zero rejects); valid as an equal-p99
            #     claim only when the pipelined knee's p99 is no worse
            #     than the pre-PR knee's.
            # (b) p99 budget — the pre-PR scheduler's best sustained
            #     throughput at ANY tail (typically its overloaded,
            #     load-shedding regime) sets a p99 budget; the pipelined
            #     scheduler's best throughput while staying WITHIN it.
            pre_knee = max(
                (a for a in base_sat["arms"].values() if a["knee_qps"]),
                key=lambda a: a["knee_qps"], default=None)
            cur_knee = max(
                (a for a in out["arms"].values() if a["knee_qps"]),
                key=lambda a: a["knee_qps"], default=None)
            speedup_knee = None
            if pre_knee and cur_knee and \
                    cur_knee["knee_p99_ms"] <= pre_knee["knee_p99_ms"]:
                speedup_knee = cur_knee["knee_qps"] / pre_knee["knee_qps"]
            pre_best = max(base_runs, key=lambda r: r["achieved_qps"])
            budget = pre_best["p99_ms"]
            pipe_best = max((r["achieved_qps"] for r in cur_runs
                             if r["p99_ms"] <= budget), default=0.0)
            speedup_budget = pipe_best / pre_best["achieved_qps"]
            speedups = [s for s in (speedup_knee, speedup_budget)
                        if s is not None]
            ok = bool(speedups) and max(speedups) >= 1.5
            out["pre_pr"] = {
                "path": base_path,
                "git_sha": base.get("provenance", {}).get("git_sha"),
                "knee_qps": pre_knee["knee_qps"] if pre_knee else 0.0,
                "knee_p99_ms": pre_knee["knee_p99_ms"] if pre_knee
                else None,
                "speedup_knee_vs_knee": speedup_knee,
                "best_qps": pre_best["achieved_qps"],
                "p99_budget_ms": budget,
                "pipelined_qps_at_equal_p99": pipe_best,
                "speedup_at_p99_budget": speedup_budget,
                "gate_1p5x": ok}
            if speedup_knee is not None:
                print(f"knee vs knee: {cur_knee['knee_qps']:,.0f} q/s "
                      f"(p99 {cur_knee['knee_p99_ms']:.1f} ms) vs pre-PR "
                      f"{pre_knee['knee_qps']:,.0f} q/s "
                      f"(p99 {pre_knee['knee_p99_ms']:.1f} ms) → "
                      f"{speedup_knee:.2f}x at equal-or-better p99")
            print(f"p99 budget: pre-PR best {pre_best['achieved_qps']:,.0f}"
                  f" q/s (p99 {budget:.1f} ms); pipelined sustains "
                  f"{pipe_best:,.0f} q/s within it → {speedup_budget:.2f}x")
            print(f"gate ≥ 1.5x vs pre-PR: "
                  f"{'PASS' if ok else 'WARN'} "
                  f"(best reading {max(speedups):.2f}x)" if speedups
                  else "gate ≥ 1.5x vs pre-PR: WARN (no valid reading)")
        except Exception as e:                    # baseline is optional
            print(f"baseline {base_path} unreadable ({e}); skipping gate")
    METRICS["serve_saturate"] = out


def _obs_overhead_check(eng, items, max_batch: int, n_queries: int):
    """PR-8 acceptance: the telemetry layer must be ≈ free on the serving
    path. Serve the same closed-loop burst with trace spans DISABLED (the
    default: metrics counters only) and ENABLED (every tick/phase
    records a span), min-of-rounds each, and report the wall-time ratio.
    Gate: spans-on ≤ 1.03× spans-off (warn-only in --smoke CI)."""
    import time

    from repro.obs import trace
    from repro.serve import MicroBatcher

    def burst() -> float:
        t0 = time.perf_counter()
        with MicroBatcher(eng, max_batch=max_batch, max_wait_ms=0.5) as mb:
            futs = [mb.submit(items[i % items.shape[0]], 10, 2.0)
                    for i in range(n_queries)]
            for f in futs:
                f.result()
        return time.perf_counter() - t0

    burst()                                     # shared warm-up compile
    rounds = 3
    was_enabled = trace.is_enabled()
    try:
        # interleaved paired rounds so host-load drift hits both arms
        t_off, t_on = float("inf"), float("inf")
        for _ in range(rounds):
            trace.disable()
            t_off = min(t_off, burst())
            trace.enable()
            t_on = min(t_on, burst())
    finally:
        trace.clear()
        if was_enabled:
            trace.enable()
        else:
            trace.disable()
    ratio = t_on / t_off
    ok = ratio <= 1.03
    print(f"obs overhead: spans-on {t_on*1e3:.1f} ms vs spans-off "
          f"{t_off*1e3:.1f} ms → {ratio:.3f}x "
          f"({'PASS' if ok else 'WARN'} ≤ 1.03x gate)")
    METRICS.setdefault("serve", {})["obs_overhead"] = {
        "spans_off_s": t_off, "spans_on_s": t_on, "ratio": ratio,
        "pass_1.03x": ok}


def _near_dup_cache_sweep(eng, users, items):
    """PR-5 satellite: near-duplicate query caching — hit rate vs rank
    quality when the `CachingBackend` LRU key is quantized query bytes
    (`quantize_key_bits`), on a hot-item workload with per-ask jitter.

    A quantized key trades exactness for reuse: queries within ~half a
    grid cell per coordinate share an entry, so the served result is the
    exact answer of a NEIGHBORING query. Coarser grids (fewer bits) raise
    the hit rate and the rank-quality cost — both measured here against
    the exact oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import metrics
    from repro.core.exact import exact_ranks, reverse_k_ranks
    from repro.serve.cache import CachingBackend

    k, c = 10, 2.0
    n_hot, n_asks, jitter = 6, 96, 1e-3
    hot = items[:n_hot]
    noise = jax.random.normal(jax.random.PRNGKey(3),
                              (n_asks, hot.shape[1]), jnp.float32)
    which = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (n_asks,),
                                          0, n_hot))
    asks = hot[jnp.asarray(which)] * (1.0 + jitter * noise)
    truths = {}
    for h in range(n_hot):                      # oracle per HOT CENTER
        truth = np.asarray(exact_ranks(users, items, hot[h]))
        ex_idx, _ = reverse_k_ranks(users, items, hot[h], k)
        truths[h] = (truth, ex_idx)
    snap = eng.current_snapshot()
    print(f"\nnear-duplicate caching: {n_hot} hot items × {n_asks} asks, "
          f"jitter {jitter:g} (quality = overall-ratio vs exact at the "
          f"hot centers)")
    print(f"{'key bits':>8s} {'hit rate':>8s} {'ratio':>7s}")
    for bits in (None, 10, 8, 6):
        bk = CachingBackend("dense", quantize_key_bits=bits)
        ratios = []
        for i in range(n_asks):
            res = bk.query_batch(snap.rank_table, snap.query_users(),
                                 asks[i:i + 1], k=k, c=c)
            truth, ex_idx = truths[int(which[i])]
            ratios.append(metrics.overall_ratio(
                np.asarray(res.indices[0]), np.asarray(ex_idx), truth))
        hit_rate = bk.hits / max(bk.hits + bk.misses, 1)
        ratio = float(np.mean(ratios))
        print(f"{str(bits):>8s} {hit_rate:8.2f} {ratio:7.3f}")
        METRICS.setdefault("serve", {})[f"neardup_bits{bits}"] = {
            "hit_rate": hit_rate, "overall_ratio": ratio}


def _compile_storm_replay(smoke: bool = False):
    """PR-7 acceptance: a churn replay with GROWING n, served twice —
    through the stock backends (whose programs are keyed on n, so every
    new n is a retrace) and through `elastic:*` (ONE capacity-padded
    program per backend×spec). Measures, per backend, bracketing the
    QUERY calls only:

      compiles   jit-cache growth (`elastic.compiled_program_count`) —
                 the recompile-storm signature; must be 0 for elastic
                 after a single warm-up across ≥ 4 distinct n values
                 (one with a padded final tile);
      swap ms    max first-query-at-new-n latency — the baseline pays
                 the retrace spike here, elastic pays a dynamic-slice
                 repad (microseconds of XLA op-cache, no XLA program);
      p50/p99    steady-state reps at each n, first query excluded.

    Hard gates (raise, so CI goes red): elastic compiles == 0, and f32
    selected indices bitwise equal to the same-n stock backend at every
    n — the bit-identity half of the PR-7 acceptance criteria.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import backends as BK
    from repro.core import elastic as EL
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings

    tile = EL.default_tile()
    d, B, k, c, reps = 64, 16, 10, 2.0, 12
    if smoke:
        m = 512
        ns = (2 * tile + 40, 2 * tile + 90, 2 * tile + 210, 4 * tile - 6)
    else:
        m = 2_048
        ns = (18 * tile + 40, 20 * tile + 8, 24 * tile - 30, 32 * tile - 8)
    cap = EL.capacity_for(ns[-1], tile)
    assert all(EL.capacity_for(n, tile) == cap for n in ns)  # one bucket
    cfg = RankTableConfig(tau=64, omega=8, s=32)
    users, items = synthetic_embeddings(jax.random.PRNGKey(0), ns[-1], m, d)
    qs = items[:B] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), (B, d), jnp.float32))
    # one build at max n, served at every n via take_rows — exactly what
    # the epoch-versioned engine's hot-swap publishes
    rt = BK.get_backend("dense").build_index(users, items, cfg,
                                             jax.random.PRNGKey(1))
    entry = {"config": {"d": d, "tile": tile, "capacity": cap, "B": B,
                        "k": k, "c": c, "m": m, "reps": reps,
                        "ns": list(ns), "smoke": smoke},
             "backends": {}, "acceptance": {}}
    METRICS.setdefault("updates", {})["compile_storm"] = entry
    print(f"\ncompile-storm churn replay: growing n over {list(ns)} "
          f"(tile={tile}, cap={cap}), d={d} B={B} k={k} reps={reps}")
    print(f"{'backend':>14s} {'compiles':>8s} {'swap ms':>8s} "
          f"{'p50 ms':>7s} {'p99 ms':>7s}")

    indices = {}                                # (backend, n) -> selected
    for name in ("dense", "elastic:dense", "fused", "elastic:fused"):
        bk = BK.get_backend(name)

        def q(n, bk=bk):
            return bk.query_batch(rt.take_rows(jnp.arange(n)), users[:n],
                                  qs, k=k, c=c)

        jax.block_until_ready(q(ns[0]).indices)          # warm-up trace
        programs0 = EL.compiled_program_count()
        steady, swap = [], []
        for n in ns:
            for r in range(reps):
                t0 = time.perf_counter()
                res = q(n)
                jax.block_until_ready(res.indices)
                (swap if r == 0 else steady).append(
                    (time.perf_counter() - t0) * 1e3)
            indices[(name, n)] = np.asarray(res.indices)
        compiles = EL.compiled_program_count() - programs0
        row = {"compiles": int(compiles),
               "max_first_query_ms": float(np.max(swap)),
               "p50_ms": float(np.percentile(steady, 50)),
               "p99_ms": float(np.percentile(steady, 99))}
        entry["backends"][name] = row
        print(f"{name:>14s} {row['compiles']:8d} "
              f"{row['max_first_query_ms']:8.2f} {row['p50_ms']:7.2f} "
              f"{row['p99_ms']:7.2f}")

    for inner in ("dense", "fused"):
        el = entry["backends"][f"elastic:{inner}"]
        base = entry["backends"][inner]
        # hard gate 1: one program serves the whole sweep
        assert el["compiles"] == 0, (
            f"elastic:{inner} compiled {el['compiles']} programs across "
            f"the n-sweep — the compile-once contract is broken")
        entry["acceptance"][f"elastic_{inner}_zero_compiles"] = True
        # hard gate 2: f32 bit-identity at every n
        for n in ns:
            np.testing.assert_array_equal(
                indices[(f"elastic:{inner}", n)], indices[(inner, n)],
                err_msg=f"elastic:{inner} selection differs at n={n}")
        entry["acceptance"][f"elastic_{inner}_bitwise_f32"] = True
        # soft gate (informational in smoke, recorded in full): the swap
        # spike — elastic's worst first-query should beat the baseline's
        # retrace stall
        flatter = el["max_first_query_ms"] < base["max_first_query_ms"]
        spike = base["max_first_query_ms"] / max(el["max_first_query_ms"],
                                                 1e-9)
        if not smoke:
            entry["acceptance"][f"elastic_{inner}_swap_flatter"] = flatter
        print(f"{inner}: elastic 0 compiles + bitwise f32: PASS; swap "
              f"spike {base['max_first_query_ms']:.2f} → "
              f"{el['max_first_query_ms']:.2f} ms "
              f"({spike:.1f}× flatter): "
              f"{'PASS' if flatter else 'FAIL'}"
              f"{' [smoke: informational]' if smoke else ''}")


def updates_mode(smoke: bool = False):
    """Acceptance: at a 5% insert delta, delta-path B=16 latency ≤ 1.3×
    static on dense AND fused, and delta-path rank quality (overall ratio
    vs the exact oracle on the merged item set) within the slack of a
    from-scratch rebuild's. Always followed by the PR-7 compile-storm
    churn replay (`_compile_storm_replay`); `--smoke` runs ONLY the
    replay at CI sizes (the delta-quality sweep needs the O(nmd) oracle).
    """
    import dataclasses as dc

    if smoke:
        _compile_storm_replay(smoke=True)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import timeit
    from repro.configs.paper_engine import DEFAULT_TABLE
    from repro.core import ReverseKRanksEngine, metrics
    from repro.core.exact import exact_ranks, reverse_k_ranks
    from repro.data.pipeline import synthetic_embeddings

    n, m, d, B, k, c = 8_192, 2_048, 128, 16, 10, 2.0
    slack = 0.10                    # configured error slack vs the rebuild
    cfg = dc.replace(DEFAULT_TABLE)             # paper_engine table config
    users, items = synthetic_embeddings(jax.random.PRNGKey(0), n, m, d)
    qs = items[:B] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), (B, d), jnp.float32))
    print(f"dynamic-index sweep: n={n:,} m={m:,} d={d} tau={cfg.tau} "
          f"omega={cfg.omega} s={cfg.s}  B={B} k={k} c={c} slack={slack}")
    print(f"{'backend':7s} {'delta':>6s} {'static ms/q':>11s} "
          f"{'delta ms/q':>10s} {'ratio':>6s} {'ratio_delta':>11s} "
          f"{'ratio_rebuild':>13s}")

    checks = []
    for backend in ("dense", "fused"):
        eng0 = ReverseKRanksEngine.build(users, items, cfg,
                                         jax.random.PRNGKey(1),
                                         backend=backend)
        t_static = timeit(lambda Q: eng0.query_batch(Q, k=k, c=c).indices,
                          qs, iters=3) / B
        for frac in (0.01, 0.05, 0.10):
            eng = ReverseKRanksEngine.build(users, items, cfg,
                                            jax.random.PRNGKey(1),
                                            backend=backend)
            n_add = int(round(frac * m))
            _, new_items = synthetic_embeddings(
                jax.random.PRNGKey(100 + n_add), 1, n_add, d)
            eng.insert_items(new_items)
            t_delta = timeit(lambda Q: eng.query_batch(Q, k=k,
                                                       c=c).indices,
                             qs, iters=3) / B
            ratio = t_delta / t_static
            quality = ""
            if frac == 0.05:
                merged = eng.live_items()
                delta_res = eng.query_batch(qs, k=k, c=c)
                scratch = ReverseKRanksEngine.build(users, merged, cfg,
                                                    jax.random.PRNGKey(1),
                                                    backend=backend)
                reb_res = scratch.query_batch(qs, k=k, c=c)
                r_d, r_r = [], []
                for i in range(8):       # exact oracle is O(nmd)/query
                    truth = np.asarray(exact_ranks(users, merged, qs[i]))
                    ex_idx, _ = reverse_k_ranks(users, merged, qs[i], k)
                    r_d.append(metrics.overall_ratio(
                        np.asarray(delta_res.indices[i]),
                        np.asarray(ex_idx), truth))
                    r_r.append(metrics.overall_ratio(
                        np.asarray(reb_res.indices[i]),
                        np.asarray(ex_idx), truth))
                rd, rr = float(np.mean(r_d)), float(np.mean(r_r))
                quality = f" {rd:11.4f} {rr:13.4f}"
                ok_lat = ratio <= 1.3
                ok_q = rd <= rr * (1.0 + slack)
                checks.append((backend, ok_lat, ok_q, ratio, rd, rr))
            print(f"{backend:7s} {frac:6.2f} {t_static*1e3:11.3f} "
                  f"{t_delta*1e3:10.3f} {ratio:6.2f}{quality}")
            METRICS.setdefault("updates", {})[
                f"{backend}_delta{frac}"] = {
                "static_ms_per_q": t_static * 1e3,
                "delta_ms_per_q": t_delta * 1e3, "latency_ratio": ratio}

    # rebuild cadence: full Algorithm 1 + hot swap on the mutated engine
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    _, new_items = synthetic_embeddings(jax.random.PRNGKey(5), 1,
                                        int(0.05 * m), d)
    eng.insert_items(new_items)
    rec = eng.rebuild(reason="cadence probe")
    print(f"rebuild cadence: build {rec.build_s:.2f}s + swap "
          f"{rec.swap_s*1e3:.1f}ms ({rec.stats})")
    for backend, ok_lat, ok_q, ratio, rd, rr in checks:
        print(f"{backend}: delta@5% latency ≤1.3× static: "
              f"{'PASS' if ok_lat else 'FAIL'} ({ratio:.2f}×); "
              f"overall-ratio within {slack:.0%} of rebuild: "
              f"{'PASS' if ok_q else 'FAIL'} ({rd:.4f} vs {rr:.4f})")

    _compile_storm_replay(smoke=False)


from benchmarks.common import zipf_clustered  # noqa: F401  (moved to
# common for the regime axis; re-exported for existing imports)


def pruned_mode(smoke: bool = False, regime: str = "clustered"):
    """Acceptance (PR 4 + PR 6): `"pruned:dense"` ≥ 2.2× over the dense
    full scan at n = 256k on the clustered regime for k ≤ 16 and ≥ 1.5×
    on the shuffled-mixture `mid` regime (where it needs the PR 6
    build-time k-means reorder + cone sketches to engage at all);
    ≤ 1.1× overhead on the i.i.d. adversarial case (phase A keeps
    everything and the fallback dispatches the inner backend);
    bit-identical selected indices on every measured batch, with
    reordered layouts additionally answering in pre-remap user
    coordinates through the snapshot's composed `user_remap`."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import make_regime, timeit
    from repro.core import ReverseKRanksEngine, pruning
    from repro.core.types import RankTableConfig

    d, tau, B, c = 64, 128, 16, 2.0
    sizes = (8_192, 16_384) if smoke else (65_536, 262_144)
    m = 2_048 if smoke else 4_096
    # mid/iid row orders carry no block structure: the pruned engine
    # gets the PR 6 k-means layout (clustered is ALREADY tile-coherent —
    # measuring it unreordered pins no-regression vs BENCH_PR4)
    reorder = regime in ("mid", "iid")
    thresholds = {"clustered": 2.2, "mid": 1.5}
    cfg = RankTableConfig(tau=tau, omega=8, s=32)
    entry = {"config": {"d": d, "tau": tau, "B": B, "c": c, "m": m,
                        "smoke": smoke, "regime": regime,
                        "reordered": reorder},
             "sweep": {}, "adversarial": {}, "acceptance": {}}
    METRICS[f"pruned_{regime}" if regime != "clustered" else "pruned"] = \
        entry
    print(f"block-pruned sweep [{regime}]: d={d} tau={tau} B={B} c={c} "
          f"m={m:,} reorder={reorder}")
    print(f"{'n':>8s} {'k':>3s} {'dense ms/q':>10s} {'pruned ms/q':>11s} "
          f"{'speedup':>7s} {'skip%':>6s} {'perq%':>6s}")

    checks = []
    for n in sizes:
        users, items, icl = make_regime(regime, jax.random.PRNGKey(0),
                                        n, m, d)
        dense = ReverseKRanksEngine.build(users, items, cfg,
                                          jax.random.PRNGKey(1))
        rt = dense.rank_table
        if reorder:
            # the engine's build(cluster_reorder=True) path permutes
            # rows then rebuilds; here the dense engine's table is
            # REUSED via take_rows (definitionally the permuted table),
            # so cross-layout parity below is a pure permutation check
            perm = pruning.kmeans_layout(users)
            remap = np.full(n, -1, np.int64)
            remap[perm] = np.arange(n)
            users_p = jnp.asarray(users)[jnp.asarray(perm)]
            rt_p = rt.take_rows(jnp.asarray(perm))
            pruned = ReverseKRanksEngine(users=users_p, rank_table=rt_p,
                                         config=cfg,
                                         backend="pruned:dense",
                                         user_remap=remap)
            # same-layout unpruned reference for the bit-identity gate
            dense_same = ReverseKRanksEngine(users=users_p, rank_table=rt_p,
                                             config=cfg)
        else:
            pruned = ReverseKRanksEngine(users=users, rank_table=rt,
                                         config=cfg,
                                         backend="pruned:dense")
            dense_same = dense
        # hot-cluster batch: B near-duplicate queries of one PROMOTED
        # item (norm-boosted 1.2×: the new/pushed item whose reverse
        # k-ranks answer is concentrated in its own cluster — what a
        # MicroBatcher tick of a hot item looks like). A generic
        # mid-cluster item has a diffuse answer set and degrades toward
        # the adversarial case. The iid regime has no clusters — use a
        # jittered item batch.
        if icl is not None:
            hot = items[int(np.flatnonzero(icl == 0)[0])] * 1.2
            qs = hot[None, :] * (1.0 + 1e-3 * jax.random.normal(
                jax.random.PRNGKey(7), (B, d), jnp.float32))
        else:
            qs = items[:B] * (1.0 + 1e-4 * jax.random.normal(
                jax.random.PRNGKey(7), (B, d), jnp.float32))
        for k in (8, 16):
            # paired min-of-rounds (see the adversarial note below): the
            # dense side's wall time drifts ±30% with background load,
            # which would flap the acceptance ratio run to run
            t_d, t_p = float("inf"), float("inf")
            for _ in range(3):
                t_d = min(t_d, timeit(lambda Q: dense.query_batch(
                    Q, k=k, c=c).indices, qs, iters=3))
                t_p = min(t_p, timeit(lambda Q: pruned.query_batch(
                    Q, k=k, c=c).indices, qs, iters=3))
            res_p = pruned.query_batch(qs, k=k, c=c)
            got = np.asarray(res_p.indices)
            # hard invariant: bit-identical to the unpruned inner
            # backend on the SAME (possibly reordered) snapshot
            np.testing.assert_array_equal(
                got, np.asarray(dense_same.query_batch(qs, k=k,
                                                       c=c).indices))
            if reorder:
                # and the remap answers in PRE-REORDER coordinates:
                # translated indices equal the original-layout scan's —
                # EXCEPT at genuine selection-key TIES, whose index
                # tie-break is layout-dependent (see tests/
                # test_pruning.py::test_reordered_parity). Ties happen
                # two ways: the sampled grid quantizes est itself, and
                # `lemma1_key` packs est as prio·(m+2)+est, whose f32
                # ulp at ~4100 (≈ 5e-4) collides near-equal ests in the
                # non-guaranteed classes. At every mismatch the packed
                # key must be bitwise tied under one of the three class
                # offsets — interchangeable under the contract.
                snap = pruned.current_snapshot()
                res0 = dense.query_batch(qs, k=k, c=c)
                diff = snap.client_user_ids(got) != np.asarray(res0.indices)
                if diff.any():
                    e_p = np.asarray(res_p.est_rank)[diff]
                    e_0 = np.asarray(res0.est_rank)[diff]
                    big = np.float32(m + 2)
                    tied = ((e_p == e_0)
                            | (big + e_p == big + e_0)
                            | (2 * big + e_p == 2 * big + e_0))
                    assert tied.all(), (
                        f"untied cross-layout mismatch: {e_p[~tied]} vs "
                        f"{e_0[~tied]}")
            st = pruned._backend.stats
            speedup = t_d / t_p
            print(f"{n:8,d} {k:3d} {t_d/B*1e3:10.3f} {t_p/B*1e3:11.3f} "
                  f"{speedup:6.2f}x {st.skip_rate*100:5.1f} "
                  f"{100*(1-st.kept_per_query):5.1f}")
            entry["sweep"][f"n{n}_k{k}"] = {
                "dense_ms_per_q": t_d / B * 1e3,
                "pruned_ms_per_q": t_p / B * 1e3,
                "speedup": speedup, "skip_rate": st.skip_rate,
                "per_query_skip": 1.0 - st.kept_per_query,
                "fallback": st.fallback}
            if n == sizes[-1]:
                checks.append((n, k, speedup))

    # adversarial: i.i.d. users — every block looks alike, phase A keeps
    # everything, the overhead is one tiny coarse pass + the host sync
    n_adv = sizes[0]
    ku, ki = jax.random.split(jax.random.PRNGKey(2))
    users = jax.random.normal(ku, (n_adv, d), jnp.float32)
    items = jax.random.normal(ki, (m, d), jnp.float32)
    dense = ReverseKRanksEngine.build(users, items, cfg,
                                      jax.random.PRNGKey(1))
    pruned = ReverseKRanksEngine(users=users, rank_table=dense.rank_table,
                                 config=cfg, backend="pruned:dense")
    qs = items[:B] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), (B, d), jnp.float32))
    # paired min-of-rounds: the adversarial overhead is ~2% of a run
    # whose wall time drifts ±30% with background load on a shared box —
    # alternating rounds and taking each side's minimum measures the
    # structural overhead, not the drift
    t_d, t_p = float("inf"), float("inf")
    for _ in range(3):
        t_d = min(t_d, timeit(lambda Q: dense.query_batch(
            Q, k=16, c=c).indices, qs, iters=3))
        t_p = min(t_p, timeit(lambda Q: pruned.query_batch(
            Q, k=16, c=c).indices, qs, iters=3))
    np.testing.assert_array_equal(
        np.asarray(pruned.query_batch(qs, k=16, c=c).indices),
        np.asarray(dense.query_batch(qs, k=16, c=c).indices))
    st = pruned._backend.stats
    overhead = t_p / t_d
    print(f"adversarial n={n_adv:,}: dense {t_d/B*1e3:.3f} pruned "
          f"{t_p/B*1e3:.3f} ms/q  overhead {overhead:.3f}x "
          f"(fallback={st.fallback!r}, kept {st.kept_union}/{st.n_blocks})")
    entry["adversarial"] = {
        "n": n_adv, "dense_ms_per_q": t_d / B * 1e3,
        "pruned_ms_per_q": t_p / B * 1e3, "overhead": overhead,
        "fallback": st.fallback}

    ok_adv = overhead <= 1.1
    entry["acceptance"]["adversarial_overhead_le_1.1x"] = ok_adv
    print(f"adversarial overhead ≤ 1.1x: {'PASS' if ok_adv else 'FAIL'} "
          f"({overhead:.3f}x)")
    bar = thresholds.get(regime)       # iid main sweep is informational
    for n, k, speedup in checks:
        if bar is None:
            print(f"n={n:,} k={k}: pruned {speedup:.2f}x dense "
                  f"[{regime}: informational]")
            continue
        if not smoke:
            # smoke sizes are not expected to clear the bar — don't
            # record a failed gate in the CI artifact for an
            # informational number
            entry["acceptance"][f"{regime}_speedup_n{n}_k{k}_ge_{bar}x"] \
                = speedup >= bar
        print(f"n={n:,} k={k} [{regime}]: pruned ≥ {bar}x dense: "
              f"{'PASS' if speedup >= bar else 'FAIL'} ({speedup:.2f}x)"
              f"{' [smoke: informational]' if smoke else ''}")


def quant_mode(smoke: bool = False):
    """Acceptance (PR 5): int8 storage ≥ 1.5× over f32-dense at n = 256k
    (d = 64, τ = 128, B = 16, paired min-of-rounds); bf16/int8 bounds
    certifiably CONTAIN the f32 bounds on every measured batch."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import timeit
    from repro.core import ReverseKRanksEngine, metrics
    from repro.core.exact import exact_ranks, reverse_k_ranks
    from repro.core.types import RankTableConfig

    d, tau, B, k, c = 64, 128, 16, 10, 2.0
    sizes = (16_384,) if smoke else (65_536, 262_144)
    m = 2_048 if smoke else 4_096
    cfg32 = RankTableConfig(tau=tau, omega=8, s=32)
    entry = {"config": {"d": d, "tau": tau, "B": B, "k": k, "c": c, "m": m,
                        "smoke": smoke},
             "sizes": {}, "acceptance": {}}
    METRICS["quant"] = entry
    print(f"storage-spec sweep (dense backend): d={d} tau={tau} B={B} "
          f"k={k} c={c} m={m:,}")
    print(f"{'n':>8s} {'spec':>5s} {'ms/q':>8s} {'speedup':>7s} "
          f"{'index MiB':>9s} {'topk∩f32':>8s} {'contain':>7s} "
          f"{'ratio':>7s}")

    checks = []
    for n in sizes:
        users, items, _ = zipf_clustered(jax.random.PRNGKey(0), n, m, d)
        qs = items[:B] * (1.0 + 1e-4 * jax.random.normal(
            jax.random.PRNGKey(7), (B, d), jnp.float32))
        engines = {}
        for spec in ("f32", "bf16", "int8"):
            cfg = dc.replace(cfg32, storage_dtype=spec)
            engines[spec] = ReverseKRanksEngine.build(
                users, items, cfg, jax.random.PRNGKey(1))
        # paired min-of-rounds: alternate specs within each round so
        # background-load drift hits every spec equally
        times = {s: float("inf") for s in engines}
        for _ in range(3):
            for s, eng in engines.items():
                times[s] = min(times[s], timeit(
                    lambda Q, e=eng: e.query_batch(Q, k=k, c=c).indices,
                    qs, iters=3))
        ref = engines["f32"].query_batch(qs, k=k, c=c)
        # rank quality vs the EXACT oracle at the smallest size (the
        # O(nmd) oracle is affordable there): a hot item's answer set is
        # heavily rank-tied, so top-k overlap with f32 understates
        # quality — overall-ratio is the §5 criterion that matters
        truths = None
        if n == sizes[0]:
            truths = []
            for qi in range(4):
                truth = np.asarray(exact_ranks(users, items, qs[qi]))
                ex_idx, _ = reverse_k_ranks(users, items, qs[qi], k)
                truths.append((qi, truth, np.asarray(ex_idx)))
        for s, eng in engines.items():
            res = eng.query_batch(qs, k=k, c=c)
            contain = bool(
                np.all(np.asarray(res.r_lo) <= np.asarray(ref.r_lo) + 1e-4)
                and np.all(np.asarray(res.r_up)
                           >= np.asarray(ref.r_up) - 1e-4))
            overlap = float(np.mean([
                len(set(np.asarray(res.indices)[b])
                    & set(np.asarray(ref.indices)[b])) / k
                for b in range(B)]))
            ratio = None
            if truths is not None:
                ratio = float(np.mean([metrics.overall_ratio(
                    np.asarray(res.indices[qi]), ex, truth)
                    for qi, truth, ex in truths]))
            speedup = times["f32"] / times[s]
            mib = eng.memory_bytes() / 2**20
            rtxt = "      -" if ratio is None else f"{ratio:7.3f}"
            print(f"{n:8,d} {s:>5s} {times[s]/B*1e3:8.3f} {speedup:6.2f}x "
                  f"{mib:9.1f} {overlap:8.2f} {str(contain):>7s} {rtxt}")
            entry["sizes"][f"n{n}_{s}"] = {
                "ms_per_q": times[s] / B * 1e3, "speedup_vs_f32": speedup,
                "index_mib": mib, "topk_overlap_f32": overlap,
                "containment": contain, "overall_ratio": ratio}
            if s != "f32":
                assert contain, f"containment violated for {s} at n={n}"
            if s == "int8" and n == sizes[-1]:
                checks.append((n, speedup))

    for n, speedup in checks:
        ok = speedup >= 1.5
        if not smoke:
            entry["acceptance"][f"int8_speedup_n{n}_ge_1.5x"] = ok
        print(f"n={n:,}: int8 ≥ 1.5x f32-dense: "
              f"{'PASS' if ok else 'FAIL'} ({speedup:.2f}x)"
              f"{' [smoke: informational]' if smoke else ''}")


def faults_mode(smoke: bool = False):
    """Acceptance (PR 9): availability under a seeded chaos plan.

    The plan injects (deterministically — same seed, same failures):
      index.rebuild   raise, max_fires=2 — the first two Algorithm-1
                      rebuilds die; the maintenance loop must back off,
                      keep serving the old snapshot, and recover on the
                      third attempt (consecutive-failures gauge → 0);
      serve.dispatch  raise, max_fires=1 after 2 ticks — one whole tick
                      fails; its futures must resolve with the TYPED
                      `InjectedFault`, never hang or return garbage;
      serve.slow_tick sleep, rate 0.05, 30 ms — random dispatch latency
                      (deadline pressure without offered load).

    Hard gates (assert, so CI goes red): zero pending futures after
    close; ≥ 99% of resolved requests within their deadline; r↓ ≤ r↑ on
    every resolved result; both rebuild failures actually injected and
    recovered from without a restart.
    """
    import time

    import jax
    import numpy as np
    from repro.core import ReverseKRanksEngine
    from repro.core.types import RankTableConfig
    from repro.data.pipeline import synthetic_embeddings
    from repro.index import MaintenanceLoop, MaintenancePolicy
    from repro.serve import (DeadlineExceeded, MicroBatcher, QueueFull,
                             SchedulerClosed, faults)

    n, m, d = (2_048, 512, 32) if smoke else (8_192, 2_048, 64)
    n_queries, max_batch, k, c = (256 if smoke else 1_024), 16, 10, 2.0
    # generous budget: the gate is the ACCOUNTING (shed vs late vs
    # faulted), not raw speed — tight-deadline shedding semantics are
    # pinned by tests/test_faults.py; here one mid-run delta-shape
    # retrace must not masquerade as an availability miss
    deadline_ms = 5_000.0
    cfg = RankTableConfig(tau=32 if smoke else 64, omega=8, s=32)
    users, items = synthetic_embeddings(jax.random.PRNGKey(0), n, m, d)
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    # warm the static-path program before chaos starts: compile time is
    # not an availability event
    jax.block_until_ready(
        eng.query_batch(items[:max_batch], k=k, c=c).indices)

    plan = faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("index.rebuild", mode="raise", max_fires=2),
        faults.FaultRule("serve.dispatch", mode="raise", max_fires=1,
                         after=2),
        faults.FaultRule("serve.slow_tick", mode="sleep", rate=0.05,
                         latency_ms=30.0),
    ]))
    print(f"chaos run: n={n:,} m={m:,} d={d} queries={n_queries} "
          f"max_batch={max_batch} deadline={deadline_ms:.0f} ms  "
          f"plan seed={plan.seed} sites={sorted(plan.rules)}")

    _, new_items = synthetic_embeddings(jax.random.PRNGKey(5), 1,
                                        max(1, int(0.05 * m)), d)
    futs, done_at = [], {}
    try:
        with MaintenanceLoop(
                eng, policy=MaintenancePolicy(max_delta_ratio=0.02,
                                              min_interval_s=0.0),
                poll_ms=10.0, failure_backoff_s=0.05,
                max_backoff_s=0.1) as ml, \
                MicroBatcher(eng, max_batch=max_batch,
                             max_wait_ms=2.0) as mb:
            waves = 8
            for w in range(waves):
                if w == 2:
                    # cross the rebuild threshold MID-SERVE: the loop's
                    # first two attempts die on the injected fault while
                    # queries keep resolving against the old snapshot
                    eng.insert_items(new_items)
                    ml.wake()
                for _ in range(n_queries // waves):
                    i = len(futs)
                    t_sub = time.monotonic()
                    f = mb.submit(items[i % m], k, c,
                                  deadline_ms=deadline_ms)
                    # resolution time from the dispatcher's set_result,
                    # not from when this thread gets around to .result()
                    f.add_done_callback(
                        lambda fut, i=i: done_at.__setitem__(
                            i, time.monotonic()))
                    futs.append((t_sub, f))
                time.sleep(0.01)
            resolved, shed, faulted, late = 0, 0, 0, 0
            bounds_ok = True
            for i, (t_sub, f) in enumerate(futs):
                try:
                    r = f.result(timeout=60)
                except faults.InjectedFault:
                    faulted += 1            # typed — never a torn future
                except (QueueFull, DeadlineExceeded, SchedulerClosed):
                    shed += 1               # typed back-pressure/deadline
                else:
                    resolved += 1
                    if (done_at[i] - t_sub) * 1e3 > deadline_ms:
                        late += 1
                    bounds_ok &= bool(np.all(np.asarray(r.r_lo)
                                             <= np.asarray(r.r_up)))
            # recovery: gauge back to 0 without a restart, bounded wait
            t0 = time.monotonic()
            while time.monotonic() - t0 < 30.0 and not (
                    ml.rebuilds and ml.consecutive_failures == 0):
                ml.wake()
                time.sleep(0.05)
            st = mb.stats()
            rebuilds, failures = len(ml.rebuilds), len(ml.failures)
            consec = ml.consecutive_failures
        pending = sum(not f.done() for _, f in futs)
    finally:
        faults.clear()

    on_time_frac = 1.0 if resolved == 0 else 1.0 - late / resolved
    print(f"requests: {len(futs)} submitted  {resolved} resolved  "
          f"{shed} shed  {faulted} faulted (typed)  {late} late")
    print(f"scheduler: {st}")
    print(f"maintenance: {failures} injected failure(s), {rebuilds} "
          f"rebuild(s), consecutive_failures={consec} at end")
    print(f"fires: {({s: plan.fires[s] for s in sorted(plan.fires)})}")
    entry = {
        "config": {"n": n, "m": m, "d": d, "queries": n_queries,
                   "max_batch": max_batch, "k": k, "c": c,
                   "deadline_ms": deadline_ms, "smoke": smoke},
        "plan": {"seed": plan.seed,
                 "rules": {s: dataclasses.asdict(r)
                           for s, r in plan.rules.items()},
                 "evaluations": dict(plan.evaluations),
                 "fires": dict(plan.fires)},
        "requests": {"submitted": len(futs), "resolved": resolved,
                     "shed": shed, "faulted": faulted, "late": late,
                     "on_time_frac": on_time_frac, "p50_ms": st.p50_ms,
                     "p99_ms": st.p99_ms},
        "maintenance": {"rebuilds": rebuilds, "failures": failures,
                        "consecutive_failures_end": consec},
        "acceptance": {},
    }
    METRICS["faults"] = entry
    checks = [
        ("no_torn_futures", pending == 0,
         f"{pending} futures still pending after close()"),
        ("faults_surface_typed", faulted >= 1,
         "the injected dispatch fault never surfaced as InjectedFault"),
        ("rebuild_faults_injected", plan.fires["index.rebuild"] == 2,
         f"expected 2 injected rebuild failures, got "
         f"{plan.fires['index.rebuild']}"),
        ("maintenance_recovered",
         rebuilds >= 1 and failures >= 2 and consec == 0,
         f"maintenance did not recover without restart (rebuilds="
         f"{rebuilds}, failures={failures}, consecutive={consec})"),
        ("on_time_ge_0.99", resolved > 0 and on_time_frac >= 0.99,
         f"on-time fraction {on_time_frac:.4f} < 0.99 "
         f"({late}/{resolved} late)"),
        ("bounds_certified", bounds_ok,
         "a resolved result violated r_lo <= r_up"),
    ]
    for name, ok, _ in checks:
        entry["acceptance"][name] = bool(ok)
        print(f"{name}: {'PASS' if ok else 'FAIL'}")
    bad = [msg for _, ok, msg in checks if not ok]
    assert not bad, "; ".join(bad)


def _provenance() -> dict:
    """What produced this artifact: BENCH_PR*.json files are compared
    across machines and months, so every artifact records the software
    stack, the accelerator, the REPRO_* env knobs that change kernel
    behavior, and the exact source revision. Every field degrades to
    None rather than failing the dump."""
    import os
    import subprocess

    prov: dict = {"jax": None, "jaxlib": None, "device_kind": None,
                  "device_count": None, "git_sha": None,
                  "env": {k: v for k, v in sorted(os.environ.items())
                          if k.startswith("REPRO_")}}
    try:
        import jax
        prov["jax"] = jax.__version__
        try:
            import jaxlib
            prov["jaxlib"] = jaxlib.__version__
        except Exception:
            pass
        devs = jax.devices()
        prov["device_kind"] = devs[0].device_kind if devs else None
        prov["device_count"] = len(devs)
    except Exception:
        pass
    try:
        prov["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        pass
    return prov


def _dump_json(path: str) -> None:
    import json
    import platform
    import time

    payload = {
        "schema": "perf_engine/1",
        "pr": 10,
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "provenance": _provenance(),
        "unix_time": int(time.time()),
        "modes": METRICS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"metrics written to {path}")

    # the serving registry's final state, as a sibling artifact (CI
    # uploads it next to the bench JSON; separate file so bench diffing
    # stays scoped to `modes`)
    from repro.obs import registry as obs
    mpath = (path[:-5] if path.endswith(".json") else path) + "_metrics.json"
    with open(mpath, "w") as f:
        json.dump({"unix_time": int(time.time()),
                   "metrics": obs.get_default().snapshot()},
                  f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    print(f"registry snapshot written to {mpath}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--quality", action="store_true")
    ap.add_argument("--batched", action="store_true")
    ap.add_argument("--serve", action="store_true")
    ap.add_argument("--saturate", action="store_true",
                    help="with --serve: PR-10 offered-load ramp locating "
                         "the throughput knee (p99 > 2×p50) per "
                         "pipeline_depth arm")
    ap.add_argument("--updates", action="store_true")
    ap.add_argument("--pruned", action="store_true")
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--faults", action="store_true",
                    help="PR-9 availability run under a seeded fault plan")
    ap.add_argument("--regime", choices=("clustered", "iid", "mid"),
                    default="clustered",
                    help="user-distribution regime for --pruned "
                         "(mid/iid apply the k-means row reorder)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problems (informational speedups)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump every executed mode's metrics as JSON")
    args = ap.parse_args()
    if args.roofline:
        roofline_mode()
    if args.quality:
        quality_mode()
    if args.batched:
        batched_mode()
    if args.serve:
        if args.saturate:
            saturate_mode(smoke=args.smoke)
        else:
            serve_mode()
    if args.updates:
        updates_mode(smoke=args.smoke)
    if args.pruned:
        pruned_mode(smoke=args.smoke, regime=args.regime)
    if args.quant:
        quant_mode(smoke=args.smoke)
    if args.faults:
        faults_mode(smoke=args.smoke)
    if args.json:
        _dump_json(args.json)
