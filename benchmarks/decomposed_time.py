"""Table 3 — decomposed query time: step 1 (u·q + bound lookup), step 2
(R↓_k/R↑_k + Lemma-1 masks), step 3 (selection fill). The paper's claim —
step 1 dominates, steps 2-3 are negligible — is the invariant checked
here. Steps are jitted separately, so boundaries are coarser than the
paper's C++ timers but the ordering is the same."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import BENCH_DATASETS, csv_row, load, timeit
from repro.core.query import lookup_bounds, select_topk
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig, kth_smallest

K, C = 10, 2.0


def run(quick: bool = False) -> list[str]:
    rows = []
    datasets = BENCH_DATASETS[:1] if quick else BENCH_DATASETS
    for ds in datasets:
        users, items = load(ds)
        cfg = RankTableConfig(tau=500, omega=10, s=64)
        rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(0))
        q = items[3]

        @jax.jit
        def step1(qq):
            uq = (users @ qq).astype(jnp.float32)
            return lookup_bounds(rt, uq)

        r_lo, r_up, est = step1(q)

        @jax.jit
        def step2(r_lo, r_up):
            Rl, Ru = kth_smallest(r_lo, K), kth_smallest(r_up, K)
            return Rl, Ru, r_up <= C * Rl, r_lo > Ru

        @jax.jit
        def step3(r_lo, r_up, est):
            return select_topk(r_lo, r_up, est, k=K, c=C,
                               m_items=rt.m).indices

        t1 = timeit(step1, q)
        t2 = timeit(step2, r_lo, r_up)
        t3 = timeit(step3, r_lo, r_up, est)
        rows.append(csv_row(f"table3/{ds.name}/step1", t1 * 1e6,
                            f"sec={t1:.2e}"))
        rows.append(csv_row(f"table3/{ds.name}/step2", t2 * 1e6,
                            f"sec={t2:.2e}"))
        rows.append(csv_row(f"table3/{ds.name}/step3", t3 * 1e6,
                            f"sec={t3:.2e};step1_share="
                            f"{t1/(t1+t2+t3):.2f}"))
    return rows


if __name__ == "__main__":
    run()
