"""Figure 3 — impact of k: time / accuracy / overall ratio for Ours vs
QSRP with k ∈ {10..50}, c = 2."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BENCH_DATASETS, csv_row, load, timeit
from repro.core import ReverseKRanksEngine, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.qsrp import build_qsrp_index, qsrp_query
from repro.core.types import RankTableConfig

C = 2.0
KS = (10, 20, 30, 40, 50)
N_EVAL = 6


def run(quick: bool = False) -> list[str]:
    rows = []
    datasets = BENCH_DATASETS[:1] if quick else BENCH_DATASETS[:2]
    ks = KS[:2] if quick else KS
    for ds in datasets:
        users, items = load(ds)
        cfg = RankTableConfig(tau=500, omega=10, s=64)
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1))
        qsrp_idx = build_qsrp_index(users, items, levels=1000)
        for k in ks:
            accs, ratios, qaccs = [], [], []
            t_q = timeit(lambda qq: eng.query(qq, k=k, c=C).indices,
                         items[11], iters=3)
            t_qsrp_tot = 0.0
            for qi in range(N_EVAL):
                q = items[qi * 53]
                truth = np.asarray(exact_ranks(users, items, q))
                ex_idx, _ = reverse_k_ranks(users, items, q, k)
                r = eng.query(q, k=k, c=C)
                accs.append(metrics.accuracy(np.asarray(r.indices),
                                             np.asarray(ex_idx), truth, C))
                ratios.append(metrics.overall_ratio(
                    np.asarray(r.indices), np.asarray(ex_idx), truth))
                t0 = time.perf_counter()
                gq, _, _ = qsrp_query(qsrp_idx, users, items, q, k, C)
                t_qsrp_tot += time.perf_counter() - t0
                qaccs.append(metrics.accuracy(gq, np.asarray(ex_idx),
                                              truth, C))
            rows.append(csv_row(
                f"fig3/{ds.name}/k{k}/ours", t_q * 1e6,
                f"acc={np.mean(accs):.3f};ratio={np.mean(ratios):.3f}"))
            rows.append(csv_row(
                f"fig3/{ds.name}/k{k}/qsrp", t_qsrp_tot / N_EVAL * 1e6,
                f"acc={np.mean(qaccs):.3f};"
                f"speedup={t_qsrp_tot/N_EVAL/max(t_q,1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    run()
