"""Table 2 — pre-processing time: Ours (Algorithm 1) vs QSRP's all-pairs
summarization, per dataset replica. The paper's headline asymmetry
(O((n+m)d + m log m) vs Ω(nmd)) shows directly at reduced scale."""
from __future__ import annotations

import time

import jax

from benchmarks.common import BENCH_DATASETS, csv_row, load
from repro.core.qsrp import build_qsrp_index
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig


def run(quick: bool = False) -> list[str]:
    rows = []
    datasets = BENCH_DATASETS[:1] if quick else BENCH_DATASETS
    for ds in datasets:
        users, items = load(ds)
        cfg = RankTableConfig(tau=500, omega=10, s=64)

        t0 = time.perf_counter()
        rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(0))
        jax.block_until_ready(rt.table)
        ours = time.perf_counter() - t0

        t0 = time.perf_counter()
        qi = build_qsrp_index(users, items, levels=1000,
                              block=512 if quick else 1024)
        jax.block_until_ready(qi.quantile_scores)
        qsrp = time.perf_counter() - t0

        rows.append(csv_row(f"table2/{ds.name}/ours", ours * 1e6,
                            f"seconds={ours:.3f}"))
        rows.append(csv_row(f"table2/{ds.name}/qsrp", qsrp * 1e6,
                            f"seconds={qsrp:.3f};speedup={qsrp/ours:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
