"""Roofline table generator: reads the dry-run JSON artifacts and emits the
§Roofline markdown table (per arch × shape × mesh: three terms, dominant
bottleneck, MODEL_FLOPS ratio).

``PYTHONPATH=src python -m benchmarks.roofline_report \
      experiments/dryrun_baseline.json [--md]``
"""
from __future__ import annotations

import argparse
import json


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def rows_from(records: list[dict]) -> list[dict]:
    out = []
    for r in records:
        if r.get("status") == "SKIP":
            out.append({"arch": r["arch"], "cell": r["cell"],
                        "mesh": r["mesh"], "skip": r["reason"]})
            continue
        if r.get("status") != "OK":
            out.append({"arch": r["arch"], "cell": r["cell"],
                        "mesh": r.get("mesh", "?"),
                        "skip": f"FAIL {r.get('error', '')[:60]}"})
            continue
        roof = r["roofline"]
        out.append({
            "arch": r["arch"], "cell": r["cell"], "mesh": r["mesh"],
            "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"],
            "bottleneck": roof["bottleneck"],
            "useful": roof.get("useful_ratio"),
            "hbm_gib": r.get("arg_bytes", 0) / 2**30,
            "coll_gib": roof["coll_bytes"] / 2**30,
        })
    return out


def to_markdown(rows: list[dict]) -> str:
    lines = [
        "| arch | cell | mesh | compute | memory | collective | bound |"
        " useful (6ND/HLO) | args GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
                         f"SKIP — {r['skip']} | | | | | |")
            continue
        useful = f"{r['useful']:.2f}" if r.get("useful") else "—"
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['bottleneck']}** | "
            f"{useful} | {r['hbm_gib']:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    with open(args.json_path) as f:
        records = json.load(f)
    rows = rows_from(records)
    if args.md:
        print(to_markdown(rows))
        return
    for r in rows:
        if "skip" in r:
            print(f"{r['arch']:28s} {r['cell']:12s} {r['mesh']:8s} SKIP "
                  f"({r['skip'][:50]})")
        else:
            print(f"{r['arch']:28s} {r['cell']:12s} {r['mesh']:8s} "
                  f"c={fmt_s(r['compute_s']):>9s} m={fmt_s(r['memory_s']):>9s}"
                  f" x={fmt_s(r['collective_s']):>9s} → {r['bottleneck']}")


if __name__ == "__main__":
    main()
