"""Checkpoint substrate: atomicity, LATEST pointer, pruning, dtype/shape
validation, torn-writer behavior."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": [jnp.ones((2,)), jnp.zeros((3, 3))]},
            "scalar": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, metadata={"note": "x"})
    restored, step, meta = ckpt.restore(str(tmp_path), jax.eval_shape(
        lambda: t))
    assert step == 7 and meta == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    ckpt.prune_old(str(tmp_path), keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    _, step, _ = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 5


def test_restore_specific_step(tmp_path):
    ckpt.save(str(tmp_path), 1, {"v": jnp.asarray(1.0)})
    ckpt.save(str(tmp_path), 2, {"v": jnp.asarray(2.0)})
    restored, step, _ = ckpt.restore(
        str(tmp_path), {"v": jnp.asarray(0.0)}, step=1)
    assert step == 1 and float(restored["v"]) == 1.0


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"v": jnp.ones((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), {"v": jnp.ones((5,))})


def test_missing_leaf_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, {"v": jnp.ones((4,))})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), {"v": jnp.ones((4,)),
                                     "w": jnp.ones((1,))})


def test_torn_writer_leaves_no_partial_step(tmp_path):
    """A crashed writer (simulated tmp dir) must be invisible to readers."""
    ckpt.save(str(tmp_path), 1, {"v": jnp.asarray(1.0)})
    os.makedirs(tmp_path / ".tmp_step_9_dead")      # torn write remains
    assert ckpt.latest_step(str(tmp_path)) == 1
    _, step, _ = ckpt.restore(str(tmp_path), {"v": jnp.asarray(0.0)})
    assert step == 1


def test_empty_dir(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), {"v": jnp.asarray(0.0)})
