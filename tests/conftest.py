"""Shared test fixtures. NOTE: no XLA_FLAGS device forcing here — smoke
tests and benches must see the single real CPU device. Multi-device tests
run in subprocesses (see tests/dist/)."""
import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_problem(key, n, m, d, norm_spread=0.3, dtype="float32"):
    """Random (users, items) with Gaussian norms per Fig. 2 of the paper."""
    import jax.numpy as jnp
    ku, ki, ks = jax.random.split(key, 3)
    users = jax.random.normal(ku, (n, d), dtype=jnp.float32)
    scale = 1.0 + norm_spread * jax.random.normal(ks, (m, 1), jnp.float32)
    items = jax.random.normal(ki, (m, d), jnp.float32) * jnp.abs(scale)
    return users.astype(dtype), items.astype(dtype)


@pytest.fixture
def small_problem():
    return make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)


@pytest.fixture
def medium_problem():
    return make_problem(jax.random.PRNGKey(7), n=2048, m=1024, d=32)
