"""Algorithm 1 tests: stratified sampling, threshold grids, Eq. (1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra — `pip install repro[test]` (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.rank_table import (build_rank_table, estimate_table_rows,
                                   sort_items_by_norm,
                                   stratified_sample_indices, threshold_grid)
from repro.core.types import RankTableConfig, partition_sizes
from tests.conftest import make_problem


if given is not None:
    @given(m=st.integers(1, 10_000), omega=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_partition_sizes_cover_and_balance(m, omega):
        sizes = partition_sizes(m, omega)
        assert sum(sizes) == m
        assert len(sizes) == omega
        assert max(sizes) - min(sizes) <= 1


else:
    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_partition_sizes_cover_and_balance():
        pass

def test_stratified_samples_stay_in_their_bucket():
    cfg = RankTableConfig(tau=10, omega=4, s=8)
    m = 103
    pos, w = stratified_sample_indices(jax.random.PRNGKey(0), m, cfg)
    sizes = partition_sizes(m, cfg.omega)
    starts = np.cumsum([0] + list(sizes))
    pos, w = np.asarray(pos), np.asarray(w)
    for l in range(cfg.omega):
        sl = pos[l * cfg.s:(l + 1) * cfg.s]
        assert np.all((sl >= starts[l]) & (sl < starts[l + 1]))
        # Eq. (1) stratum weight |P_l| / s
        np.testing.assert_allclose(w[l * cfg.s:(l + 1) * cfg.s],
                                   sizes[l] / cfg.s)
        # without replacement: all distinct (s=8 <= bucket sizes ~25)
        assert len(set(sl.tolist())) == cfg.s


def test_threshold_grid_uniform_and_ascending():
    smin = jnp.array([0.0, -2.0])
    smax = jnp.array([1.0, 2.0])
    t = np.asarray(threshold_grid(smin, smax, 5))
    np.testing.assert_allclose(t[0], [0, 0.25, 0.5, 0.75, 1.0], atol=1e-6)
    np.testing.assert_allclose(t[1], [-2, -1, 0, 1, 2], atol=1e-6)


def test_estimate_table_rows_matches_naive_loop():
    rng = np.random.default_rng(1)
    n, ns, tau = 5, 40, 7
    scores = rng.normal(size=(n, ns)).astype(np.float32)
    weights = rng.uniform(0.5, 2.0, size=(ns,)).astype(np.float32)
    thresholds = np.sort(rng.normal(size=(n, tau)).astype(np.float32), axis=1)
    got = np.asarray(estimate_table_rows(jnp.asarray(scores),
                                         jnp.asarray(weights),
                                         jnp.asarray(thresholds)))
    want = np.zeros((n, tau), np.float64)
    for i in range(n):
        for j in range(tau):
            want[i, j] = 1 + weights[(scores[i] > thresholds[i, j])].sum()
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_sort_items_by_norm_descending(small_problem):
    _, items = small_problem
    items_sorted, order = sort_items_by_norm(items)
    norms = np.linalg.norm(np.asarray(items_sorted), axis=1)
    assert np.all(np.diff(norms) <= 1e-5)
    np.testing.assert_allclose(np.asarray(items)[np.asarray(order)],
                               np.asarray(items_sorted))


def test_table_rows_non_increasing(medium_problem):
    users, items = medium_problem
    cfg = RankTableConfig(tau=64, omega=8, s=16)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(3))
    table = np.asarray(rt.table)
    assert np.all(np.diff(table, axis=1) <= 1e-4)
    assert table.min() >= 1.0
    assert table.max() <= items.shape[0] + 1 + 1e-4
    assert int(rt.m) == items.shape[0]


def test_full_sampling_gives_exact_table(small_problem):
    """When s = |P_l| (sample everything, no replacement), Eq. (1) becomes
    the exact count: the table must equal true ranks at each threshold."""
    users, items = small_problem
    users, items = users[:64], items[:100]
    omega = 4
    cfg = RankTableConfig(tau=33, omega=omega, s=items.shape[0] // omega,
                          threshold_mode="exact")
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(5))
    U = np.asarray(users, np.float64)
    P = np.asarray(items, np.float64)
    thr = np.asarray(rt.thresholds, np.float64)
    scores = np.einsum("nd,md->nm", U, P)[:, None, :]
    # f_min/f_max thresholds EQUAL extreme scores; strict `>` at a float32
    # tie can flip vs float64 — compare against the [lo, hi] tie band.
    eps = 1e-4 * np.abs(scores).max()
    lo = 1 + (scores > thr[:, :, None] + eps).sum(axis=2)
    hi = 1 + (scores > thr[:, :, None] - eps).sum(axis=2)
    got = np.asarray(rt.table)
    assert np.all((lo - 1e-5 <= got) & (got <= hi + 1e-5))


def test_estimator_is_unbiased(small_problem):
    """E[T̂] = T over sampling keys (Eq. 1's unbiasedness claim)."""
    users, items = small_problem
    users, items = users[:8], items[:200]
    cfg = RankTableConfig(tau=9, omega=5, s=8, threshold_mode="norm_bound")
    tables = []
    for seed in range(200):
        rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(seed))
        tables.append(np.asarray(rt.table))
    mean_table = np.mean(tables, axis=0)
    exact_cfg = RankTableConfig(tau=9, omega=5, s=40,
                                threshold_mode="norm_bound")
    # exact table: full sampling per bucket
    rt_exact = build_rank_table(users, items, exact_cfg,
                                jax.random.PRNGKey(0))
    np.testing.assert_allclose(mean_table, np.asarray(rt_exact.table),
                               atol=3.0)  # 3 ranks of 200 ≈ 1.5 %


@pytest.mark.parametrize("mode", ["sampled", "norm_bound", "exact"])
def test_threshold_modes_all_build(small_problem, mode):
    users, items = small_problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, threshold_mode=mode)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(1))
    thr = np.asarray(rt.thresholds)
    assert np.all(np.diff(thr, axis=1) > 0)
    assert rt.table.shape == (users.shape[0], 16)


def test_config_validation():
    with pytest.raises(ValueError):
        RankTableConfig(tau=1)
    with pytest.raises(ValueError):
        RankTableConfig(omega=0)
    with pytest.raises(ValueError):
        RankTableConfig(threshold_mode="bogus")
