"""Chaos suite (PR 9): deterministic fault injection, deadline admission,
typed shutdown, thread-death visibility, backoff recovery, and the
certified degrade ladder.

Every test runs with `faults.clear()` guaranteed afterwards (autouse
fixture), and against a FRESH default metrics registry — injected chaos
must never leak into another test, and callback gauges
(`*_thread_alive`) must bind to THIS test's threads, not a previous
test's dead ones.

Run the suite alone with `pytest -m faults` (the CI chaos job).
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.engine import ReverseKRanksEngine
from repro.core.types import RankTableConfig
from repro.index import MaintenanceLoop, MaintenancePolicy
from repro.obs import registry as obs
from repro.obs.audit import QualityAuditor
from repro.serve import (DeadlineExceeded, DegradeController, DegradePolicy,
                         MicroBatcher, QueueFull, SchedulerClosed, faults)
from tests.conftest import make_problem

pytestmark = pytest.mark.faults

K, C = 7, 2.0
MAX_BATCH = 4


@pytest.fixture(autouse=True)
def chaos_hygiene():
    """Fresh registry + guaranteed faults.clear() per test."""
    old = obs.get_default()
    obs.set_default(obs.MetricsRegistry())
    try:
        yield
    finally:
        faults.clear()
        obs.set_default(old)


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=256, m=128, d=16)


def _engine(problem, backend="dense"):
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=16)
    return ReverseKRanksEngine.build(users, items, cfg,
                                     jax.random.PRNGKey(1), backend=backend)


# ---------------------------------------------------------------- the plan
def test_plan_is_deterministic_per_site():
    """Same seed ⇒ the same fire pattern at a site, independent of how
    often OTHER sites are evaluated (per-site RNG streams)."""
    def pattern(extra_noise_evals):
        faults.install(faults.FaultPlan(seed=3, rules=[
            faults.FaultRule("serve.dispatch", mode="raise", rate=0.3),
            faults.FaultRule("serve.slow_tick", mode="sleep", rate=0.5),
        ]))
        out = []
        for i in range(64):
            for _ in range(extra_noise_evals * (i % 3)):
                faults.should_fire("serve.slow_tick")   # perturb ANOTHER site
            out.append(faults.should_fire("serve.dispatch"))
        faults.clear()
        return out

    a, b = pattern(0), pattern(5)
    assert a == b
    assert any(a) and not all(a)        # rate 0.3 actually thins the stream


def test_plan_parse_grammar():
    plan = faults.FaultPlan.parse(
        "index.rebuild:raise:1.0:2, serve.slow_tick:sleep:0.1::25", seed=7)
    assert plan.seed == 7
    r = plan.rules["index.rebuild"]
    assert (r.mode, r.rate, r.max_fires) == ("raise", 1.0, 2)
    s = plan.rules["serve.slow_tick"]
    assert (s.mode, s.rate, s.max_fires, s.latency_ms) == \
        ("sleep", 0.1, None, 25.0)


def test_plan_rejects_typos():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.FaultRule("serve.dispach")
    with pytest.raises(ValueError, match="unknown fault mode"):
        faults.FaultRule("serve.dispatch", mode="explode")
    with pytest.raises(ValueError, match="rate"):
        faults.FaultRule("serve.dispatch", rate=1.5)
    with pytest.raises(ValueError, match="duplicate"):
        faults.FaultPlan(rules=[faults.FaultRule("serve.dispatch"),
                                faults.FaultRule("serve.dispatch")])
    with pytest.raises(ValueError, match="bad fault spec"):
        faults.FaultPlan.parse("just-a-site")


def test_disabled_is_a_noop():
    faults.clear()
    assert faults.ACTIVE is None
    faults.fire("serve.dispatch")               # must not raise
    assert faults.should_fire("persist.spill") is False


def test_max_fires_and_after():
    plan = faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("serve.dispatch", mode="raise", max_fires=2,
                         after=1)]))
    fired = [faults.should_fire("serve.dispatch") for _ in range(6)]
    assert fired == [False, True, True, False, False, False]
    assert plan.fires["serve.dispatch"] == 2
    assert plan.evaluations["serve.dispatch"] == 6


# --------------------------------------------------- deadlines & shutdown
def test_deadline_rejected_at_admission(problem):
    eng = _engine(problem)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=1.0) as mb:
        with pytest.raises(DeadlineExceeded):
            mb.submit(problem[1][0], K, C, deadline_ms=0.0)
        assert mb.stats().expired == 1


def test_deadline_sweep_shed_before_tick(problem):
    """A queued request whose budget expires during coalescing is failed
    by the sweep with the TYPED error, and never occupies a tick slot."""
    eng = _engine(problem)
    users, items = problem
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=200.0) as mb:
        doomed = mb.submit(items[0], K, C, deadline_ms=5.0)
        time.sleep(0.03)                # let the budget lapse in-queue
        # a FULL group of fresh requests forces a tick cut; the sweep
        # runs first and sheds the expired head
        ok = [mb.submit(items[i + 1], K, C) for i in range(MAX_BATCH)]
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=5)
        for f in ok:
            assert f.result(timeout=5).indices.shape == (K,)
    st = mb.stats()
    assert st.expired == 1
    assert st.requests == MAX_BATCH     # the expired one never dispatched
    assert sum(t.expired for t in mb.tick_log) == 1


def test_submit_after_close_raises_typed(problem):
    eng = _engine(problem)
    mb = MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=0.5)
    f = mb.submit(problem[1][0], K, C)
    mb.close()
    mb.close()                          # idempotent double-close
    assert f.result(timeout=5).indices.shape == (K,)
    with pytest.raises(SchedulerClosed):
        mb.submit(problem[1][1], K, C)


def test_close_racing_inflight_tick_leaves_no_torn_future(problem):
    """close(drain_s=) while ticks are slow (injected latency): every
    accepted future must terminate — a result or a TYPED exception,
    never pending forever — and every shed must be accounted."""
    eng = _engine(problem)
    users, items = problem
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("serve.slow_tick", mode="sleep", rate=1.0,
                         latency_ms=40.0)]))
    mb = MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=0.5)
    futs = [mb.submit(items[i % items.shape[0]], K, C) for i in range(24)]
    closer = threading.Thread(target=lambda: mb.close(drain_s=0.06))
    closer.start()
    closer.join(timeout=30)
    assert not closer.is_alive()
    resolved = shed = 0
    for f in futs:
        assert f.done(), "future left pending after close()"
        try:
            r = f.result(timeout=0)
        except SchedulerClosed:
            shed += 1
        else:
            resolved += 1
            assert r.indices.shape == (K,)
    assert resolved + shed == len(futs)
    assert shed >= 1                    # the bounded drain actually shed
    st = mb.stats()
    assert st.rejected == shed
    # every rejection is attributed to exactly one TickStats record
    assert sum(t.rejected for t in mb.tick_log) == st.rejected


def test_dispatch_fault_fails_tick_typed_and_recovers(problem):
    """An injected dispatch failure fails that tick's futures with
    `InjectedFault` (typed, all of them, none torn); later ticks serve
    normally and the failed tick's reject accounting is re-credited."""
    eng = _engine(problem)
    users, items = problem
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("serve.dispatch", mode="raise", max_fires=1)]))
    # a wide coalescing window keeps each MAX_BATCH burst in ONE tick even
    # when the pipelined dispatcher (PR 10) is warm enough to cut early
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=50.0) as mb:
        bad = [mb.submit(items[i], K, C) for i in range(MAX_BATCH)]
        for f in bad:
            with pytest.raises(faults.InjectedFault):
                f.result(timeout=10)
        good = [mb.submit(items[i], K, C) for i in range(MAX_BATCH)]
        for f in good:
            assert f.result(timeout=10).indices.shape == (K,)
    assert sum(t.rejected for t in mb.tick_log) == mb.stats().rejected


# --------------------------------------------------- thread-death gauges
@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_maintenance_thread_death_flips_liveness_gauge(problem):
    """A fault OUTSIDE the rebuild try/except kills the loop thread; the
    callback gauge must read 0 at the next scrape (no silent death)."""
    eng = _engine(problem)
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("maintenance.loop", mode="raise", max_fires=1)]))
    ml = MaintenanceLoop(eng, poll_ms=5.0)
    assert ml._m_alive.value == 1.0
    ml.wake()
    ml._thread.join(timeout=10)
    assert not ml._thread.is_alive()
    assert ml._m_alive.value == 0.0     # scrape-time callback, not stale


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_audit_thread_death_flips_liveness_gauge():
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("audit.loop", mode="raise", max_fires=1)]))
    aud = QualityAuditor(engine=object(), fraction=1.0, seed=0)
    assert aud._m_alive.value == 1.0
    assert aud.observe(np.zeros(4, np.float32), None, k=K, c=C)
    aud._thread.join(timeout=10)
    assert not aud._thread.is_alive()
    assert aud._m_alive.value == 0.0
    # the fault restored _in_flight, so flush() terminates instead of
    # hanging on the dead scorer
    assert aud.flush(timeout=1.0)


def test_maintenance_backoff_and_recovery_without_restart(problem):
    """Two injected rebuild failures: the loop logs, backs off (capped
    exponential), keeps serving, and the consecutive-failures gauge
    returns to 0 on the third (successful) attempt — no restart."""
    eng = _engine(problem)
    users, items = problem
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("index.rebuild", mode="raise", max_fires=2)]))
    with MaintenanceLoop(
            eng, policy=MaintenancePolicy(max_delta_ratio=0.01,
                                          min_interval_s=0.0),
            poll_ms=5.0, failure_backoff_s=0.02, max_backoff_s=0.05) as ml:
        eng.insert_items(items[:8] * 1.1)      # cross the rebuild trigger
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline and not (
                ml.rebuilds and ml.consecutive_failures == 0):
            ml.wake()
            time.sleep(0.01)
        assert len(ml.failures) == 2
        assert all(isinstance(e, faults.InjectedFault) for e in ml.failures)
        assert len(ml.rebuilds) >= 1
        assert ml.consecutive_failures == 0     # recovered, same process
        assert ml._m_consec.value == 0.0
        assert ml._thread.is_alive()
        # the old snapshot kept serving THROUGH the failures
        res = eng.query_batch(items[:2], k=K, c=C)
        assert np.all(np.asarray(res.r_lo) <= np.asarray(res.r_up))


# ------------------------------------------------------ the degrade ladder
def test_degrade_ladder_hysteresis_and_widened_c():
    dc = DegradeController(DegradePolicy(high_depth=8, low_depth=2,
                                         dwell_ticks=2, widen_c=1.5))
    assert dc.effective_max == 2        # no cache ⇒ rung 3 unreachable
    assert dc.on_tick_cut(10) == 0      # one hot tick is not a trend
    assert dc.on_tick_cut(10) == 1      # dwell met: step down
    assert dc.widened_c(C) == C         # rung 1 is contract-free
    dc.on_tick_cut(10)
    assert dc.on_tick_cut(10) == 2
    assert dc.widened_c(C) == C * 1.5   # rung 2 serves c_eff, explicitly
    dc.on_tick_cut(10)
    assert dc.on_tick_cut(10) == 2      # topped out without a cache
    assert dc.on_tick_cut(5) == 2       # hysteresis band holds the level
    dc.on_tick_cut(1)
    assert dc.on_tick_cut(1) == 1       # recovery is as deliberate
    dc.on_tick_cut(1)
    assert dc.on_tick_cut(1) == 0
    assert dc.transitions == [(0, 1), (1, 2), (2, 1), (1, 0)]


def test_degrade_single_burst_cannot_thrash():
    dc = DegradeController(DegradePolicy(high_depth=8, low_depth=2,
                                         dwell_ticks=3))
    for depth in (20, 5, 20, 5, 20, 5):     # bursty, never sustained
        assert dc.on_tick_cut(depth) == 0
    assert dc.transitions == []


def test_degrade_cache_only_serves_hits_sheds_misses(problem):
    """Rung 3: an LRU hit resolves (certified result computed earlier in
    the same epoch), a miss sheds with the `degraded` reject reason."""
    eng = _engine(problem, backend="cached:dense")
    users, items = problem
    hot, cold = items[0], items[1]
    # warm the LRU at the base contract through the real serving path
    want = eng.query(hot, k=K, c=C)
    dc = DegradeController(DegradePolicy(high_depth=50, low_depth=1,
                                         dwell_ticks=50),
                           backend=eng._backend)
    assert dc.cache is not None         # auto-discovered from the chain
    assert dc.effective_max == 3
    dc.level = 3                        # pin rung 3; the wide dwell window
    # keeps on_tick_cut from stepping during the test
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=20.0,
                      degrade=dc) as mb:
        f_hit = mb.submit(hot, K, C)
        f_miss = mb.submit(cold, K, C)
        got = f_hit.result(timeout=10)
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(want.indices))
        assert np.all(np.asarray(got.r_lo) <= np.asarray(got.r_up))
        with pytest.raises(QueueFull, match="degrade level 3"):
            f_miss.result(timeout=10)
    log = mb.tick_log
    assert any(t.degrade_level == 3 for t in log)
    assert sum(t.rejected for t in log) == mb.stats().rejected == 1


def test_degraded_tick_recorded_at_widened_contract(problem):
    """Rung 2 under real dispatch: the tick record carries the rung, and
    results are still valid certified bounds (at c_eff)."""
    eng = _engine(problem)
    users, items = problem
    dc = DegradeController(DegradePolicy(high_depth=2, low_depth=1,
                                         dwell_ticks=1, max_level=2,
                                         widen_c=2.0))
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=30.0,
                      degrade=dc) as mb:
        # two bursts deep enough to step 0→1→2 (dwell 1), then serve
        for _ in range(3):
            futs = [mb.submit(items[i], K, C) for i in range(MAX_BATCH)]
            for f in futs:
                r = f.result(timeout=10)
                assert np.all(np.asarray(r.r_lo) <= np.asarray(r.r_up))
    levels = [t.degrade_level for t in mb.tick_log]
    assert max(levels) == 2
    assert dc.widened_c(C) == 2.0 * C


# ------------------------------------------- overlapped pipeline (PR 10)
def test_cache_only_rung_batches_device_get(problem, monkeypatch):
    """Rung 3 resolves ALL its LRU hits through ONE batched
    `jax.device_get` — the per-request blocking transfer is gone."""
    eng = _engine(problem, backend="cached:dense")
    users, items = problem
    hots = [items[i] for i in range(3)]
    wants = [eng.query(h, k=K, c=C) for h in hots]     # warm the LRU
    dc = DegradeController(DegradePolicy(high_depth=50, low_depth=1,
                                         dwell_ticks=50),
                           backend=eng._backend)
    dc.level = 3                        # pin rung 3 (cache-only)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=150.0,
                      degrade=dc) as mb:
        mb._admission_cache = None      # force hits down to the tick path
        futs = [mb.submit(h, K, C) for h in hots]
        calls = []
        real = jax.device_get

        def counting(x):
            calls.append(x)
            return real(x)

        monkeypatch.setattr(jax, "device_get", counting)
        got = [f.result(timeout=30) for f in futs]
        monkeypatch.undo()
    for g, w in zip(got, wants):
        np.testing.assert_array_equal(np.asarray(g.indices),
                                      np.asarray(w.indices))
    assert len(calls) == 1, f"expected ONE batched D2H, saw {len(calls)}"
    assert isinstance(calls[0], list) and len(calls[0]) == len(hots)


def test_transfer_fault_fails_only_that_tick_and_recredits(problem):
    """An injected `serve.transfer` failure (the completion stage's D2H)
    fails exactly that tick's futures with `InjectedFault`; its reject
    and expiry accounting is re-credited so conservation still holds,
    and later ticks serve normally."""
    eng = _engine(problem)
    users, items = problem
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("serve.transfer", mode="raise", max_fires=1)]))
    # wide coalescing window: each MAX_BATCH burst forms ONE tick
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=50.0) as mb:
        # two requests whose budget lapses in-queue: swept before any
        # cut, charged to the next DISPATCHED tick — which will fault
        doomed = [mb.submit(items[9 + i], K, C, deadline_ms=1e-3)
                  for i in range(2)]
        time.sleep(0.01)
        bad = [mb.submit(items[i], K, C) for i in range(MAX_BATCH)]
        for f in doomed:
            with pytest.raises(DeadlineExceeded):
                f.result(timeout=10)
        for f in bad:
            with pytest.raises(faults.InjectedFault):
                f.result(timeout=10)
        good = [mb.submit(items[i], K, C) for i in range(MAX_BATCH)]
        for f in good:
            assert f.result(timeout=10).indices.shape == (K,)
    st = mb.stats()
    assert st.expired == 2
    # the faulted tick re-credited its expiries: they land on exactly one
    # surviving record, and reject conservation holds
    assert sum(t.expired for t in mb.tick_log) == 2
    assert sum(t.rejected for t in mb.tick_log) == st.rejected
    assert sum(1 for t in mb.tick_log if t.batch > 0) == 1


@pytest.mark.concurrency
def test_close_with_two_ticks_in_flight_no_torn_futures(problem):
    """close(drain_s=) while TWO ticks are in flight (slow transfer,
    pipeline_depth=2): every accepted future terminates with a result or
    a typed error — never pending — and shed accounting is exact."""
    eng = _engine(problem)
    users, items = problem
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("serve.transfer", mode="sleep", rate=1.0,
                         latency_ms=50.0)]))
    mb = MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=0.5,
                      pipeline_depth=2)
    futs = [mb.submit(items[i % items.shape[0]], K, C) for i in range(24)]
    closer = threading.Thread(target=lambda: mb.close(drain_s=0.08))
    closer.start()
    closer.join(timeout=60)
    assert not closer.is_alive()
    resolved = shed = 0
    for f in futs:
        assert f.done(), "future left pending after close()"
        try:
            r = f.result(timeout=0)
        except SchedulerClosed:
            shed += 1
        else:
            resolved += 1
            assert r.indices.shape == (K,)
    assert resolved + shed == len(futs)
    assert shed >= 1
    st = mb.stats()
    assert st.rejected == shed
    assert sum(t.rejected for t in mb.tick_log) == st.rejected
    # the pipeline genuinely overlapped while draining
    assert max((t.inflight for t in mb.tick_log if t.batch > 0),
               default=0) >= 2
