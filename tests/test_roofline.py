"""Roofline extraction: HLO collective parser + term math + sharding rules."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.roofline import (Roofline, analyze, collective_bytes,
                                   model_flops_train, shape_bytes)


def test_shape_bytes():
    assert shape_bytes("bf16", "16,2048") == 16 * 2048 * 2
    assert shape_bytes("f32", "128") == 512
    assert shape_bytes("pred", "8,8") == 64
    assert shape_bytes("f32", "") == 4          # scalar


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = bf16[16,2048]{1,0} all-gather(bf16[1,2048]{1,0} %x), dims={0}
  %ar = (f32[128]{0}, f32[64]{0}) all-reduce(f32[128]{0} %a, f32[64]{0} %b)
  %rs = f32[4,32]{1,0} reduce-scatter(f32[64,32]{1,0} %y), dims={0}
  %aa = bf16[8,8]{1,0} all-to-all(bf16[8,8]{1,0} %z)
  %cp = f32[10]{0} collective-permute(f32[10]{0} %w)
  %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %p, f32[2,2]{1,0} %q)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 2048 * 2
    assert got["all-reduce"] == 128 * 4 + 64 * 4
    assert got["reduce-scatter"] == 4 * 32 * 4
    assert got["all-to-all"] == 8 * 8 * 2
    assert got["collective-permute"] == 40
    assert got["total"] == sum(got[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"))
    assert got["counts"]["all-reduce"] == 1


def test_analyze_terms_and_bottleneck():
    class FakeCompiled:
        def cost_analysis(self):
            return {"flops": 197e12, "bytes accessed": 819e9 * 2}

        def as_text(self):
            return "%ag = f32[100]{0} all-gather(f32[10]{0} %x)"

    r = analyze(FakeCompiled(), chips=4, model_flops=197e12 * 4)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_ratio - 1.0) < 1e-9


def test_model_flops_moe_discounts_inactive_experts():
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("llama4-scout-17b-a16e"))
    from repro.models.model import Model
    ap = Model(cfg).abstract_params()
    dense_equiv = model_flops_train(cfg, ap, tokens=1000)
    # activating 1 of E experts must cost far less than 6·N_total·D
    total = sum(int(l.size) for l in jax.tree.leaves(ap))
    assert dense_equiv < 6.0 * total * 1000


def test_rules_divisibility_fallbacks():
    import os
    from repro.models.sharding import rules_for
    from repro.configs import get_config
    if jax.device_count() < 2:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    else:
        mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    cfg = get_config("gemma-2b")
    r = rules_for(cfg, mesh, batch_size=1)
    assert r.table["batch"] is None or mesh.shape["data"] == 1
    # q_dim 2048 divisible by any pow2 model axis here; kv heads = 1 never
    if mesh.shape["model"] > 1:
        assert r.table["kv"] is None
        assert r.table["kv_seq"] == "model"
