"""Compile-once elastic serving tests (the PR-7 tentpole,
`repro.core.elastic`).

Two contracts are pinned:

  * BIT-IDENTITY (f32): for every n in a sweep — including one with a
    padded tail — the elastic program's QueryResult equals the non-tiled
    backend's bitwise, every field. The scan is a reordering of
    row-local work plus a dominated sentinel that the selection provably
    never admits for k ≤ n (see the module's sentinel-soundness note).
    On the QUANTIZED specs the certified artifacts (indices, bounds,
    order statistics, Lemma-1 counters) still compare bitwise; only
    `est_rank` — a tie-break estimate, not a certified quantity — is
    held to float accuracy, because XLA contracts its FMA chains
    differently inside the fori_loop body than in the monolithic region
    (same class of caveat as the width-1 matvec lowering in
    tests/test_serve.py).
  * COMPILE-ONCE: a sweep of distinct n values inside one capacity
    bucket, served after a single warm-up, adds ZERO elastic traces and
    ZERO programs to the query stack's jit caches
    (`compiled_program_count`) — the tier-1 guard that fails loudly if
    any future change re-keys the serving path on n.

Queries are items perturbed off the threshold grid (conventions of
tests/test_backends.py). n values are chosen inside one power-of-two
capacity bucket of the default 256-tile (cap 1024): 643 exercises a
mid-tile tail, 760 a padded final tile, 600 a different tile count.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core import elastic as EL
from repro.core.engine import ReverseKRanksEngine
from repro.core.types import RankTableConfig
from tests.conftest import make_problem

K, C = 7, 2.0
N, M, D, B = 800, 300, 16, 4
SWEEP = (600, 643, 700, 760)            # one capacity bucket (cap = 1024)
SPECS = ("float32", "bfloat16", "int8")
INNERS = ("dense", "fused")


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=N, m=M, d=D)


@pytest.fixture(scope="module")
def queries(problem):
    _, items = problem
    base = items[(1 + jnp.arange(B) * 13) % items.shape[0]]
    return base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), base.shape, jnp.float32))


def _cfg(spec="float32"):
    return RankTableConfig(tau=16, omega=4, s=8, storage_dtype=spec)


def _rows(users, packed, n):
    idx = jnp.arange(n)
    return users[:n] if packed is None else packed.take_rows(idx)


def assert_parity(got, want, spec="float32"):
    """Bitwise on every field; quantized specs hold est_rank to float
    accuracy instead (module docstring)."""
    for f in want._fields:
        x, y = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        if f == "est_rank" and spec != "float32":
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5,
                                       err_msg="est_rank drifted")
            continue
        np.testing.assert_array_equal(x, y,
                                      err_msg=f"field {f!r} not bitwise")


# ------------------------------------------------------------ bit-identity
@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("inner", INNERS)
def test_elastic_matches_inner_across_n(problem, queries, spec, inner):
    users, items = problem
    cfg = _cfg(spec)
    ref = BK.get_backend(inner)
    el = BK.get_backend(f"elastic:{inner}")
    assert el.name == f"elastic:{inner}"
    rt = ref.build_index(users, items, cfg, jax.random.PRNGKey(1))
    packed = cfg.storage.pack_users(users)
    for n in SWEEP:
        u = _rows(users, packed, n)
        rtn = rt.take_rows(jnp.arange(n))
        want = ref.query_batch(rtn, u, queries, k=K, c=C)
        got = el.query_batch(rtn, u, queries, k=K, c=C)
        assert got.r_lo.shape == want.r_lo.shape      # capacity sliced off
        assert_parity(got, want, spec)


def test_k_edges_and_degenerate_accept(problem, queries):
    """k = n (selection spans every real row), k > n (delegates to the
    inner backend), and a huge c (the sentinel-accepted degenerate case:
    c·R↓_k ≥ m+2 accepts EVERY user) all match dense bitwise."""
    users, items = problem
    cfg = _cfg()
    ref, el = BK.get_backend("dense"), BK.get_backend("elastic:dense")
    rt = ref.build_index(users, items, cfg, jax.random.PRNGKey(1))
    n = 600
    u, rtn = users[:n], rt.take_rows(jnp.arange(n))
    assert_parity(el.query_batch(rtn, u, queries, k=n, c=C),
                  ref.query_batch(rtn, u, queries, k=n, c=C))
    assert_parity(el.query_batch(rtn, u, queries, k=K, c=1e6),
                  ref.query_batch(rtn, u, queries, k=K, c=1e6))
    # k > n delegates to the inner backend wholesale (the shared
    # selection partitions at k−1, which needs k ≤ n): elastic must
    # reproduce the inner's behavior exactly, whatever it is.
    def probe(backend):
        try:
            return "ok", backend.query_batch(rtn, u, queries, k=n + 1, c=C)
        except Exception as e:                      # noqa: BLE001
            return "err", type(e)

    kind_ref, val_ref = probe(ref)
    kind_el, val_el = probe(el)
    assert kind_el == kind_ref
    if kind_ref == "ok":
        assert_parity(val_el, val_ref)
    else:
        assert val_el is val_ref


# ------------------------------------------------------------ delta path
@pytest.mark.parametrize("spec", ("float32", "int8"))
@pytest.mark.parametrize("inner", INNERS)
def test_elastic_delta_parity(problem, queries, spec, inner):
    """Engine-level churn (item inserts/deletes + user deletes) serves
    through the +inf-sentinel delta program; parity with the non-tiled
    inner on the identical mutation script."""
    users, items = problem
    cfg = _cfg(spec)

    def churned(backend):
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1),
                                        backend=backend)
        eng.insert_items(jax.random.normal(jax.random.PRNGKey(11),
                                           (16, D), jnp.float32))
        eng.delete_items(list(range(5, 15)))
        eng.delete_users(list(range(0, 30, 3)))
        return eng.query_batch(queries, k=K, c=C)

    assert_parity(churned(f"elastic:{inner}"), churned(inner), spec)


def test_delta_mostly_dead_users(problem, queries):
    """k exceeding the LIVE user count drives R↑_k to +inf — the pad
    correction's edge case (inf ≤ c·inf counts pads accepted, inf > inf
    counts none pruned, mirroring how the non-tiled program counts dead
    real rows). Parity must hold bitwise."""
    users, items = problem
    cfg = _cfg()

    def run(backend):
        eng = ReverseKRanksEngine.build(users, items, cfg,
                                        jax.random.PRNGKey(1),
                                        backend=backend)
        eng.delete_users([i for i in range(N) if i % 160 != 0])  # 5 live
        return eng.query_batch(queries, k=K, c=C)

    got, want = run("elastic:dense"), run("dense")
    assert bool(np.all(np.isinf(np.asarray(want.R_up_k))))  # edge reached
    assert_parity(got, want)


# ----------------------------------------------------------- compile-once
def test_single_program_serves_n_sweep(problem, queries):
    """THE tentpole assertion: after one warm-up, a sweep of 4 distinct
    n values (mid-tile tails and a padded final tile included) adds zero
    elastic traces and zero compiled programs anywhere in the query
    stack's jit caches."""
    users, items = problem
    cfg = _cfg()
    el = BK.get_backend("elastic:dense")
    rt = el.build_index(users, items, cfg, jax.random.PRNGKey(1))
    caps = {EL.capacity_for(n, el.tile) for n in SWEEP}
    assert caps == {1024}                      # one bucket, by construction
    el.query_batch(rt.take_rows(jnp.arange(SWEEP[0])), users[:SWEEP[0]],
                   queries, k=K, c=C)          # warm-up (may trace)
    traces0 = EL.elastic_trace_count()
    programs0 = EL.compiled_program_count()
    ref = BK.get_backend("dense")
    for n in SWEEP:
        got = el.query_batch(rt.take_rows(jnp.arange(n)), users[:n],
                             queries, k=K, c=C)
        assert_parity(got, ref.query_batch(rt.take_rows(jnp.arange(n)),
                                           users[:n], queries, k=K, c=C))
    assert EL.elastic_trace_count() == traces0
    assert EL.compiled_program_count() == programs0


def test_capacity_bucketing():
    assert EL.capacity_for(1, 256) == 256
    assert EL.capacity_for(256, 256) == 256
    assert EL.capacity_for(257, 256) == 512
    assert EL.capacity_for(600, 256) == 1024
    assert EL.capacity_for(1024, 256) == 1024
    assert EL.capacity_for(1025, 256) == 2048
    # doubling buckets ⇒ O(log n) lifetime compiles, ≤ 2× waste
    assert EL.capacity_for(100_000, 256) == 256 * 512


def test_bucket_crossing_traces_once_per_capacity(problem, queries):
    """Growing n across a capacity boundary traces exactly once for the
    new bucket, then serves it compile-free — O(log n) lifetime traces."""
    users, items = problem
    cfg = _cfg()
    el = EL.ElasticBackend("dense", tile=32)
    rt = el.build_index(users, items, cfg, jax.random.PRNGKey(1))

    def q(n):
        return el.query_batch(rt.take_rows(jnp.arange(n)), users[:n],
                              queries, k=K, c=C)

    q(500)                                     # cap 512: warm bucket 1
    t0 = EL.elastic_trace_count()
    q(510)                                     # same bucket: no trace
    assert EL.elastic_trace_count() == t0
    q(600)                                     # cap 1024: one new trace
    assert EL.elastic_trace_count() == t0 + 1
    q(760)                                     # warm bucket 2: no trace
    assert EL.elastic_trace_count() == t0 + 1


def test_engine_hot_swap_without_retrace(problem, queries):
    """End-to-end: rebuilds that GROW n (the recompile-storm scenario)
    republish into the same compiled program — zero serving traces across
    the churn, results right at every step."""
    users, items = problem
    cfg = _cfg()
    eng = ReverseKRanksEngine.build(users[:600], items, cfg,
                                    jax.random.PRNGKey(1),
                                    backend="elastic:dense")
    eng.query_batch(queries, k=K, c=C)          # warm
    t0 = EL.elastic_trace_count()
    rng = np.random.default_rng(5)
    for grow in (43, 57, 60):
        eng.upsert_users(jnp.asarray(
            rng.standard_normal((grow, D)).astype(np.float32)))
        assert eng.rebuild() is not None
        res = eng.query_batch(queries, k=K, c=C)
        assert res.indices.shape == (B, K)
    assert eng.n == 760
    assert EL.elastic_trace_count() == t0       # zero serving retraces


def test_padded_operand_cache_reuses_generation(problem, queries):
    users, items = problem
    cfg = _cfg()
    el = EL.ElasticBackend("dense")
    rt = el.build_index(users, items, cfg, jax.random.PRNGKey(1))
    n = 600
    u, rtn = users[:n], rt.take_rows(jnp.arange(n))
    el.query_batch(rtn, u, queries, k=K, c=C)
    assert len(el._padded) == 1
    first = next(iter(el._padded.values()))[1]
    el.query_batch(rtn, u, queries, k=K, c=C)
    assert len(el._padded) == 1                 # identity hit, no repad
    assert next(iter(el._padded.values()))[1] is first


# ------------------------------------------------------ registry + knobs
def test_registry_and_delegation(problem, queries):
    users, items = problem
    assert BK.get_backend("elastic:").name == "elastic:dense"  # default
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("elastic")               # prefix alone: not a name
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("elastic:no-such-backend")
    # non-stock inner (sharded): documented delegation, results intact
    el = BK.get_backend("elastic:sharded")
    assert el._mode is None
    cfg = _cfg()
    rt = el.build_index(users, items, cfg, jax.random.PRNGKey(1))
    want = BK.get_backend("sharded").query_batch(rt, users, queries,
                                                 k=K, c=C)
    assert_parity(el.query_batch(rt, users, queries, k=K, c=C), want)


def test_tile_knob_validation(monkeypatch):
    with pytest.raises(ValueError, match="multiple of 32"):
        EL.ElasticBackend("dense", tile=33)
    monkeypatch.setenv("REPRO_ELASTIC_TILE", "64")
    assert EL.default_tile() == 64
    assert EL.ElasticBackend("dense").tile == 64
    monkeypatch.setenv("REPRO_ELASTIC_TILE", "20")
    with pytest.raises(ValueError, match="multiple of 32"):
        EL.default_tile()


def test_tile_takes_dequant_direct_branch():
    """The one n-sensitive branch in the dense tile unit
    (`_dequant_matmul`'s blocked split) must take its DIRECT branch at
    tile granularity, or tiling would not be bit-identical — guard the
    constants against drifting apart."""
    from repro.core.query import _DEQUANT_MM_BLOCK
    assert EL.default_tile() < 2 * _DEQUANT_MM_BLOCK
