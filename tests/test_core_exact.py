"""Oracle tests: repro.core.exact vs literal numpy Definition 1/2.

Ranks are tie-sensitive: when q ∈ P, u·q mathematically ties u·p for p = q,
and float32 matmuls in XLA vs numpy round differently. The reference is
therefore a band [rank_strict, rank_with_ties] computed in float64 with an
epsilon window; the JAX rank must fall inside the band.
"""
import jax
import numpy as np
import pytest

from repro.core.exact import exact_rank_single, exact_ranks, reverse_k_ranks
from tests.conftest import make_problem

EPS = 1e-4


def np_rank_band(users, items, q):
    users = np.asarray(users, np.float64)
    items = np.asarray(items, np.float64)
    q = np.asarray(q, np.float64)
    uq = users @ q
    up = users @ items.T
    scale = np.abs(up).max()
    lo = 1 + (up > uq[:, None] + EPS * scale).sum(axis=1)
    hi = 1 + (up > uq[:, None] - EPS * scale).sum(axis=1)
    return lo, hi


def assert_in_band(got, lo, hi):
    got = np.asarray(got)
    ok = (lo <= got) & (got <= hi)
    assert ok.all(), f"out of band at {np.where(~ok)[0][:10]}"


@pytest.mark.parametrize("n,m,d", [(64, 50, 8), (257, 129, 16), (1000, 333, 64)])
def test_exact_ranks_matches_numpy(n, m, d):
    users, items = make_problem(jax.random.PRNGKey(n + m), n, m, d)
    q = items[3]
    got = np.asarray(exact_ranks(users, items, q, block=128))
    lo, hi = np_rank_band(users, items, q)
    assert_in_band(got, lo, hi)


def test_block_size_invariance(small_problem):
    users, items = small_problem
    q = items[0]
    a = np.asarray(exact_ranks(users, items, q, block=32))
    b = np.asarray(exact_ranks(users, items, q, block=4096))
    np.testing.assert_array_equal(a, b)


def test_reverse_k_ranks_is_k_smallest(small_problem):
    users, items = small_problem
    q = items[11]
    k = 17
    idx, ranks = reverse_k_ranks(users, items, q, k)
    ranks, idx = np.asarray(ranks), np.asarray(idx)
    full = np.asarray(exact_ranks(users, items, q))
    assert len(set(idx.tolist())) == k
    np.testing.assert_array_equal(ranks, full[idx])
    # rank-ascending and no better user left out (vs the same rank vector)
    assert np.all(np.diff(ranks) >= 0)
    assert ranks[-1] <= np.partition(full, k - 1)[k - 1]


def test_single_user_rank_matches(small_problem):
    users, items = small_problem
    q = items[5]
    lo, hi = np_rank_band(users, items, q)
    for i in [0, 7, 511]:
        got = int(exact_rank_single(users[i], items, q))
        assert lo[i] <= got <= hi[i]


def test_rank_one_for_best_user(small_problem):
    """A user whose strictly-best item is q has rank 1 (Definition 1 counts
    strictly greater items only). Ties with q allow rank 2 under float
    rounding, hence the ≤ 2 band for the self-tie."""
    users, items = small_problem
    q = items[9]
    ranks = np.asarray(exact_ranks(users, items, q))
    best = np.asarray(np.asarray(users, np.float64)
                      @ np.asarray(items, np.float64).T).argmax(axis=1)
    assert np.all(ranks[best == 9] <= 2)
