"""§Perf H5 option: online-logsumexp chunked-vocab CE ≡ dense CE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.models.transformer import _chunked_ce


@pytest.mark.parametrize("n_chunks", [2, 8])
def test_chunked_ce_matches_dense_loss(n_chunks):
    cfg0 = dataclasses.replace(reduced(get_config("granite-3-8b")),
                               n_layers=2, vocab=512, remat="none")
    cfg1 = dataclasses.replace(cfg0, vocab_chunks=n_chunks)
    m0, m1 = Model(cfg0), Model(cfg1)
    params = m0.init_params(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, 512),
             "labels": jax.random.randint(key, (2, 16), 0, 512)}
    l0 = float(m0.loss_fn(params, batch))
    l1 = float(m1.loss_fn(params, batch))
    assert abs(l0 - l1) < 5e-3

    g0 = jax.grad(m0.loss_fn)(params, batch)
    g1 = jax.grad(m1.loss_fn)(params, batch)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        scale = float(jnp.abs(a).max()) + 1e-9
        assert float(jnp.abs(a - b).max()) / scale < 0.05


def test_chunked_ce_raw_math():
    """lse/label-logit from the scan equal the dense computation exactly
    (f32 inputs, no bf16 rounding)."""
    key = jax.random.PRNGKey(2)
    B, S, D, V = 2, 5, 16, 64
    x = jax.random.normal(key, (B, S, D), jnp.float32)
    head = jax.random.normal(jax.random.PRNGKey(3), (D, V), jnp.float32)
    labels = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, V)
    lse, ll = _chunked_ce(x, head, labels, n_chunks=4)
    logits = (x @ head).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(jax.nn.logsumexp(logits, -1)),
                               rtol=1e-5)
    want = np.take_along_axis(np.asarray(logits),
                              np.asarray(labels)[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(ll), want, rtol=1e-5)
