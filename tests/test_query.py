"""§4.3 query-processing tests, incl. hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra — `pip install repro[test]` (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core import metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.query import lookup_bounds, query, query_batch
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTable, RankTableConfig
from tests.conftest import make_problem


def _exact_full_table(users, items, tau):
    """A rank table with exact entries (full-information limit)."""
    cfg = RankTableConfig(tau=tau, omega=4, s=items.shape[0] // 4,
                          threshold_mode="exact")
    return build_rank_table(users, items, cfg, jax.random.PRNGKey(0))


def test_lookup_bounds_bracket_with_exact_table(small_problem):
    users, items = small_problem
    rt = _exact_full_table(users, items, tau=50)
    q = items[3]
    uq = users @ q
    r_lo, r_up, est = lookup_bounds(rt, jnp.asarray(uq))
    truth = np.asarray(exact_ranks(users, items, q))
    r_lo, r_up, est = map(np.asarray, (r_lo, r_up, est))
    assert np.all(r_lo <= truth + 1e-5)
    assert np.all(truth <= r_up + 1e-5)
    assert np.all((r_lo <= est + 1e-5) & (est <= r_up + 1e-5))


def test_lookup_bounds_out_of_range():
    thresholds = jnp.array([[0.0, 1.0, 2.0]])
    table = jnp.array([[90.0, 50.0, 10.0]])
    rt = RankTable(thresholds=thresholds, table=table,
                   m=jnp.asarray(100, jnp.int32))
    r_lo, r_up, est = lookup_bounds(rt, jnp.array([-5.0]))   # below range
    assert float(r_up[0]) == 101.0 and float(r_lo[0]) == 90.0
    r_lo, r_up, est = lookup_bounds(rt, jnp.array([9.0]))    # above range
    assert float(r_lo[0]) == 1.0 and float(r_up[0]) == 10.0
    r_lo, r_up, est = lookup_bounds(rt, jnp.array([0.5]))    # interior
    assert float(r_lo[0]) == 50.0 and float(r_up[0]) == 90.0
    np.testing.assert_allclose(float(est[0]), 70.0, rtol=1e-6)  # midpoint


def test_interpolation_linear_in_score():
    thresholds = jnp.array([[0.0, 1.0]])
    table = jnp.array([[80.0, 20.0]])
    rt = RankTable(thresholds, table, jnp.asarray(100, jnp.int32))
    for s, want in [(0.25, 65.0), (0.5, 50.0), (0.75, 35.0)]:
        _, _, est = lookup_bounds(rt, jnp.array([s]))
        np.testing.assert_allclose(float(est[0]), want, rtol=1e-6)


def test_query_accuracy_exact_table(small_problem):
    """Exact table ⇒ valid bounds ⇒ accuracy 1 at c = 2."""
    users, items = small_problem
    rt = _exact_full_table(users, items, tau=100)
    truth_q = items[21]
    res = query(rt, users, truth_q, k=10, c=2.0)
    truth = np.asarray(exact_ranks(users, items, truth_q))
    ex_idx, _ = reverse_k_ranks(users, items, truth_q, 10)
    assert metrics.accuracy(np.asarray(res.indices), np.asarray(ex_idx),
                            truth, c=2.0) == 1.0


def test_query_invariants(medium_problem):
    users, items = medium_problem
    cfg = RankTableConfig(tau=128, omega=8, s=32)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(2))
    res = query(rt, users, items[5], k=25, c=1.5)
    r_lo, r_up = np.asarray(res.r_lo), np.asarray(res.r_up)
    assert np.all(r_lo <= r_up + 1e-5)
    assert float(res.R_lo_k) <= float(res.R_up_k) + 1e-5
    idx = np.asarray(res.indices)
    assert len(set(idx.tolist())) == 25
    # In the non-guaranteed case, accept/prune masks are disjoint:
    if not bool(res.guaranteed):
        acc = r_up <= 1.5 * float(res.R_lo_k)
        pru = r_lo > float(res.R_up_k)
        assert not np.any(acc & pru)


def test_query_batch_matches_loop(medium_problem):
    users, items = medium_problem
    cfg = RankTableConfig(tau=64, omega=4, s=16)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(4))
    qs = items[:6]
    batched = query_batch(rt, users, qs, k=7, c=2.0)
    for b in range(6):
        single = query(rt, users, qs[b], k=7, c=2.0)
        bi = np.asarray(batched.indices[b])
        si = np.asarray(single.indices)
        if np.array_equal(bi, si):
            continue
        # An item-query can put a CLUSTER of users at float-identical
        # estimates; the (n,d)×(d,B) matmul's low bits then order the tie
        # differently from the (n,d)×(d,1) case (true of the seed's vmap
        # path as well). Equally-good selections must agree on the
        # estimate multiset to float accuracy.
        np.testing.assert_allclose(
            np.sort(np.asarray(batched.est_rank[b])),
            np.sort(np.asarray(single.est_rank)), rtol=1e-5, atol=1e-3)
        # bounds are table-derived and stay exact
        np.testing.assert_array_equal(np.asarray(batched.r_lo[b]),
                                      np.asarray(single.r_lo))
        np.testing.assert_array_equal(np.asarray(batched.r_up[b]),
                                      np.asarray(single.r_up))


def test_query_deterministic(medium_problem):
    users, items = medium_problem
    cfg = RankTableConfig(tau=64, omega=4, s=16)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(4))
    a = query(rt, users, items[1], k=9, c=1.2)
    b = query(rt, users, items[1], k=9, c=1.2)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))


if given is not None:
    @given(seed=st.integers(0, 2**16), k=st.integers(1, 20),
           c=st.floats(1.0, 8.0))
    @settings(max_examples=25, deadline=None)
    def test_query_property_shapes_and_bounds(seed, k, c):
        users, items = make_problem(jax.random.PRNGKey(seed), n=200, m=150,
                                    d=8)
        cfg = RankTableConfig(tau=32, omega=4, s=8)
        rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(seed + 1))
        res = query(rt, users, items[seed % 150], k=k, c=float(c))
        assert res.indices.shape == (k,)
        idx = np.asarray(res.indices)
        assert len(set(idx.tolist())) == k
        assert np.all((idx >= 0) & (idx < 200))
        est = np.asarray(res.est_rank)
        # est is a selection KEY: the sub-unit margin tie-break can dip it to
        # est - 0.5 for above-range scores (see lookup_bounds), never below.
        assert np.all((est >= 0.5 - 1e-5) & (est <= 151.0 + 1e-5))
        # Estimated bounds never invert.
        assert np.all(np.asarray(res.r_lo) <= np.asarray(res.r_up) + 1e-5)


else:
    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_query_property_shapes_and_bounds():
        pass

def test_accuracy_tracks_paper_regime():
    """Paper reports accuracy ≈ 1 with τ=500, modest sampling, c ≥ 2 —
    reproduce that regime at reduced scale."""
    users, items = make_problem(jax.random.PRNGKey(11), n=4000, m=2000, d=64)
    # At this reduced scale the k-th best rank is single-digit, so c·rank is
    # far tighter than at paper scale (n ≥ 1.6e5); s=128 compensates.
    cfg = RankTableConfig(tau=500, omega=10, s=128)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(12))
    accs, ratios = [], []
    for qi in range(8):
        q = items[qi * 13]
        res = query(rt, users, q, k=10, c=2.0)
        truth = np.asarray(exact_ranks(users, items, q))
        ex_idx, _ = reverse_k_ranks(users, items, q, 10)
        accs.append(metrics.accuracy(np.asarray(res.indices),
                                     np.asarray(ex_idx), truth, c=2.0))
        ratios.append(metrics.overall_ratio(np.asarray(res.indices),
                                            np.asarray(ex_idx), truth))
    assert np.mean(accs) >= 0.95            # paper: "almost perfect"
    assert np.mean(ratios) <= 1.3           # paper: "almost 1"
