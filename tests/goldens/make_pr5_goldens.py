"""Generate the PR-5 pre-refactor f32 goldens (run ONCE on the pre-refactor
tree; the committed .npz pins the storage-tier refactor's f32 no-op claim).

    PYTHONPATH=src python tests/goldens/make_pr5_goldens.py

The golden records the dense backend's QueryResult fields for the
test_backends problem in both Lemma-1 regimes at B in {1, 16}, plus a
delta-path result (inserts + deletes + dead users). test_storage.py
asserts every backend at StorageSpec f32 still reproduces these BITWISE
after the precision-polymorphic storage refactor.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import ReverseKRanksEngine
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig
from tests.conftest import make_problem

K = 7
OUT = os.path.join(os.path.dirname(__file__), "pr5_f32.npz")


def main():
    users, items = make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)
    regimes = {
        "guaranteed": (RankTableConfig(tau=128, omega=4, s=items.shape[0] // 4,
                                       threshold_mode="exact"),
                       jax.random.PRNGKey(0), 4.0),
        "non_guaranteed": (RankTableConfig(tau=16, omega=4, s=8),
                           jax.random.PRNGKey(1), 1.0),
    }
    out = {}
    for regime, (cfg, key, c) in regimes.items():
        rt = build_rank_table(users, items, cfg, key)
        eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg)
        for B in (1, 16):
            base = items[(1 + jnp.arange(B) * 17) % items.shape[0]]
            qs = base * (1.0 + 1e-4 * jax.random.normal(
                jax.random.PRNGKey(100 + B), base.shape, jnp.float32))
            res = eng.query_batch(qs, k=K, c=c)
            tag = f"{regime}_B{B}"
            out[f"{tag}_qs"] = np.asarray(qs)
            for f in ("indices", "est_rank", "r_lo", "r_up", "R_lo_k",
                      "R_up_k"):
                out[f"{tag}_{f}"] = np.asarray(getattr(res, f))

    # delta path: inserts + deletes + dead users on the sampled regime
    cfg, key, c = regimes["non_guaranteed"]
    eng = ReverseKRanksEngine.build(users, items, cfg, key)
    _, new_items = make_problem(jax.random.PRNGKey(77), n=1, m=24, d=16)
    eng.insert_items(new_items)
    eng.delete_items([3, 44, 101, 257])
    eng.delete_users([7, 300])
    qs = out["non_guaranteed_B16_qs"]
    res = eng.query_batch(jnp.asarray(qs), k=K, c=c)
    for f in ("indices", "est_rank", "r_lo", "r_up", "R_lo_k", "R_up_k"):
        out[f"delta_B16_{f}"] = np.asarray(getattr(res, f))
    out["delta_new_items"] = np.asarray(new_items)

    np.savez_compressed(OUT, **out)
    print(f"wrote {OUT}: {sorted(out)[:4]}... ({len(out)} arrays)")


if __name__ == "__main__":
    main()
