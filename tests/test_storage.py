"""Precision-polymorphic storage tier (PR 5).

Three contracts, per StorageSpec × backend × batch shape:

  * f32 is a NO-OP REFACTOR: selected indices, bounds and order
    statistics are bit-identical to the pre-refactor code, pinned by the
    committed goldens (tests/goldens/pr5_f32.npz, generated on the
    pre-refactor tree by make_pr5_goldens.py) — including the delta path.
  * bf16/int8 are CERTIFIED: the widened (r↓, r↑) CONTAIN the f32-spec
    bounds for every user and every query (r↓ rounds down, r↑ up), so
    Lemma-1 selection over them stays sound — including the delta path,
    where quantized correction rows yield certified count ranges.
  * the quantizer itself: per-row affine int8 codes reconstruct within
    half a step, packing preserves sortedness, and the absent sentinel
    (−128 / −inf) can never be counted by the delta count brackets.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core.engine import ReverseKRanksEngine
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig, StorageSpec, StoredUsers
from tests.conftest import make_problem

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens", "pr5_f32.npz")
SPECS = ("float32", "bfloat16", "int8")
BACKENDS = ("dense", "fused", "sharded", "pruned", "pruned:fused")
K = 7


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)


def _cfg(spec: str, **kw) -> RankTableConfig:
    base = dict(tau=16, omega=4, s=8)
    base.update(kw)
    return RankTableConfig(storage_dtype=spec, **base)


@pytest.fixture(scope="module")
def tables(problem):
    """Rank tables for both Lemma-1 regimes × every storage spec, built
    from the SAME f32 estimation pass (same key)."""
    users, items = problem
    out = {}
    for spec in SPECS:
        exact_cfg = _cfg(spec, tau=128, s=items.shape[0] // 4,
                         threshold_mode="exact")
        coarse_cfg = _cfg(spec)
        out[("guaranteed", spec)] = (
            exact_cfg,
            build_rank_table(users, items, exact_cfg, jax.random.PRNGKey(0)),
            4.0)
        out[("non_guaranteed", spec)] = (
            coarse_cfg,
            build_rank_table(users, items, coarse_cfg,
                             jax.random.PRNGKey(1)), 1.0)
    return out


def _engine(problem, tables, regime, spec, backend):
    users, _ = problem
    cfg, rt, c = tables[(regime, spec)]
    return ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                               backend=backend), c


def _golden_qs(golden, regime, B):
    return jnp.asarray(golden[f"{regime}_B{B}_qs"])


# ------------------------------------------------------------ f32 goldens
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B", [1, 16])
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_f32_bit_parity_with_prerefactor_goldens(problem, tables, golden,
                                                 backend, B, regime):
    """The f32 spec is provably a no-op: every backend reproduces the
    PRE-REFACTOR dense results bitwise (indices, table-derived bounds,
    order statistics; est at float accuracy)."""
    eng, c = _engine(problem, tables, regime, "float32", backend)
    qs = _golden_qs(golden, regime, B)
    res = eng.query_batch(qs, k=K, c=c)
    tag = f"{regime}_B{B}"
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  golden[f"{tag}_indices"])
    np.testing.assert_array_equal(np.asarray(res.R_lo_k),
                                  golden[f"{tag}_R_lo_k"])
    np.testing.assert_array_equal(np.asarray(res.R_up_k),
                                  golden[f"{tag}_R_up_k"])
    np.testing.assert_allclose(np.asarray(res.est_rank),
                               golden[f"{tag}_est_rank"], rtol=1e-5,
                               atol=1e-4)
    if res.r_lo.shape == golden[f"{tag}_r_lo"].shape:   # not candidate-set
        np.testing.assert_array_equal(np.asarray(res.r_lo),
                                      golden[f"{tag}_r_lo"])
        np.testing.assert_array_equal(np.asarray(res.r_up),
                                      golden[f"{tag}_r_up"])


def test_f32_delta_bit_parity_with_goldens(problem, golden):
    """Delta path (inserts + deletes + dead users) at the f32 spec is
    bit-identical to the pre-refactor code."""
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, _cfg("float32"),
                                    jax.random.PRNGKey(1))
    eng.insert_items(jnp.asarray(golden["delta_new_items"]))
    eng.delete_items([3, 44, 101, 257])
    eng.delete_users([7, 300])
    res = eng.query_batch(_golden_qs(golden, "non_guaranteed", 16), k=K,
                          c=1.0)
    for f in ("indices", "r_lo", "r_up", "R_lo_k", "R_up_k", "est_rank"):
        np.testing.assert_array_equal(np.asarray(getattr(res, f)),
                                      golden[f"delta_B16_{f}"])


# --------------------------------------------------- certified containment
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B", [1, 16])
@pytest.mark.parametrize("spec", ["bfloat16", "int8"])
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_certified_containment(problem, tables, golden, backend, B, regime,
                               spec):
    """Quantized specs widen certifiably: r↓ ≤ f32 r↓ and r↑ ≥ f32 r↑
    for EVERY user and query, est stays inside the widened interval, and
    every returned user is admissible under the widened bounds."""
    eng, c = _engine(problem, tables, regime, spec, backend)
    ref, _ = _engine(problem, tables, regime, "float32", "dense")
    qs = _golden_qs(golden, regime, B)
    res = eng.query_batch(qs, k=K, c=c)
    want = ref.query_batch(qs, k=K, c=c)
    if res.r_lo.shape == want.r_lo.shape:       # full (B, n) bound fields
        r_lo, r_up = np.asarray(res.r_lo), np.asarray(res.r_up)
        assert np.all(r_lo <= np.asarray(want.r_lo) + 1e-4)
        assert np.all(r_up >= np.asarray(want.r_up) - 1e-4)
        # returned users: inside the widened interval (the sub-unit
        # above-range tie-break dips est up to 0.5 below r↓ by design —
        # same as the f32 path) and admissible
        est = np.asarray(res.est_rank)
        idx = np.asarray(res.indices)
        take = lambda a: np.take_along_axis(
            np.atleast_2d(a), np.atleast_2d(idx), axis=-1)
        assert np.all(take(r_lo) - 0.5 - 1e-4 <= np.atleast_2d(est))
        assert np.all(np.atleast_2d(est) <= take(r_up) + 1e-4)
    # the order statistics must bracket the f32 ones in the widened
    # direction on every backend (sharded included)
    assert np.all(np.asarray(res.R_lo_k) <= np.asarray(want.R_lo_k) + 1e-4)
    assert np.all(np.asarray(res.R_up_k) >= np.asarray(want.R_up_k) - 1e-4)


@pytest.mark.parametrize("backend", ["dense", "fused", "sharded", "pruned",
                                     "pruned:fused"])
@pytest.mark.parametrize("spec", ["bfloat16", "int8"])
def test_certified_containment_delta(problem, golden, backend, spec):
    """Containment survives the delta path: quantized correction rows
    yield certified count ranges, so corrected bounds still bracket the
    f32 engine's corrected bounds; dead users are +inf everywhere."""
    users, items = problem

    def mutate(engine):
        engine.insert_items(jnp.asarray(golden["delta_new_items"]))
        engine.delete_items([3, 44, 101, 257])
        engine.delete_users([7, 300])
        return engine

    eng = mutate(ReverseKRanksEngine.build(users, items, _cfg(spec),
                                           jax.random.PRNGKey(1),
                                           backend=backend))
    ref = mutate(ReverseKRanksEngine.build(users, items, _cfg("float32"),
                                           jax.random.PRNGKey(1)))
    qs = _golden_qs(golden, "non_guaranteed", 16)
    res = eng.query_batch(qs, k=K, c=1.0)
    want = ref.query_batch(qs, k=K, c=1.0)
    if res.r_lo.shape == want.r_lo.shape:
        rl, ru = np.asarray(res.r_lo), np.asarray(res.r_up)
        wl, wu = np.asarray(want.r_lo), np.asarray(want.r_up)
        fin = np.isfinite(wl)
        assert np.all(rl[fin] <= wl[fin] + 1e-4)
        assert np.all(ru[fin] >= wu[fin] - 1e-4)
        assert np.all(~np.isfinite(rl[~fin]))   # dead users stay +inf
        assert not np.isin(np.asarray(res.indices), [7, 300]).any()
    assert np.all(np.asarray(res.R_lo_k) <= np.asarray(want.R_lo_k) + 1e-4)
    assert np.all(np.asarray(res.R_up_k) >= np.asarray(want.R_up_k) - 1e-4)


# ----------------------------------------------------- quantizer contracts
def test_storage_spec_parse_and_validation():
    assert StorageSpec.parse("float32").kind == "f32"
    assert StorageSpec.parse("bf16").kind == "bf16"
    assert StorageSpec.parse(StorageSpec(kind="int8")).kind == "int8"
    with pytest.raises(ValueError, match="unknown storage spec"):
        StorageSpec.parse("fp4")
    with pytest.raises(ValueError, match="unknown StorageSpec kind"):
        StorageSpec(kind="f16")
    with pytest.raises(ValueError):
        RankTableConfig(storage_dtype="no-such-dtype")
    assert RankTableConfig(storage_dtype="int8").storage.kind == "int8"


def test_pack_table_roundtrip_error_bound():
    """int8 affine codes reconstruct within half a quantization step and
    preserve per-row monotonicity."""
    key = jax.random.PRNGKey(0)
    thr = jnp.sort(jax.random.normal(key, (32, 40)) * 3.0, axis=1)
    tab = jnp.sort(jax.random.uniform(key, (32, 40)) * 100 + 1.0,
                   axis=1)[:, ::-1]
    rt = StorageSpec(kind="int8").pack_table(thr, tab)
    deq_thr = (rt.thresholds.astype(jnp.float32) * rt.thr_scale
               + rt.thr_off)
    deq_tab = rt.table.astype(jnp.float32) * rt.tab_scale + rt.tab_off
    assert rt.thresholds.dtype == jnp.int8
    assert np.all(np.abs(np.asarray(deq_thr - thr))
                  <= np.asarray(rt.thr_scale) * 0.5 + 1e-6)
    assert np.all(np.abs(np.asarray(deq_tab - tab))
                  <= np.asarray(rt.tab_scale) * 0.5 + 1e-6)
    assert np.all(np.diff(np.asarray(deq_thr), axis=1) >= 0)
    assert np.all(np.diff(np.asarray(deq_tab), axis=1) <= 0)


def test_pack_users_slack_bound():
    """The per-row slack certifies the score error: for random queries,
    |stored-score − f32-score| ≤ row_slack · ‖q‖₁."""
    key = jax.random.PRNGKey(1)
    users = jax.random.normal(key, (64, 24)) * 2.0
    qs = jax.random.normal(jax.random.PRNGKey(2), (8, 24))
    for spec in ("bf16", "int8"):
        stored = StorageSpec(kind=spec).pack_users(users)
        assert isinstance(stored, StoredUsers)
        rows = stored.rows.astype(jnp.float32)
        if stored.scale is not None:
            rows = rows * stored.scale
        err = np.abs(np.asarray(rows @ qs.T - users @ qs.T))
        bound = np.asarray(stored.row_slack) * np.asarray(
            jnp.sum(jnp.abs(qs), axis=1))[None, :]
        assert np.all(err <= bound + 1e-5)
    assert StorageSpec(kind="f32").pack_users(users) is None


def test_pack_scores_sentinel_never_counted():
    """Delta count brackets: [count_lo, count_hi] contains the exact f32
    count for every spec, and left-padding sentinels cannot inflate
    either side even for scores below every stored value."""
    from repro.core.rank_table import _count_above, _count_above_range
    key = jax.random.PRNGKey(3)
    raw = jnp.sort(jax.random.normal(key, (16, 5)) * 2.0, axis=1)
    scores = jnp.concatenate([
        jax.random.normal(jax.random.PRNGKey(4), (16, 6)) * 2.0,
        jnp.full((16, 1), -50.0), jnp.full((16, 1), 50.0)], axis=1)
    exact = np.asarray(_count_above(raw, scores))
    for spec in ("f32", "bf16", "int8"):
        rows, sc, off = StorageSpec(kind=spec).pack_scores(raw, pad=3)
        lo, hi = _count_above_range(rows, sc, off, scores, None)
        assert np.all(np.asarray(lo) <= exact + 1e-6), spec
        assert np.all(exact <= np.asarray(hi) + 1e-6), spec
        assert np.all(np.asarray(hi) <= raw.shape[1]), spec   # pads excluded
        assert np.all(np.asarray(lo) >= 0.0), spec


# ----------------------------------------------------- mutation lifecycle
@pytest.mark.parametrize("spec", ["bfloat16", "int8"])
def test_upsert_users_quantized_spec(problem, spec):
    """Upserts re-estimate rows in f32 and re-pack through the ONE pack
    path: replaced rows behave like a from-scratch build's rows."""
    users, items = problem
    cfg = _cfg(spec)
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    new_rows = users[:3] * 1.5
    eng.upsert_users(new_rows, indices=[5, 9, 300])
    users_new = np.array(users)
    users_new[[5, 9, 300]] = np.asarray(new_rows)
    scratch = ReverseKRanksEngine.build(jnp.asarray(users_new), items, cfg,
                                        jax.random.PRNGKey(1))
    q = items[11]
    got = eng.query(q, k=K, c=2.0)
    want = scratch.query(q, k=K, c=2.0)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    np.testing.assert_array_equal(np.asarray(got.r_lo),
                                  np.asarray(want.r_lo))
    # appended users land in the stored tier too
    eng.upsert_users(users[:2] * 0.5)
    assert eng.current_snapshot().stored_users.rows.shape[0] == eng.n


@pytest.mark.parametrize("spec", SPECS)
def test_rebuild_quantized_spec(problem, spec):
    """rebuild() over a mutated quantized engine equals a from-scratch
    build over the merged item set, bitwise."""
    users, items = problem
    cfg = _cfg(spec)
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1))
    _, new_items = make_problem(jax.random.PRNGKey(9), n=1, m=12, d=16)
    eng.insert_items(new_items)
    rec = eng.rebuild()
    assert rec is not None
    scratch = ReverseKRanksEngine.build(users, eng.live_items(), cfg,
                                        jax.random.PRNGKey(1))
    q = items[3]
    got = eng.query(q, k=K, c=2.0)
    want = scratch.query(q, k=K, c=2.0)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    np.testing.assert_array_equal(np.asarray(got.r_lo),
                                  np.asarray(want.r_lo))


def test_stored_users_lifecycle(problem):
    users, items = problem
    eng32 = ReverseKRanksEngine.build(users, items, _cfg("float32"),
                                      jax.random.PRNGKey(1))
    assert eng32.current_snapshot().stored_users is None    # no-op path
    eng8 = ReverseKRanksEngine.build(users, items, _cfg("int8"),
                                     jax.random.PRNGKey(1))
    su = eng8.current_snapshot().stored_users
    assert su is not None and su.rows.dtype == jnp.int8
    assert eng8.memory_bytes() < eng32.memory_bytes()
    # user mutation repacks the stored tier; item mutation carries it
    snap0 = eng8.current_snapshot()
    eng8.insert_items(items[:2] * 0.9)
    assert eng8.current_snapshot().stored_users is snap0.stored_users
    eng8.upsert_users(users[:1] * 2.0, indices=[0])
    assert eng8.current_snapshot().stored_users is not snap0.stored_users


# ------------------------------------------------- near-duplicate caching
def test_near_duplicate_cache_key(problem, tables):
    from repro.serve.cache import CachingBackend
    users, items = problem
    cfg, rt, c = tables[("non_guaranteed", "float32")]
    snap_users = users
    q = items[5]
    jit = q * (1.0 + 1e-5)
    far = items[77]
    exact = CachingBackend("dense")
    for qq in (q, jit):
        exact.query_batch(rt, snap_users, qq[None, :], k=K, c=c)
    assert exact.hits == 0                      # exact keys never alias
    coarse = CachingBackend("dense", quantize_key_bits=6)
    r1 = coarse.query_batch(rt, snap_users, q[None, :], k=K, c=c)
    r2 = coarse.query_batch(rt, snap_users, jit[None, :], k=K, c=c)
    assert coarse.hits == 1                     # near-duplicate reused
    np.testing.assert_array_equal(np.asarray(r1.indices),
                                  np.asarray(r2.indices))
    coarse.query_batch(rt, snap_users, far[None, :], k=K, c=c)
    assert coarse.misses == 2                   # distinct queries miss
    with pytest.raises(ValueError, match="quantize_key_bits"):
        CachingBackend("dense", quantize_key_bits=1)


def test_interpret_env_override():
    """REPRO_INTERPRET flips the kernels' interpret mode without a source
    edit (the ROADMAP TPU-validation knob)."""
    import subprocess
    import sys
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.kernels import ops; print(ops.INTERPRET)"],
        env={**os.environ, "REPRO_INTERPRET": "0",
             "PYTHONPATH": "src" + os.pathsep + os.environ.get(
                 "PYTHONPATH", "")},
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.stdout.strip() == "False", out.stderr
    from repro.kernels.ops import _interpret_default
    assert _interpret_default() is True or "REPRO_INTERPRET" in os.environ
