"""QSRP baseline tests: exact bounds, accuracy-1 guarantee, c behaviour."""
import jax
import numpy as np
import pytest

from repro.core import metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.qsrp import (QSRPIndex, _bounds_from_summary,
                             build_qsrp_index, qsrp_query)
from tests.conftest import make_problem


@pytest.fixture(scope="module")
def problem():
    users, items = make_problem(jax.random.PRNGKey(33), n=600, m=500, d=24)
    idx = build_qsrp_index(users, items, levels=100, block=256)
    return users, items, idx


def test_qsrp_bounds_always_valid(problem):
    """Quantile summaries are true order statistics ⇒ bounds are EXACT
    (unlike the rank table's estimates)."""
    users, items, idx = problem
    for qi in [0, 10, 499]:
        q = items[qi]
        uq = np.asarray(users @ q)
        r_lo, r_up = map(np.asarray,
                         _bounds_from_summary(idx, jax.numpy.asarray(uq)))
        truth = np.asarray(exact_ranks(users, items, q))
        assert np.all(r_lo <= truth)
        assert np.all(truth <= r_up)
        assert np.all(r_up - r_lo <= np.ceil(500 / 99) + 1)


@pytest.mark.parametrize("c", [1.0, 2.0, 4.0])
def test_qsrp_accuracy_always_one(problem, c):
    """QSRP's guarantee holds up to float-tie noise: two different matmul
    schedules can flip a strict `>` at a mathematical tie, shifting a rank
    by ±1; we therefore assert the Def.-3 inequality with a 1-rank slack."""
    users, items, idx = problem
    for qi in [3, 77]:
        q = items[qi]
        truth = np.asarray(exact_ranks(users, items, q))
        ex_idx, _ = reverse_k_ranks(users, items, q, 10)
        got_idx, got_ranks, _ = qsrp_query(idx, users, items, q, 10, c)
        ours = np.sort(truth[got_idx]).astype(np.float64)
        exact = np.sort(truth[np.asarray(ex_idx)]).astype(np.float64)
        assert np.all(ours <= c * exact + 1)
        np.testing.assert_allclose(got_ranks, truth[got_idx], atol=2)


def test_qsrp_c1_equals_exact(problem):
    """c = 1 degenerates to the exact reverse k-ranks answer (rank-wise,
    modulo float-tie ±1)."""
    users, items, idx = problem
    q = items[42]
    truth = np.asarray(exact_ranks(users, items, q))
    ex_idx, ex_ranks = reverse_k_ranks(users, items, q, 15)
    got_idx, got_ranks, _ = qsrp_query(idx, users, items, q, 15, 1.0)
    np.testing.assert_allclose(np.sort(truth[got_idx]),
                               np.sort(np.asarray(ex_ranks)), atol=1)


def test_larger_c_refines_no_more(problem):
    """Higher c accepts more users via Lemma 1(1) ⇒ refinement work cannot
    grow with c (the Fig. 4 trend)."""
    users, items, idx = problem
    q = items[8]
    refined = [qsrp_query(idx, users, items, q, 10, c)[2]
               for c in (1.0, 2.0, 4.0, 8.0)]
    assert all(a >= b for a, b in zip(refined, refined[1:]))


def test_metrics_definitions():
    true_ranks = np.array([5, 1, 10, 100, 3])
    exact_idx = np.array([1, 4, 0])           # ranks 1, 3, 5
    ours_idx = np.array([1, 0, 2])            # ranks 1, 5, 10
    acc = metrics.accuracy(ours_idx, exact_idx, true_ranks, c=2.0)
    # pairs: (1,1) ok, (5,3) 5<=6 ok, (10,5) 10<=10 ok  → 1.0
    assert acc == 1.0
    ratio = metrics.overall_ratio(ours_idx, exact_idx, true_ranks)
    np.testing.assert_allclose(ratio, np.mean([1 / 1, 5 / 3, 10 / 5]))
