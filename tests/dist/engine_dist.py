"""Multi-device engine scenario (run by tests/test_distributed.py in a
subprocess): sharded build parity, single/batched sharded query parity
vs the single-device reference, ring exact ranks, and the one-collective
schedule property of the batched tree merge."""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core import distributed as D                       # noqa: E402
from repro.core.exact import exact_ranks                      # noqa: E402
from repro.core.query import query, query_batch               # noqa: E402
from repro.core.rank_table import build_rank_table            # noqa: E402
from repro.core.types import RankTableConfig                  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()
    n, m, d, k, c = 1024, 512, 32, 10, 2.0
    cfg = RankTableConfig(tau=64, omega=4, s=16)
    ku, ki, ks = jax.random.split(jax.random.PRNGKey(0), 3)
    users = jax.random.normal(ku, (n, d), jnp.float32)
    scale = 1.0 + 0.3 * jax.random.normal(ks, (m, 1), jnp.float32)
    items = jax.random.normal(ki, (m, d), jnp.float32) * jnp.abs(scale)
    mesh = D.flat_mesh(jax.devices())

    # ---- sharded build == single-device build (same key ⇒ same samples)
    rt_ref = build_rank_table(users, items, cfg, jax.random.PRNGKey(1))
    rt_sh = D.build_sharded(users, items, cfg, jax.random.PRNGKey(1), mesh)
    np.testing.assert_allclose(np.asarray(rt_sh.thresholds),
                               np.asarray(rt_ref.thresholds), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rt_sh.table),
                               np.asarray(rt_ref.table), rtol=1e-5,
                               atol=1e-5)
    print("BUILD_PARITY_OK")

    # ---- single sharded query == single-device reference
    qfn = D.make_query_fn(mesh, k=k, n=n, c=c)
    q = items[7]
    res_sh = qfn(rt_ref, users, q)
    res_ref = query(rt_ref, users, q, k, c)
    np.testing.assert_array_equal(np.asarray(res_sh.indices),
                                  np.asarray(res_ref.indices))
    assert float(res_sh.R_lo_k) == float(res_ref.R_lo_k)
    assert float(res_sh.R_up_k) == float(res_ref.R_up_k)
    print("QUERY_PARITY_OK")

    # ---- batched sharded queries ≡ per-query / dense reference. The
    # shard-local (n/P, d) × (d, B) matmul rounds differently from the
    # global one, so interpolated estimates differ in the low bits and a
    # tie at the top-k boundary may swap — allow one boundary swap per
    # query; the table-derived statistics must match exactly.
    B = 8
    qs = items[:B]
    bq = D.make_batch_query_fn(mesh, k=k, n=n, c=c)
    res_b = bq(rt_ref, users, qs)
    ref_b = query_batch(rt_ref, users, qs, k, c)
    np.testing.assert_array_equal(np.asarray(res_b.R_lo_k),
                                  np.asarray(ref_b.R_lo_k))
    np.testing.assert_array_equal(np.asarray(res_b.R_up_k),
                                  np.asarray(ref_b.R_up_k))
    for b in range(B):
        got = set(np.asarray(res_b.indices[b]).tolist())
        want = set(np.asarray(ref_b.indices[b]).tolist())
        assert len(got & want) >= k - 1, (b, got, want)
        single = qfn(rt_ref, users, qs[b])
        got1 = set(np.asarray(single.indices).tolist())
        assert len(got & got1) >= k - 1, (b, got, got1)
    print("BATCH_QUERY_OK")

    # ---- ring exact refinement == dense oracle
    ring = D.ring_exact_ranks(users, items, q, mesh)
    truth = exact_ranks(users, items, q)
    np.testing.assert_allclose(np.asarray(ring),
                               np.asarray(truth).astype(np.float32),
                               atol=1.0)  # self-tie rounding band
    print("RING_OK")

    # ---- schedule: collective count is independent of the batch size —
    # the tree merge gathers (B, k·P) candidates in the same collectives
    # a single query uses (no per-query gathers).
    def n_collectives(batch):
        qs_sds = jax.ShapeDtypeStruct((batch, d), jnp.float32)
        txt = jax.jit(bq).lower(rt_ref, users, qs_sds).compile().as_text()
        return sum(txt.count(op) for op in ("all-gather(", "all-gather-start(",
                                            "all-reduce(", "all-to-all("))
    c1, c16 = n_collectives(1), n_collectives(16)
    assert c1 == c16, (c1, c16)
    print(f"SCHEDULE_OK collectives(B=1)={c1} collectives(B=16)={c16}")

    print("ALL_OK")


if __name__ == "__main__":
    main()
