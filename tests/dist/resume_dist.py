"""Kill/resume scenario (run by tests/test_distributed.py in a
subprocess): a run checkpointed mid-flight and resumed on the same 4×2
mesh reproduces the uninterrupted run BITWISE — checkpoint round-trip is
exact and the counter-based pipeline replays the identical batch stream.
Template: tests/dist/engine_dist.py."""
import os
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.launch.train import run_training                   # noqa: E402
from train_dist import GB, SEQ, tiny_config  # noqa: E402  (script dir)

STEPS, KILL_AT = 8, 4


def main():
    assert jax.device_count() == 8, jax.devices()
    cfg = tiny_config()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # uninterrupted reference run
    params_full, losses_full = run_training(
        cfg, steps=STEPS, global_batch=GB, seq_len=SEQ, mesh=mesh,
        ckpt_every=10**6, lr=1e-3, log_every=STEPS)

    # "preempted" run: killed at KILL_AT (simulated by running to a final
    # checkpoint there), then resumed from disk and run to completion
    with tempfile.TemporaryDirectory() as d:
        _, losses_a = run_training(cfg, steps=KILL_AT, global_batch=GB,
                                   seq_len=SEQ, mesh=mesh, ckpt_dir=d,
                                   ckpt_every=10**6, lr=1e-3,
                                   log_every=KILL_AT)
        params_res, losses_b = run_training(cfg, steps=STEPS,
                                            global_batch=GB, seq_len=SEQ,
                                            mesh=mesh, ckpt_dir=d,
                                            ckpt_every=10**6, lr=1e-3,
                                            log_every=STEPS)

    # loss streams line up exactly: pre-kill + post-resume == full run
    np.testing.assert_array_equal(np.asarray(losses_a, np.float32),
                                  np.asarray(losses_full[:KILL_AT],
                                             np.float32))
    np.testing.assert_array_equal(np.asarray(losses_b, np.float32),
                                  np.asarray(losses_full[KILL_AT:],
                                             np.float32))

    # final parameters are bitwise identical leaf-by-leaf
    flat_full = jax.tree_util.tree_leaves_with_path(params_full)
    flat_res = dict(jax.tree_util.tree_leaves_with_path(params_res))
    assert flat_res, "resumed run returned no parameters"
    for path, leaf in flat_full:
        a = np.asarray(leaf)
        b = np.asarray(flat_res[path])
        assert a.dtype == b.dtype and np.array_equal(a, b), \
            f"leaf {jax.tree_util.keystr(path)} differs after resume"
    print("BITWISE_RESUME_OK")

    print("ALL_OK")


if __name__ == "__main__":
    main()
