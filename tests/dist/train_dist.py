"""Multi-device training scenario (run by tests/test_distributed.py in a
subprocess): sharded-vs-single-device loss parity, sharded execution on a
data×model mesh, and elastic checkpoint restore onto a DIFFERENT mesh
topology. Template: tests/dist/engine_dist.py."""
import os
import tempfile

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import dataclasses                                            # noqa: E402

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P    # noqa: E402

from repro.configs import get_config, reduced                 # noqa: E402
from repro.launch.train import run_training                   # noqa: E402
from repro.models.model import Model                          # noqa: E402
from repro.models.sharding import rules_for                   # noqa: E402
from repro.train import checkpoint as ckpt                    # noqa: E402
from repro.train.optimizer import AdamWConfig, adamw_init     # noqa: E402
from repro.train.trainer import make_train_step               # noqa: E402

STEPS, GB, SEQ = 6, 8, 32


def tiny_config():
    base = get_config("gemma-2b")
    return dataclasses.replace(reduced(base), remat="none")


def main():
    assert jax.device_count() == 8, jax.devices()
    cfg = tiny_config()

    # ---- single-device reference vs 4×2 data×model sharded run: same
    # seed, same deterministic pipeline ⇒ loss trajectories agree up to
    # GSPMD reduction-order noise.
    _, losses_ref = run_training(cfg, steps=STEPS, global_batch=GB,
                                 seq_len=SEQ, ckpt_every=10**6, lr=1e-3,
                                 log_every=STEPS)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    params_sh, losses_sh = run_training(cfg, steps=STEPS, global_batch=GB,
                                        seq_len=SEQ, mesh=mesh_a,
                                        ckpt_every=10**6, lr=1e-3,
                                        log_every=STEPS)
    np.testing.assert_allclose(np.asarray(losses_sh),
                               np.asarray(losses_ref), rtol=5e-2,
                               atol=5e-2)
    print("PARITY_OK")

    # ---- the sharded run really executed sharded: at least one weight
    # leaf spans multiple devices, and training moved the loss.
    n_sharded = sum(
        1 for leaf in jax.tree_util.tree_leaves(params_sh)
        if hasattr(leaf, "sharding") and not leaf.sharding.is_fully_replicated)
    assert n_sharded > 0, "no parameter leaf is actually sharded"
    assert np.isfinite(losses_sh).all()
    assert losses_sh[-1] < losses_sh[0], (losses_sh[0], losses_sh[-1])
    print(f"SHARDED_OK sharded_leaves={n_sharded}")

    # ---- elasticity: checkpoint written under the 4×2 mesh restores onto
    # a 2×4 topology (restore(shardings=...) device_puts every leaf) and
    # training continues there.
    model = Model(cfg)
    with tempfile.TemporaryDirectory() as d:
        run_training(cfg, steps=2, global_batch=GB, seq_len=SEQ,
                     mesh=mesh_a, ckpt_dir=d, ckpt_every=10**6, lr=1e-3,
                     log_every=2)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        rules_b = rules_for(cfg, mesh_b, batch_size=GB)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh_b, s),
                              model.param_specs(rules_b))
        abstract = model.abstract_params()
        tpl = {"params": abstract, "opt": jax.eval_shape(adamw_init,
                                                         abstract)}
        oshard = type(adamw_init(model.init_params(jax.random.PRNGKey(9))))(
            mu=pshard, nu=pshard, step=NamedSharding(mesh_b, P()))
        state, step, _ = ckpt.restore(d, tpl, shardings={"params": pshard,
                                                         "opt": oshard})
        assert step == 2, step
        step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                          rules_b))
        from repro.data.pipeline import PipelineConfig, TokenPipeline
        pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=SEQ,
                                            global_batch=GB))
        _, _, metrics = step_fn(state["params"], state["opt"],
                                pipe.batch_at(step))
        assert np.isfinite(float(metrics["loss"]))
    print("ELASTIC_OK")

    print("ALL_OK")


if __name__ == "__main__":
    main()
