"""Multi-device integration tests. Each scenario runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single real CPU device (per the dry-run isolation rule).
"""
import os
import subprocess
import sys

import pytest

_DIST = os.path.join(os.path.dirname(__file__), "dist")
_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _needs(script: str):
    """Skip (not fail) scenarios whose driver script isn't in the tree yet
    — see ROADMAP.md open items for the missing train/resume drivers."""
    return pytest.mark.skipif(
        not os.path.exists(os.path.join(_DIST, script)),
        reason=f"tests/dist/{script} not in tree")


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)        # the script sets its own device count
    proc = subprocess.run(
        [sys.executable, os.path.join(_DIST, script)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"{script} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout, proc.stdout
    return proc.stdout


@pytest.mark.slow
@_needs("engine_dist.py")
def test_engine_distributed():
    out = _run("engine_dist.py")
    for marker in ("BUILD_PARITY_OK", "QUERY_PARITY_OK", "BATCH_QUERY_OK",
                   "RING_OK", "SCHEDULE_OK"):
        assert marker in out


@pytest.mark.slow
@_needs("train_dist.py")
def test_train_distributed():
    out = _run("train_dist.py")
    for marker in ("PARITY_OK", "SHARDED_OK", "ELASTIC_OK"):
        assert marker in out


@pytest.mark.slow
@_needs("resume_dist.py")
def test_kill_resume_bitwise():
    out = _run("resume_dist.py")
    assert "BITWISE_RESUME_OK" in out
