"""Crash-safe persistence tests (PR 9): bitwise restore round-trips per
storage spec, torn-spill fallback, WAL corruption detection, degraded
(never crashed) serving on WAL write failure, and replay divergence.

The durability model under test (src/repro/index/persist.py): the
durable point is the newest checksum-valid spill plus its WAL prefix.
A torn TAIL (crash mid-append, nothing intact after it) truncates to the
prefix; corruption with intact records AFTER it, an unknown op, or a
replay that diverges from the recorded effect all raise `PersistError` —
recovery must fall back to rebuilding from the master copy rather than
ever serving a wrong answer from a bad WAL.
"""
import os

import jax
import numpy as np
import pytest

from repro.core.engine import ReverseKRanksEngine
from repro.core.types import RankTableConfig
from repro.index import IndexPersister, PersistError
from repro.index.persist import SPILL_MAGIC
from repro.obs import registry as obs
from repro.serve import faults
from tests.conftest import make_problem

pytestmark = pytest.mark.faults

K, C = 7, 2.0


@pytest.fixture(autouse=True)
def chaos_hygiene():
    old = obs.get_default()
    obs.set_default(obs.MetricsRegistry())
    try:
        yield
    finally:
        faults.clear()
        obs.set_default(old)


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=192, m=96, d=12)


def _build(problem, spec="f32"):
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=16, storage_dtype=spec)
    return ReverseKRanksEngine.build(users, items, cfg,
                                     jax.random.PRNGKey(1))


def _mutate_a(eng, problem):
    users, items = problem
    ids = eng.insert_items(items[:5] * 1.05)
    eng.delete_items([int(ids[1])])
    eng.upsert_users(users[:2] * 1.2, indices=np.array([0, 7]))
    return ids


def _mutate_b(eng, problem):
    users, items = problem
    eng.upsert_users(users[3:5] * 0.9)          # append two new users
    eng.delete_users([2])


def _assert_same_engine(got, want, problem):
    """Bitwise equality of the restored engine against the reference: the
    lineage counters, the as-stored rank-table bytes, and every field of
    a served batch."""
    users, items = problem
    assert got.current_snapshot().epoch == want.current_snapshot().epoch
    assert got._next_item_id == want._next_item_id
    rt_g, rt_w = got.rank_table, want.rank_table
    for f in rt_w._fields:
        a, b = getattr(rt_g, f), getattr(rt_w, f)
        assert (a is None) == (b is None), f"rank-table field {f!r}"
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"rank-table field {f!r}")
    np.testing.assert_array_equal(np.asarray(got.users),
                                  np.asarray(want.users))
    qs = items[:4] * 1.01
    rg = got.query_batch(qs, k=K, c=C)
    rw = want.query_batch(qs, k=K, c=C)
    for f in rw._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rg, f)), np.asarray(getattr(rw, f)),
            err_msg=f"query field {f!r} differs after restore")


def _spill_paths(d):
    return sorted(os.path.join(d, fn) for fn in os.listdir(d)
                  if fn.startswith("spill-"))


def _wal_paths(d):
    return sorted(os.path.join(d, fn) for fn in os.listdir(d)
                  if fn.startswith("wal-"))


def _truncate(path, keep=None):
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2 if keep is None else keep)


# ------------------------------------------------------------ round trips
@pytest.mark.parametrize("spec", ["f32", "bf16", "int8"])
def test_restore_is_bitwise_after_mutations(tmp_path, problem, spec):
    eng = _build(problem, spec)
    eng.attach_persister(IndexPersister(tmp_path))
    _mutate_a(eng, problem)
    _mutate_b(eng, problem)
    got = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(got, eng, problem)


def test_restore_after_rebuild_and_postspill_mutations(tmp_path, problem):
    """A rebuild spills the new epoch and rotates the WAL inside the
    locked swap, so mutations on either side of it land in exactly one
    durable point — the round-trip stays bitwise across the rotation."""
    eng = _build(problem)
    eng.attach_persister(IndexPersister(tmp_path))
    _mutate_a(eng, problem)
    eng.rebuild(reason="test")
    _mutate_b(eng, problem)
    assert len(_spill_paths(tmp_path)) == 2     # baseline + rebuild epoch
    got = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(got, eng, problem)
    # durability re-arms on the restored engine too
    got.attach_persister(IndexPersister(tmp_path))
    _mutate_b(got, problem)
    again = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(again, got, problem)


# -------------------------------------------------------- torn/corrupt IO
def test_torn_newest_spill_falls_back_to_previous_durable_point(
        tmp_path, problem):
    eng = _build(problem)
    eng.attach_persister(IndexPersister(tmp_path))
    _mutate_a(eng, problem)
    eng.rebuild(reason="test")                  # second durable point
    _truncate(_spill_paths(tmp_path)[-1])       # crash mid-spill
    # reference: the same lineage at the PREVIOUS durable point —
    # baseline + WAL replay of _mutate_a, no rebuild
    ref = _build(problem)
    _mutate_a(ref, problem)
    got = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(got, ref, problem)


def test_no_valid_spill_raises_rebuild_from_master(tmp_path, problem):
    eng = _build(problem)
    eng.attach_persister(IndexPersister(tmp_path))
    eng.rebuild(reason="test")
    for p in _spill_paths(tmp_path):
        _truncate(p, keep=len(SPILL_MAGIC) + 3)
    with pytest.raises(PersistError, match="rebuild from the master"):
        ReverseKRanksEngine.restore(tmp_path)


def test_torn_wal_tail_accepts_prefix(tmp_path, problem):
    """A crash mid-append tears the LAST record: the intact prefix is the
    durable point (accepted with a warning), the torn tail is dropped."""
    eng = _build(problem)
    eng.attach_persister(IndexPersister(tmp_path))
    _mutate_a(eng, problem)                     # prefix records
    eng.delete_users([4])                       # final record → torn
    wal = _wal_paths(tmp_path)[-1]
    _truncate(wal, keep=os.path.getsize(wal) - 5)
    ref = _build(problem)
    _mutate_a(ref, problem)
    got = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(got, ref, problem)


def test_corrupt_wal_interior_raises(tmp_path, problem):
    """Corruption with an INTACT record after it is not a torn tail — the
    op sequence is untrustworthy, and loading must refuse rather than
    replay around the hole."""
    eng = _build(problem)
    eng.attach_persister(IndexPersister(tmp_path))
    _mutate_a(eng, problem)                     # several records
    wal = _wal_paths(tmp_path)[-1]
    with open(wal, "r+b") as f:                 # flip a payload byte of
        f.seek(16 + 5)                          # record 0 (16 B header)
        b = f.read(1)
        f.seek(16 + 5)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(PersistError):
        ReverseKRanksEngine.restore(tmp_path)


def test_replay_divergence_raises(tmp_path, problem):
    """A WAL whose recorded insert ids disagree with what replay assigns
    is a corrupted/foreign log — refuse, never serve mismatched ids."""
    users, items = problem
    eng = _build(problem)
    p = IndexPersister(tmp_path)
    eng.attach_persister(p)
    eng.insert_items(items[:2] * 1.03)
    p.append("insert_items", {"vectors": np.asarray(items[2:3] * 1.01),
                              "ids": np.array([4242], np.int64)})
    with pytest.raises(PersistError, match="diverged"):
        ReverseKRanksEngine.restore(tmp_path)


def test_unknown_wal_op_rejected_at_append(tmp_path):
    p = IndexPersister(tmp_path)
    with pytest.raises(ValueError, match="unknown WAL op"):
        p.append("drop_everything", {})


# ------------------------------------------------------ injected failures
def test_wal_write_failure_degrades_then_spill_rearms(tmp_path, problem):
    """An injected WAL write error: serving continues, durability drops
    to the last spill (the failed-and-after mutations are NOT durable),
    and the next rebuild's spill re-arms logging."""
    eng = _build(problem)
    p = IndexPersister(tmp_path)
    eng.attach_persister(p)
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("persist.wal_write", mode="raise", max_fires=1)]))
    _mutate_a(eng, problem)                     # first append dies
    assert p._wal_broken
    assert p._m_wal_errors.value >= 1
    res = eng.query_batch(problem[1][:2], k=K, c=C)     # still serving
    assert np.all(np.asarray(res.r_lo) <= np.asarray(res.r_up))
    # the lost tail is EXPLICIT: restore sees only the baseline spill
    got = ReverseKRanksEngine.restore(tmp_path)
    assert got.current_snapshot().epoch == 0
    eng.rebuild(reason="re-baseline")           # spill re-arms the WAL
    assert not p._wal_broken
    _mutate_b(eng, problem)                     # durable again
    _assert_same_engine(ReverseKRanksEngine.restore(tmp_path), eng,
                        problem)


def test_injected_torn_spill_falls_back(tmp_path, problem):
    """The persist.spill torn-mode fault writes a half spill exactly as a
    crash mid-spill would; recovery detects it by checksum and falls back
    to the previous durable point."""
    eng = _build(problem)
    faults.install(faults.FaultPlan(seed=0, rules=[
        faults.FaultRule("persist.spill", mode="torn", after=1,
                         max_fires=1)]))
    eng.attach_persister(IndexPersister(tmp_path))  # baseline spill intact
    _mutate_a(eng, problem)
    eng.rebuild(reason="test")                  # this spill is torn
    ref = _build(problem)
    _mutate_a(ref, problem)
    got = ReverseKRanksEngine.restore(tmp_path)
    _assert_same_engine(got, ref, problem)
