"""Per-architecture smoke tests (deliverable f): each assigned arch
instantiates a REDUCED config of the same family and runs one forward /
train-loss / decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as TT
from repro.models.model import Model


def _batch_for(model, B=2, S=16):
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def models():
    return {a: Model(reduced(get_config(a))) for a in ARCH_IDS}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(models, arch):
    model = models[arch]
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(model)
    logits = model.forward_logits(params, batch["tokens"],
                                  frames=batch.get("frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, model.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_loss_and_grads_finite(models, arch):
    model = models[arch]
    params = model.init_params(jax.random.PRNGKey(2))
    batch = _batch_for(model, B=2, S=8)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # at least some gradient signal reaches the embedding table
    gsum = sum(float(jnp.abs(g).sum()) for g in flat)
    assert gsum > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(models, arch):
    model = models[arch]
    params = model.init_params(jax.random.PRNGKey(3))
    B, T = 2, 12
    cache = model.init_cache(B, T)
    enc = None
    if model.cfg.family == "encdec":
        enc = TT.encode(params, jax.random.normal(
            jax.random.PRNGKey(4), (B, model.cfg.enc_seq,
                                    model.cfg.d_model)), model.cfg)
        cache = TT.fill_cross_kv(params, cache, enc, model.cfg)
    tok_a = jnp.array([[5], [7]], jnp.int32)
    tok_b = jnp.array([[9], [3]], jnp.int32)
    logits, cache1 = model.decode_step(params, cache, tok_a)
    assert logits.shape == (B, 1, model.cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache1["len"]) == 1
    # context-dependence: logits for b after a ≠ logits for b with no context
    logits_b_ctx, cache2 = model.decode_step(params, cache1, tok_b)
    assert int(cache2["len"]) == 2
    fresh = model.init_cache(B, T)
    if model.cfg.family == "encdec":
        fresh = TT.fill_cross_kv(params, fresh, enc, model.cfg)
    logits_b_fresh, _ = model.decode_step(params, fresh, tok_b)
    assert not np.allclose(np.asarray(logits_b_ctx, np.float32),
                           np.asarray(logits_b_fresh, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(models, arch):
    """Greedy next-token from full-sequence forward == token-by-token
    decode through the cache (the serving-path correctness invariant).

    MoE needs a no-drop capacity factor here: with finite capacity the
    prefill path drops tokens that single-token decode never drops — an
    inherent property of capacity-based routing, not a bug."""
    import dataclasses
    model = models[arch]
    if model.cfg.family == "moe":
        nodrops = dataclasses.replace(model.cfg, capacity_factor=float(
            model.cfg.n_experts))
        model = Model(nodrops)
    cfg = model.cfg
    params = model.init_params(jax.random.PRNGKey(5))
    B, S = 1, 6
    batch = _batch_for(model, B=B, S=S)
    toks = batch["tokens"]
    full = model.forward_logits(params, toks, frames=batch.get("frames"))

    cache = model.init_cache(B, S + 2)
    if cfg.family == "encdec":
        enc = TT.encode(params, batch["frames"], cfg)
        cache = TT.fill_cross_kv(params, cache, enc, cfg)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.15, atol=0.15)   # bf16 path; argmax agreement checked below
    agree = (np.argmax(np.asarray(full, np.float32), -1)
             == np.argmax(np.asarray(dec, np.float32), -1)).mean()
    assert agree >= 0.8


def test_segments_cover_all_layers():
    for a in ARCH_IDS:
        cfg = get_config(a)
        total = sum(len(p) * r for p, r in cfg.segments())
        assert total == cfg.n_layers, a


def test_exact_published_dimensions():
    """The full configs carry the exact assigned hyper-parameters."""
    c = get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (48, 5120, 40, 8)
    assert (c.vocab, c.n_experts, c.experts_per_tok) == (202_048, 16, 1)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.experts_per_tok, c.d_ff) == (64, 6, 1408)
    c = get_config("recurrentgemma-9b")
    assert c.block_pattern == ("rglru", "rglru", "local_attn")
    assert (c.n_layers, c.vocab) == (38, 256_000)
    c = get_config("qwen3-32b")
    assert c.qk_norm and (c.n_layers, c.d_ff) == (64, 25_600)
    c = get_config("gemma-2b")
    assert (c.head_dim, c.n_kv_heads, c.act) == (256, 1, "geglu")
    c = get_config("whisper-medium")
    assert (c.n_enc_layers, c.vocab, c.enc_seq) == (24, 51_865, 1500)
    c = get_config("rwkv6-7b")
    assert (c.family, c.d_ff) == ("rwkv", 14_336)
    c = get_config("chameleon-34b")
    assert (c.d_model, c.vocab) == (8192, 65_536)
    c = get_config("phi3-medium-14b")
    assert (c.n_kv_heads, c.d_ff) == (10, 17_920)
    c = get_config("granite-3-8b")
    assert (c.n_heads, c.vocab) == (32, 49_155)
