"""Dynamic-index subsystem tests (the PR-3 tentpole, `repro.index`):
delta-buffer correctness, cross-backend delta-path parity, rebuild ==
scratch-build identity, snapshot hot-swap under concurrent serving, and
cache epoch invalidation.

Conventions follow tests/test_backends.py: queries are items perturbed
off the threshold grid, indices and the table-DERIVED bounds compare
exactly (the delta shift is an exact integer count, so it preserves
this), `est` compares at float accuracy across backends.

Problem sizes keep n and m divisible by 8 (also after the scripted
insert/delete churn) so the whole suite runs under the CI job that
forces 8 host devices — exercising the row-sharded delta correction and
the sharded end-to-end rebuild path.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core.engine import ReverseKRanksEngine
from repro.core.rank_table import build_rank_table
from repro.core.types import DeltaCorrection, RankTableConfig
from repro.index import MaintenanceLoop, MaintenancePolicy
from repro.serve import MicroBatcher, QueueFull
from tests.conftest import make_problem

ALL_BACKENDS = ("dense", "fused", "sharded")
K, C = 7, 2.0
N, M, D = 512, 400, 16
CFG = RankTableConfig(tau=16, omega=4, s=8)


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=N, m=M, d=D)


def fresh_engine(problem, backend="dense"):
    users, items = problem
    return ReverseKRanksEngine.build(users, items, CFG,
                                     jax.random.PRNGKey(1), backend=backend)


def off_grid_queries(items, B, seed=7):
    base = items[(1 + jnp.arange(B) * 13) % items.shape[0]]
    return base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(seed), base.shape, jnp.float32))


def churn(eng):
    """The scripted mutation sequence shared by the parity tests:
    inserts, base + fresh-item deletions, an upsert, a user deletion."""
    new = jax.random.normal(jax.random.PRNGKey(11), (16, D), jnp.float32)
    ids = eng.insert_items(new)
    eng.delete_items([3, 17, int(ids[1])])
    eng.upsert_users(
        jax.random.normal(jax.random.PRNGKey(12), (1, D), jnp.float32),
        indices=[5])
    eng.delete_users([9])
    return ids


# ---------------------------------------------------------- config guard
def test_rank_table_config_validation():
    """The threshold grid divides by tau-1 and the sampler needs omega/s
    >= 1: bad values must raise at CONSTRUCTION, not surface as NaN
    thresholds after an expensive build."""
    with pytest.raises(ValueError, match="tau must be >= 2"):
        RankTableConfig(tau=1)
    with pytest.raises(ValueError, match="omega must be >= 1"):
        RankTableConfig(omega=0)
    with pytest.raises(ValueError, match="s must be >= 1"):
        RankTableConfig(s=0)
    cfg = RankTableConfig(tau=2, omega=1, s=1)      # minimal legal config
    assert cfg.tau == 2


# ------------------------------------------------------- delta unit math
def test_delta_correction_counts_brute_force(problem):
    """`apply_delta_corrections` == the Definition-1 count shift, checked
    against a numpy brute force, including bucket padding (-inf rows
    count as zero) and the dead-user sentinel."""
    from repro.core.rank_table import apply_delta_corrections
    from repro.index.delta import _sorted_padded
    users, items = problem
    rng = np.random.default_rng(0)
    add = jnp.asarray(rng.normal(size=(5, D)), jnp.float32)
    dead = jnp.asarray(rng.normal(size=(3, D)), jnp.float32)
    qs = off_grid_queries(items, 4)
    scores = (users @ qs.T).astype(jnp.float32)
    r_lo = jnp.ones_like(scores) * 10.0
    r_up = jnp.ones_like(scores) * 30.0
    est = jnp.ones_like(scores) * 20.0
    live = jnp.ones((N,), bool).at[7].set(False)
    m_new = M - 3 + 5
    corr = DeltaCorrection(_sorted_padded(users @ add.T, 5),
                           _sorted_padded(users @ dead.T, 3),
                           live, jnp.asarray(m_new, jnp.int32))
    assert corr.add_scores.shape == (N, 8)      # padded to the 8-bucket
    g_lo, g_up, g_est = apply_delta_corrections(scores, r_lo, r_up, est,
                                                corr)
    sc = np.asarray(scores)
    cnt = ((np.asarray(users @ add.T)[:, :, None] > sc[:, None, :]).sum(1)
           - (np.asarray(users @ dead.T)[:, :, None] > sc[:, None, :])
           .sum(1))
    live_h = np.asarray(live)
    np.testing.assert_array_equal(
        np.asarray(g_lo)[live_h],
        np.clip(10.0 + cnt, 1, m_new + 1)[live_h])
    np.testing.assert_array_equal(
        np.asarray(g_up)[live_h],
        np.clip(30.0 + cnt, 1, m_new + 1)[live_h])
    np.testing.assert_array_equal(np.asarray(g_est)[7], np.full(4, np.inf))


def test_insert_shifts_bounds_exactly(problem):
    """Engine-level: after inserts the per-user bounds move by exactly
    the #{a : u·a > u·q} count (the Eq.-1 estimator is shifted, not
    re-estimated)."""
    users, items = problem
    eng = fresh_engine(problem)
    qs = off_grid_queries(items, 3)
    before = eng.query_batch(qs, k=K, c=C)
    new = jax.random.normal(jax.random.PRNGKey(21), (10, D), jnp.float32)
    eng.insert_items(new)
    after = eng.query_batch(qs, k=K, c=C)
    cnt = (np.asarray(users @ new.T)[:, :, None]
           > np.asarray((users @ qs.T).astype(jnp.float32))[:, None, :]
           ).sum(1)                                        # (n, B)
    want_lo = np.clip(np.asarray(before.r_lo) + cnt.T, 1, M + 10 + 1)
    want_up = np.clip(np.asarray(before.r_up) + cnt.T, 1, M + 10 + 1)
    np.testing.assert_array_equal(np.asarray(after.r_lo), want_lo)
    np.testing.assert_array_equal(np.asarray(after.r_up), want_up)


# ------------------------------------------------ (a) cross-backend parity
@pytest.mark.parametrize("B", [1, 16])
def test_delta_path_parity_across_backends(problem, B):
    """(a) After the scripted churn, delta-path results agree across
    dense/fused/sharded at B ∈ {1, 16}: indices and the k-th-bound
    statistics bitwise, est at float accuracy; dense vs fused also
    bitwise on the full (B, n) bound vectors (sharded returns (B, k·P)
    candidate-set bounds by contract)."""
    users, items = problem
    engines = {b: fresh_engine(problem, b) for b in ALL_BACKENDS}
    for eng in engines.values():
        churn(eng)
    qs = off_grid_queries(items, B)
    res = {b: engines[b].query_batch(qs, k=K, c=C) for b in ALL_BACKENDS}
    ref = res["dense"]
    assert engines["dense"].current_snapshot().corr is not None
    for b in ("fused", "sharded"):
        got = res[b]
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(ref.indices),
                                      err_msg=f"indices drift on {b}")
        np.testing.assert_array_equal(np.asarray(got.R_lo_k),
                                      np.asarray(ref.R_lo_k))
        np.testing.assert_array_equal(np.asarray(got.R_up_k),
                                      np.asarray(ref.R_up_k))
        np.testing.assert_allclose(np.asarray(got.est_rank),
                                   np.asarray(ref.est_rank), rtol=1e-5,
                                   atol=1e-4)
    np.testing.assert_array_equal(np.asarray(res["fused"].r_lo),
                                  np.asarray(ref.r_lo))
    np.testing.assert_array_equal(np.asarray(res["fused"].r_up),
                                  np.asarray(ref.r_up))
    # deleted user masked identically everywhere
    for b in ALL_BACKENDS:
        assert 9 not in np.asarray(res[b].indices)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_delta_query_is_batch_case_b1(problem, backend):
    """`query` stays the B = 1 case of `query_batch` on the delta path."""
    users, items = problem
    eng = fresh_engine(problem, backend)
    churn(eng)
    q = off_grid_queries(items, 1)[0]
    single = eng.query(q, k=K, c=C)
    batched = eng.query_batch(q[None, :], k=K, c=C)
    np.testing.assert_array_equal(np.asarray(single.indices),
                                  np.asarray(batched.indices[0]))
    np.testing.assert_array_equal(np.asarray(single.r_lo),
                                  np.asarray(batched.r_lo[0]))


# --------------------------------------------- (b) rebuild == from scratch
@pytest.mark.parametrize("backend", ["dense", "sharded"])
def test_insert_then_rebuild_equals_scratch(problem, backend):
    """(b) insert + delete then rebuild == building from scratch on the
    merged item set (same key): rank table bitwise, delta drained, query
    results identical. Runs the sharded end-to-end build path too (16
    inserts − 8 deletes keeps m divisible by 8 for the 8-device job)."""
    users, items = problem
    eng = fresh_engine(problem, backend)
    ids = eng.insert_items(
        jax.random.normal(jax.random.PRNGKey(31), (16, D), jnp.float32))
    eng.delete_items(list(range(8)))
    merged = eng.live_items()
    assert merged.shape[0] == M + 16 - 8
    rec = eng.rebuild()
    assert rec is not None and rec.epoch_after == eng.epoch
    snap = eng.current_snapshot()
    assert snap.delta.is_empty and snap.corr is None
    scratch = ReverseKRanksEngine.build(users, merged, CFG,
                                        jax.random.PRNGKey(1),
                                        backend=backend)
    np.testing.assert_array_equal(
        np.asarray(snap.rank_table.thresholds),
        np.asarray(scratch.rank_table.thresholds))
    np.testing.assert_array_equal(np.asarray(snap.rank_table.table),
                                  np.asarray(scratch.rank_table.table))
    assert int(snap.rank_table.m) == int(scratch.rank_table.m)
    qs = off_grid_queries(items, 4)
    got = eng.query_batch(qs, k=K, c=C)
    want = scratch.query_batch(qs, k=K, c=C)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    # inserted-item ids survive the rebuild as live ids
    assert set(ids) - set(eng.live_item_ids().tolist()) == set()


def test_rebuild_rebases_concurrent_mutations(problem):
    """Mutations that land while a rebuild is building are NOT lost: the
    swap re-bases them as a residual delta on the new epoch."""
    users, items = problem
    eng = fresh_engine(problem)
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(41), (8, D),
                                       jnp.float32))
    # user 5 upserted BEFORE the rebuild captures, and AGAIN mid-build:
    # the swap must keep the LATEST vector's row (a touched-set
    # difference would silently keep the capture-time row)
    eng.upsert_users(jax.random.normal(jax.random.PRNGKey(43), (1, D),
                                       jnp.float32), indices=[5])
    v_final = jax.random.normal(jax.random.PRNGKey(44), (1, D), jnp.float32)
    # interleave: capture what rebuild will build, then mutate mid-build
    # by monkeypatching the backend build hook to inject a mutation
    orig = eng._backend.build_index
    late_ids = []

    def slow_build(u, it, cfg, key):
        rt = orig(u, it, cfg, key)
        late_ids.append(eng.insert_items(
            jax.random.normal(jax.random.PRNGKey(42), (4, D), jnp.float32)))
        eng.delete_users([11])
        eng.upsert_users(v_final, indices=[5])
        return rt

    eng._backend.build_index = slow_build
    try:
        rec = eng.rebuild()
    finally:
        eng._backend.build_index = orig
    assert rec is not None
    snap = eng.current_snapshot()
    # the 8 pre-rebuild inserts are merged into the base; the 4 late ones
    # survive as residual delta; the late user deletion is still masked
    assert int(snap.rank_table.m) == M + 8
    assert snap.delta.n_added == 4
    assert set(late_ids[0]) <= set(eng.live_item_ids().tolist())
    res = eng.query_batch(off_grid_queries(items, 4), k=K, c=C)
    assert 11 not in np.asarray(res.indices)
    # user 5's row reflects v_final, not the capture-time vector
    np.testing.assert_array_equal(np.asarray(snap.users[5]),
                                  np.asarray(v_final[0]))
    from repro.core.rank_table import recompute_user_rows
    base = snap.base
    thr5, tab5 = recompute_user_rows(v_final, base.samples, base.weights,
                                     CFG, max_norm=base.max_norm)
    np.testing.assert_allclose(np.asarray(snap.rank_table.table)[5],
                               np.asarray(tab5)[0], rtol=1e-6, atol=0)


# ------------------------------------------------------------- user churn
def test_upsert_user_rows_match_scratch(problem):
    """An upserted user's threshold/table rows equal a from-scratch build
    on the modified user matrix (same key, same samples)."""
    users, items = problem
    eng = fresh_engine(problem)
    v = jax.random.normal(jax.random.PRNGKey(51), (1, D), jnp.float32)
    eng.upsert_users(v, indices=[5])
    users2 = users.at[5].set(v[0])
    rt2 = build_rank_table(users2, items, CFG, jax.random.PRNGKey(1))
    snap = eng.current_snapshot()
    np.testing.assert_allclose(np.asarray(snap.rank_table.thresholds),
                               np.asarray(rt2.thresholds), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(snap.rank_table.table),
                               np.asarray(rt2.table), rtol=1e-6, atol=0)
    # untouched rows are bit-identical (only row 5 was recomputed)
    mask = np.ones(N, bool)
    mask[5] = False
    np.testing.assert_array_equal(
        np.asarray(snap.rank_table.table)[mask],
        np.asarray(rt2.table)[mask])


def test_append_users_and_query(problem):
    users, items = problem
    eng = fresh_engine(problem)
    vecs = jax.random.normal(jax.random.PRNGKey(52), (3, D), jnp.float32)
    idx = eng.upsert_users(vecs)
    assert list(idx) == [N, N + 1, N + 2]
    assert eng.n == N + 3
    snap = eng.current_snapshot()
    assert snap.rank_table.thresholds.shape == (N + 3, CFG.tau)
    res = eng.query_batch(off_grid_queries(items, 4), k=K, c=C)
    assert res.indices.shape == (4, K)


def test_delete_users_masked_everywhere(problem):
    users, items = problem
    eng = fresh_engine(problem)
    qs = off_grid_queries(items, 4)
    before = eng.query_batch(qs, k=K, c=C)
    victim = int(np.asarray(before.indices)[0, 0])
    eng.delete_users([victim])
    after = eng.query_batch(qs, k=K, c=C)
    assert victim not in np.asarray(after.indices)
    # dead rows are pruned, never accepted
    assert np.all(np.isinf(np.asarray(after.r_lo)[:, victim]))


def test_dead_user_never_outranks_shifted_live_user():
    """Regression: a live user whose insertion-shifted estimate exceeds
    m'+1 must still outrank a deleted user — a FINITE dead sentinel
    (m'+2) loses to est = m_base+1+shift and can even pass the Lemma-1
    accept test when c·R↓_k exceeds it; the +inf sentinel cannot."""
    from repro.core.query import select_topk
    from repro.core.rank_table import apply_delta_corrections
    m_base, n_add, n_del = 10, 4, 2
    m_new = m_base - n_del + n_add                          # 12
    scores = jnp.zeros((3, 1), jnp.float32)
    # user 1: bottom-ranked (est = m_base+1 = 11) and beaten by all 4
    # inserted items → shifted est 15 > old sentinel m'+2 = 14
    corr = DeltaCorrection(
        add_scores=jnp.asarray([[-1.0] * 4, [1.0] * 4, [-1.0] * 4],
                               jnp.float32),
        del_scores=jnp.zeros((3, 0), jnp.float32),
        user_live=jnp.asarray([True, True, False]),
        m_new=jnp.asarray(m_new, jnp.int32))
    r_lo = jnp.asarray([[2.0], [10.0], [3.0]])
    r_up = jnp.asarray([[4.0], [11.0], [5.0]])
    est = jnp.asarray([[3.0], [11.0], [4.0]])
    g_lo, g_up, g_est = apply_delta_corrections(scores, r_lo, r_up, est,
                                                corr)
    assert float(g_est[1, 0]) == 15.0       # above the old finite sentinel
    res = select_topk(g_lo.T, g_up.T, g_est.T, k=2, c=2.0,
                      m_items=corr.m_new)
    assert 2 not in np.asarray(res.indices)         # dead user excluded
    np.testing.assert_array_equal(np.asarray(res.indices)[0],
                                  np.asarray([0, 1]))


# ----------------------------------------- (PR 6) user-row remap lineage
def test_compose_remaps_identity_and_absorption():
    """`compose_remaps` unit semantics: None is the identity segment on
    either side, and −1 (a row dropped by compaction) absorbs through any
    later remap — once gone, a row stays gone."""
    from repro.index.snapshot import compose_remaps
    first = np.asarray([2, -1, 0, 1], np.int64)
    assert compose_remaps(None, None) is None
    np.testing.assert_array_equal(compose_remaps(None, first), first)
    np.testing.assert_array_equal(compose_remaps(first, None), first)
    second = np.asarray([1, -1, 0], np.int64)   # intermediate has 3 rows
    np.testing.assert_array_equal(compose_remaps(first, second),
                                  np.asarray([0, -1, 1, -1], np.int64))


def test_compact_then_reorder_composes_remap(problem):
    """Regression (PR 6): a compacting rebuild FOLLOWED by further
    compaction/reorder must COMPOSE the published `user_remap`, not
    replace it. The invariant checked at every epoch: for each
    lineage-original row still alive, `snap.users[remap[orig]]` is the
    original vector bitwise, dropped rows stay −1 forever, and
    `client_user_ids` translates query indices back to the coordinates an
    unremapped reference engine answers in.

    Uses the exact-threshold grid so `est` is continuous: sampled grids
    quantize est into genuine ties whose index tie-break is
    layout-dependent, which would make the cross-layout index comparison
    vacuous (see tests/test_pruning.py::test_reordered_parity). Queries
    are sub-scale random directions rather than hot items for the same
    reason: an item that ≥ 2 users rank exactly #1 clips both ests to
    the rank floor 1.0 — a genuine tie even on the exact grid.
    """
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, threshold_mode="exact")
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1))
    dead = list(range(0, N, 3))                 # 171/512 ≈ 33% tombstoned
    eng.delete_users(dead)
    rec = eng.rebuild(compact_dead_above=0.2, reorder_clusters=True)
    assert rec is not None and rec.users_compacted == len(dead)
    snap = eng.current_snapshot()
    remap = snap.user_remap
    assert remap is not None and remap.shape == (N,)
    assert np.all(remap[dead] == -1)
    alive = np.setdiff1d(np.arange(N), dead)
    # survivors hit every compacted coordinate exactly once, carrying
    # their original vector through compaction AND the k-means reorder
    assert np.array_equal(np.sort(remap[alive]), np.arange(alive.size))
    np.testing.assert_array_equal(np.asarray(snap.users)[remap[alive]],
                                  np.asarray(users)[alive])

    # query translation: an unremapped reference (dead rows masked, never
    # compacted) must agree index-for-index after client_user_ids
    ref = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1))
    ref.delete_users(dead)
    qs = 0.5 * jax.random.normal(jax.random.PRNGKey(7), (4, D),
                                 jnp.float32)
    got = eng.query_batch(qs, k=K, c=C)
    want = ref.query_batch(qs, k=K, c=C)
    # per-user bounds are row-wise ops — bitwise layout-invariant
    np.testing.assert_array_equal(
        np.asarray(got.r_lo)[:, remap[alive]],
        np.asarray(want.r_lo)[:, alive])
    np.testing.assert_array_equal(
        np.asarray(got.r_up)[:, remap[alive]],
        np.asarray(want.r_up)[:, alive])
    orig_ids = snap.client_user_ids(np.asarray(got.indices))
    np.testing.assert_array_equal(orig_ids, np.asarray(want.indices))
    np.testing.assert_array_equal(remap[orig_ids],
                                  np.asarray(got.indices))
    assert not np.isin(orig_ids, np.asarray(dead)).any()

    # epoch 2: tombstone more rows IN CURRENT COORDINATES and compact
    # again — the new remap must compose onto the lineage, not reset it
    n1 = snap.n
    dead2_cur = np.arange(0, n1, 5)
    dead2_orig = snap.client_user_ids(dead2_cur)
    eng.delete_users(dead2_cur.tolist())
    rec2 = eng.rebuild(compact_dead_above=0.1, reorder_clusters=True)
    assert rec2 is not None and rec2.users_compacted == dead2_cur.size
    snap2 = eng.current_snapshot()
    remap2 = snap2.user_remap
    assert remap2.shape == (N,)                 # still lineage-original
    assert np.all(remap2[dead] == -1)           # −1 absorbed through
    assert np.all(remap2[dead2_orig] == -1)
    alive2 = np.flatnonzero(remap2 >= 0)
    assert alive2.size == N - len(dead) - dead2_cur.size
    assert np.array_equal(np.sort(remap2[alive2]),
                          np.arange(alive2.size))
    np.testing.assert_array_equal(np.asarray(snap2.users)[remap2[alive2]],
                                  np.asarray(users)[alive2])

    # a rebuild that neither compacts nor reorders CARRIES the remap
    rec3 = eng.rebuild()
    assert rec3 is not None and rec3.users_compacted == 0
    np.testing.assert_array_equal(eng.current_snapshot().user_remap,
                                  remap2)


# --------------------------------------------------- stats + maintenance
def test_delta_stats_and_stale_weight(problem):
    eng = fresh_engine(problem)
    st = eng.delta_stats()
    assert st.delta_ratio == 0.0 and st.stale_weight == 0.0
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(61), (8, D),
                                       jnp.float32))
    # delete an item that the build SAMPLED: its stratum weight becomes
    # stale estimator mass (the error-budget trigger)
    sampled_id = int(eng.current_snapshot().base.sample_ids[0])
    eng.delete_items([sampled_id])
    st = eng.delta_stats()
    assert st.n_added == 8 and st.n_deleted == 1
    assert st.delta_ratio == pytest.approx(9 / M)
    assert st.stale_weight > 0.0
    assert st.m_live == M + 8 - 1


def test_maintenance_loop_triggers_rebuild(problem):
    eng = fresh_engine(problem)
    policy = MaintenancePolicy(max_delta_ratio=0.03)
    with MaintenanceLoop(eng, policy=policy, poll_ms=5.0) as ml:
        eng.insert_items(jax.random.normal(jax.random.PRNGKey(71),
                                           (24, D), jnp.float32))
        ml.wake()
        deadline = time.monotonic() + 60
        while not ml.rebuilds and time.monotonic() < deadline:
            time.sleep(0.01)
    assert ml.rebuilds, "maintenance loop never rebuilt"
    rec = ml.rebuilds[0]
    assert "delta_ratio" in rec.reason
    assert eng.delta_stats().delta_ratio == 0.0
    assert int(eng.current_snapshot().rank_table.m) == M + 24


def test_engine_without_items_rejects_item_mutations(problem):
    users, items = problem
    rt = build_rank_table(users, items, CFG, jax.random.PRNGKey(1))
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=CFG)
    with pytest.raises(ValueError, match="base item set"):
        eng.insert_items(jnp.zeros((1, D)))
    with pytest.raises(ValueError, match="base item set"):
        eng.rebuild()
    eng.delete_users([3])                     # mask-only: allowed
    res = eng.query_batch(off_grid_queries(items, 2), k=K, c=C)
    assert 3 not in np.asarray(res.indices)


# ------------------------------------- (c) hot-swap under live scheduling
@pytest.mark.concurrency
def test_swap_under_load_never_mixes_epochs(problem):
    """(c) A snapshot hot-swap concurrent with in-flight MicroBatcher
    submissions: zero dropped futures, every future resolves bitwise
    against EXACTLY one epoch's reference, and every tick is pinned to
    one epoch."""
    users, items = problem
    eng = fresh_engine(problem)
    qs = off_grid_queries(items, 8)
    snap0 = eng.current_snapshot()
    # a high-norm insert moves many users' counts, so the two epochs are
    # distinguishable on every query
    new = 4.0 * jax.random.normal(jax.random.PRNGKey(81), (6, D),
                                  jnp.float32)

    results, errors = [], []

    def submitter(mb, stop):
        i = 0
        while not stop.is_set():
            try:
                f = mb.submit(qs[i % 8], K, C)
                results.append((i % 8, f))
            except Exception as e:             # pragma: no cover - fail loud
                errors.append(e)
                return
            i += 1
            time.sleep(0.001)

    with MicroBatcher(eng, max_batch=4, max_wait_ms=2.0) as mb:
        stop = threading.Event()
        t = threading.Thread(target=submitter, args=(mb, stop))
        t.start()
        try:
            while len(mb.tick_log) < 3:        # epoch-0 traffic flowing
                time.sleep(0.005)
            eng.insert_items(new)              # the hot swap
            snap1 = eng.current_snapshot()
            deadline = time.monotonic() + 60
            while (not any(t_.epoch == snap1.epoch for t_ in mb.tick_log)
                   and time.monotonic() < deadline):
                time.sleep(0.005)
        finally:
            stop.set()
            t.join()
        resolved = [(qi, f.result(timeout=120)) for qi, f in results]
        log = mb.tick_log
    assert not errors
    assert sum(t_.batch for t_ in log) == len(resolved)   # zero dropped

    ref0 = jax.device_get(eng.query_batch_at(snap0, qs, K, C))
    ref1 = jax.device_get(eng.query_batch_at(snap1, qs, K, C))
    # epochs must be distinguishable for "exactly one" to mean anything
    for i in range(8):
        assert not np.array_equal(np.asarray(ref0.r_lo[i]),
                                  np.asarray(ref1.r_lo[i]))

    def matches(res, ref, i):
        return all(np.array_equal(np.asarray(getattr(res, f)),
                                  np.asarray(getattr(ref, f)[i]))
                   for f in ("indices", "r_lo", "r_up", "R_lo_k", "R_up_k"))

    seen = {snap0.epoch: 0, snap1.epoch: 0}
    for qi, res in resolved:
        m0, m1 = matches(res, ref0, qi), matches(res, ref1, qi)
        assert m0 != m1, f"future for query {qi} torn between epochs"
        seen[snap0.epoch if m0 else snap1.epoch] += 1
    assert seen[snap0.epoch] > 0 and seen[snap1.epoch] > 0
    epochs = [t_.epoch for t_ in log]
    assert epochs == sorted(epochs)            # ticks never roll back
    assert set(epochs) == {snap0.epoch, snap1.epoch}


# --------------------------------------- (d) cache epoch invalidation
def test_cache_stale_epoch_hits_are_zero(problem):
    """(d) After a swap, the hit rate for stale-epoch keys is exactly 0:
    every pre-swap entry misses and is recomputed on the new epoch."""
    users, items = problem
    eng = fresh_engine(problem, "cached:dense")
    ref = fresh_engine(problem, "dense")
    cache = eng._backend
    qs = off_grid_queries(items, 6)
    eng.query_batch(qs, k=K, c=C)              # fill
    h0 = cache.hits
    eng.query_batch(qs, k=K, c=C)
    assert cache.hits - h0 == 6                # warm within the epoch
    for mutate in (
            lambda: eng.insert_items(jax.random.normal(
                jax.random.PRNGKey(91), (4, D), jnp.float32)),
            lambda: eng.delete_users([2]),
            lambda: eng.rebuild()):
        mutate()
        h = cache.hits
        got = eng.query_batch(qs, k=K, c=C)
        assert cache.hits == h, "stale-epoch cache hit served post-swap"
        ref._snapshots = eng._snapshots        # same state, uncached
        want = ref.query_batch(qs, k=K, c=C)
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(want.indices))
    # and warm again within the new epoch
    h = cache.hits
    eng.query_batch(qs, k=K, c=C)
    assert cache.hits - h == 6


# ----------------------------------------------------- back-pressure
def test_microbatcher_backpressure(problem):
    """`max_depth` admission: past the bound submits fail fast with
    QueueFull, accepted futures all resolve, and the rejection count +
    high-watermark surface in the stats."""
    users, items = problem
    eng = fresh_engine(problem)

    class SlowEngine:
        def query_batch(self, qs, k, c):
            time.sleep(0.05)
            return eng.query_batch(qs, k=k, c=c)

    qs = off_grid_queries(items, 8)
    rejected = 0
    with MicroBatcher(SlowEngine(), max_batch=2, max_wait_ms=1.0,
                      max_depth=3) as mb:
        futs = []
        for i in range(30):
            try:
                futs.append(mb.submit(qs[i % 8], K, C))
            except QueueFull:
                rejected += 1
        for f in futs:
            assert f.result(timeout=120).indices.shape == (K,)
        st = mb.stats()
        log = mb.tick_log
    assert rejected > 0
    assert st.rejected == rejected
    assert st.depth_hwm <= 3
    assert sum(t.rejected for t in log) <= rejected   # rest pre-first-tick
    assert st.requests == len(futs)

    with pytest.raises(ValueError, match="max_depth"):
        MicroBatcher(eng, max_depth=0)


# ------------------------------------------- sharded build-path routing
def test_sharded_build_routes_through_build_sharded(problem, monkeypatch):
    """`build(backend="sharded")` and maintenance-triggered rebuilds run
    Algorithm 1 through `distributed.build_sharded` (row-sharded
    end-to-end), and the resulting table matches the dense build."""
    from repro.core import distributed as dist
    users, items = problem
    calls = []
    orig = dist.build_sharded

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(dist, "build_sharded", counting)
    eng = fresh_engine(problem, "sharded")
    assert len(calls) == 1
    dense_rt = build_rank_table(users, items, CFG, jax.random.PRNGKey(1))
    np.testing.assert_allclose(
        np.asarray(eng.current_snapshot().rank_table.table),
        np.asarray(dense_rt.table), rtol=1e-6, atol=1e-6)
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(95), (16, D),
                                       jnp.float32))
    eng.delete_items(list(range(8)))
    eng.rebuild()                 # same path for maintenance rebuilds
    assert len(calls) == 2
    assert int(eng.current_snapshot().rank_table.m) == M + 8


def test_sharded_mutation_shape_guards(problem):
    """Churn off the mesh multiple must not wedge the sharded backend:
    rebuilds over a non-divisible live m fall back to the dense build
    (instead of an opaque shard_map error on every maintenance retry),
    and an append that would break n-divisibility fails fast with a
    clear error BEFORE publishing."""
    users, items = problem
    eng = fresh_engine(problem, "sharded")
    P = jax.device_count()
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(97), (3, D),
                                       jnp.float32))
    rec = eng.rebuild()           # m = M+3: not divisible for P > 1
    assert rec is not None
    assert int(eng.current_snapshot().rank_table.m) == M + 3
    res = eng.query_batch(off_grid_queries(items, 4), k=K, c=C)
    assert res.indices.shape == (4, K)
    if P > 1:
        with pytest.raises(ValueError, match="divisible by the mesh"):
            eng.upsert_users(jax.random.normal(jax.random.PRNGKey(98),
                                               (1, D), jnp.float32))
        assert eng.n == N         # nothing published by the failed append
    eng.upsert_users(jax.random.normal(jax.random.PRNGKey(99), (P, D),
                                       jnp.float32))   # mesh-multiple: ok
    assert eng.n == N + P


# ------------------------------------- residuals across a reordering swap
def test_residual_remapped_through_compact_reorder_lineage(problem):
    """Satellite (PR 7): `residual_after_rebuild` composed with a
    compacting + cluster-reordering rebuild. Items inserted MID-BUILD
    survive the swap as a residual delta, and the re-materialized
    correction rows must live in the PUBLISHED user layout — i.e. be
    remapped through the composed `user_remap` (compact→reorder
    lineage). Checked bitwise two ways: against a from-scratch engine
    built on the published user matrix (same layout, same late inserts),
    and row-by-row through the remap against a never-compacted reference
    in original coordinates. Nothing exercised residuals across a
    reordering swap before this test."""
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, threshold_mode="exact")
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1))
    dead = list(range(0, N, 3))                 # ≈ 33% tombstoned
    eng.delete_users(dead)

    late_vecs = jax.random.normal(jax.random.PRNGKey(83), (8, D),
                                  jnp.float32)
    orig = eng._backend.build_index
    late_ids = []

    def slow_build(u, it, cfg_, key):
        rt = orig(u, it, cfg_, key)
        late_ids.append(eng.insert_items(late_vecs))   # lands mid-build
        return rt

    eng._backend.build_index = slow_build
    try:
        rec = eng.rebuild(compact_dead_above=0.2, reorder_clusters=True)
    finally:
        eng._backend.build_index = orig
    assert rec is not None and rec.users_compacted == len(dead)
    assert rec.users_reordered

    snap = eng.current_snapshot()
    remap = snap.user_remap
    alive = np.setdiff1d(np.arange(N), dead)
    assert snap.delta.n_added == 8              # residual survived the swap
    assert snap.corr is not None
    assert snap.corr.add_scores.shape[0] == alive.size   # NEW layout rows
    assert bool(np.all(np.asarray(snap.corr.user_live)))
    assert set(late_ids[0]) <= set(eng.live_item_ids().tolist())

    # (a) from-scratch build over the PUBLISHED matrix + the same late
    # inserts: the residual correction must be bitwise identical — both
    # sides materialize it from the same (layout, vectors) pair
    ref = ReverseKRanksEngine.build(jnp.asarray(snap.users), items, cfg,
                                    jax.random.PRNGKey(1))
    ref.insert_items(late_vecs)
    ref_corr = ref.current_snapshot().corr
    np.testing.assert_array_equal(np.asarray(snap.corr.add_scores),
                                  np.asarray(ref_corr.add_scores))
    assert int(snap.corr.selection_m()) == int(ref_corr.selection_m())

    # (b) remap lineage: row remap[i] of the published correction is
    # original user i's correction row, per a never-compacted reference
    # holding the same residual in ORIGINAL coordinates
    ref2 = ReverseKRanksEngine.build(users, items, cfg,
                                     jax.random.PRNGKey(1))
    ref2.delete_users(dead)
    ref2.insert_items(late_vecs)
    ref2_corr = ref2.current_snapshot().corr
    np.testing.assert_array_equal(
        np.asarray(snap.corr.add_scores)[remap[alive]],
        np.asarray(ref2_corr.add_scores)[alive])

    # (c) end-to-end: residual-corrected queries translate back to the
    # reference engine's answers through client_user_ids
    qs = 0.5 * jax.random.normal(jax.random.PRNGKey(7), (4, D),
                                 jnp.float32)
    got = eng.query_batch(qs, k=K, c=C)
    want = ref2.query_batch(qs, k=K, c=C)
    np.testing.assert_array_equal(
        np.asarray(got.r_lo)[:, remap[alive]],
        np.asarray(want.r_lo)[:, alive])
    np.testing.assert_array_equal(
        np.asarray(got.r_up)[:, remap[alive]],
        np.asarray(want.r_up)[:, alive])
    orig_ids = snap.client_user_ids(np.asarray(got.indices))
    np.testing.assert_array_equal(orig_ids, np.asarray(want.indices))
