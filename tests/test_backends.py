"""Backend registry + batched-execution parity (the PR-1 tentpole).

On every backend `query` IS the B = 1 case of `query_batch`, so
batched-vs-per-query parity directly checks that the table-bandwidth-
amortized path computes the same §4.3 selection as per-query execution.

Comparison contract: indices and the table-DERIVED bounds (r↓/r↑ are
gathered table entries, integer-valued in rank space) must match exactly;
the interpolated estimate `est` is continuous in the score u·q, whose low
bits legitimately differ between an (n,d)×(d,1) and an (n,d)×(d,B)
matmul, so est compares at float accuracy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core.engine import ReverseKRanksEngine
from repro.core.query import query as core_query
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig
from tests.conftest import make_problem

# "pruned"/"pruned:fused" ride the full parity matrix: per-query phase-A
# masking makes even their materialized (B, n) bound arrays (skip
# sentinels included) independent of batch-mates, so every comparison
# below holds bitwise. "pruned:sharded" returns (B, k·P) candidate-SET
# bounds whose tail is batch-dependent — its (relaxed to selected
# outputs) parity lives in tests/test_pruning.py.
ALL_BACKENDS = ("dense", "fused", "sharded", "pruned", "pruned:fused")
K = 7


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)


@pytest.fixture(scope="module")
def regimes(problem):
    """(rank_table, c) pairs pinning both Lemma-1 cases.

    guaranteed:     exact-mode table (tight bounds) + generous c
                    ⇒ c·R↓_k ≥ R↑_k, selection is pure-est ordering.
    non_guaranteed: coarse sampled table + c = 1
                    ⇒ accept/prune masks and the U_temp fill engage.
    """
    users, items = problem
    exact_cfg = RankTableConfig(tau=128, omega=4, s=items.shape[0] // 4,
                                threshold_mode="exact")
    coarse_cfg = RankTableConfig(tau=16, omega=4, s=8)
    return {
        "guaranteed": (exact_cfg,
                       build_rank_table(users, items, exact_cfg,
                                        jax.random.PRNGKey(0)), 4.0),
        "non_guaranteed": (coarse_cfg,
                           build_rank_table(users, items, coarse_cfg,
                                            jax.random.PRNGKey(1)), 1.0),
    }


def _engine(problem, regimes, regime, backend):
    users, _ = problem
    cfg, rt, c = regimes[regime]
    return ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                               backend=backend), c


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("B", [1, 3, 16])
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_query_batch_matches_per_query(problem, regimes, backend, B, regime):
    users, items = problem
    eng, c = _engine(problem, regimes, regime, backend)
    # Slightly perturbed item queries: an exact item query scores exactly
    # on the exact-mode table's threshold endpoints, where a 1-ulp matmul
    # difference legitimately flips the bucketize by one cell. A 1e-4
    # relative perturbation stays in-distribution (regimes unchanged) but
    # moves every score ~1e3 ulps off the threshold grid, making the bound
    # lookup exactly reproducible across batch shapes.
    base = items[(1 + jnp.arange(B) * 17) % items.shape[0]]
    qs = base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(100 + B), base.shape, jnp.float32))
    batched = eng.query_batch(qs, k=K, c=c)
    assert batched.indices.shape == (B, K)
    # the regime fixture really pins the Lemma-1 case (guaranteed is a
    # per-query property: the tight-table/generous-c regime closes the
    # search for every query; the coarse/c=1 regime leaves at least the
    # anchor query open so the accept/prune/U_temp path is exercised)
    if regime == "guaranteed":
        assert bool(np.all(np.asarray(batched.guaranteed)))
    else:
        assert not bool(np.asarray(batched.guaranteed)[0])
    for b in range(B):
        single = eng.query(qs[b], k=K, c=c)
        np.testing.assert_array_equal(np.asarray(batched.indices[b]),
                                      np.asarray(single.indices))
        np.testing.assert_array_equal(np.asarray(batched.r_lo[b]),
                                      np.asarray(single.r_lo))
        np.testing.assert_array_equal(np.asarray(batched.r_up[b]),
                                      np.asarray(single.r_up))
        assert float(batched.R_lo_k[b]) == float(single.R_lo_k)
        assert float(batched.R_up_k[b]) == float(single.R_up_k)
        np.testing.assert_allclose(np.asarray(batched.est_rank[b]),
                                   np.asarray(single.est_rank), rtol=1e-5,
                                   atol=1e-4)
        assert int(batched.n_accepted[b]) == int(single.n_accepted)
        assert int(batched.n_pruned[b]) == int(single.n_pruned)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_backends_agree_with_core(problem, regimes, backend, regime):
    """Every backend's per-query result matches the core reference path."""
    users, items = problem
    eng, c = _engine(problem, regimes, regime, backend)
    for qi in (3, 99):
        q = items[qi]
        got = eng.query(q, k=K, c=c)
        want = core_query(eng.rank_table, users, q, K, c)
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(want.indices))
        np.testing.assert_allclose(np.asarray(got.est_rank),
                                   np.asarray(want.est_rank), rtol=1e-5,
                                   atol=1e-4)
        assert float(got.R_lo_k) == float(want.R_lo_k)
        assert float(got.R_up_k) == float(want.R_up_k)


def test_registry_lists_and_errors():
    names = BK.available_backends()
    for name in ALL_BACKENDS:
        # wrapper specs ("pruned:fused") resolve but list only by prefix
        assert name.partition(":")[0] in names
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("no-such-backend")
    assert ReverseKRanksEngine.backends() == names


def test_registry_custom_backend(problem):
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(5))

    @BK.register_backend("test-dense-alias")
    class AliasBackend(BK.DenseBackend):
        pass

    try:
        eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                                  backend="test-dense-alias")
        assert eng.backend_name == "test-dense-alias"
        ref = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg)
        q = items[11]
        np.testing.assert_array_equal(
            np.asarray(eng.query(q, k=K, c=2.0).indices),
            np.asarray(ref.query(q, k=K, c=2.0).indices))
    finally:
        BK._REGISTRY.pop("test-dense-alias", None)


def test_backend_instance_passthrough(problem, regimes):
    """An already-built backend object is accepted as `backend=`."""
    users, items = problem
    cfg, rt, c = regimes["non_guaranteed"]
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=BK.DenseBackend())
    assert eng.backend_name == "dense"
    res = eng.query_batch(items[:3], k=K, c=c)
    assert res.indices.shape == (3, K)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("B", [1, 16])
@pytest.mark.parametrize("spec", ["float32", "bfloat16", "int8"])
def test_storage_spec_parity_matrix(problem, spec, backend, B):
    """PR-5 parity matrix: at EVERY storage spec, query_batch is the B=1
    case of query (batch-shape independence), and every backend selects
    identically to the dense backend on the same quantized index — the
    dequant-aware bound path is shared, so backends cannot drift. (f32
    bit-parity against the pre-refactor goldens and bf16/int8 certified
    containment live in tests/test_storage.py.)"""
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, storage_dtype=spec)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(1))
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=backend)
    dense = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg)
    base = items[(1 + jnp.arange(B) * 17) % items.shape[0]]
    qs = base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(100 + B), base.shape, jnp.float32))
    batched = eng.query_batch(qs, k=K, c=1.0)
    want = dense.query_batch(qs, k=K, c=1.0)
    np.testing.assert_array_equal(np.asarray(batched.indices),
                                  np.asarray(want.indices))
    # dequantized order statistics compare at float accuracy across
    # program shapes (FMA contraction is shape-dependent); exact for f32
    tol = dict(rtol=0) if spec == "float32" else dict(rtol=1e-6)
    np.testing.assert_allclose(np.asarray(batched.R_lo_k),
                               np.asarray(want.R_lo_k), **tol)
    np.testing.assert_allclose(np.asarray(batched.R_up_k),
                               np.asarray(want.R_up_k), **tol)
    for b in range(B):
        single = eng.query(qs[b], k=K, c=1.0)
        np.testing.assert_array_equal(np.asarray(batched.indices[b]),
                                      np.asarray(single.indices))
        if spec == "float32":
            # gathered table entries: exact across batch shapes
            np.testing.assert_array_equal(np.asarray(batched.r_lo[b]),
                                          np.asarray(single.r_lo))
            np.testing.assert_array_equal(np.asarray(batched.r_up[b]),
                                          np.asarray(single.r_up))
        else:
            # dequantized bounds (code·scale + offset − widen): XLA may
            # or may not contract the multiply-add into an FMA depending
            # on the program shape — float accuracy, not bitwise
            np.testing.assert_allclose(np.asarray(batched.r_lo[b]),
                                       np.asarray(single.r_lo), rtol=1e-6)
            np.testing.assert_allclose(np.asarray(batched.r_up[b]),
                                       np.asarray(single.r_up), rtol=1e-6)


@pytest.mark.parametrize("backend", ["dense", "fused"])
def test_bound_ranks_orientation(problem, regimes, backend):
    """`QueryBackend.bound_ranks` returns (B, n) query-major arrays that
    bracket each other."""
    users, items = problem
    cfg, rt, _ = regimes["non_guaranteed"]
    bk = BK.get_backend(backend)
    qs = items[:4]
    r_lo, r_up, est = bk.bound_ranks(rt, users, qs)
    n = users.shape[0]
    assert r_lo.shape == r_up.shape == est.shape == (4, n)
    assert bool(jnp.all(r_lo <= r_up + 1e-5))
    assert bool(jnp.all(est <= r_up + 1e-5))
