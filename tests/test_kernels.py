"""Per-kernel validation: pallas_call (interpret=True) vs ref.py oracles,
swept over shapes and dtypes, plus integration vs repro.core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.query import lookup_bounds, query
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTable, RankTableConfig
from repro.kernels import ops, ref
from tests.conftest import make_problem


def _table_for(users, items, tau, key=0):
    cfg = RankTableConfig(tau=tau, omega=4, s=16)
    return build_rank_table(users, items, cfg, jax.random.PRNGKey(key))


# ---------------------------------------------------------------- user_scores
@pytest.mark.parametrize("n,d,tau", [
    (256, 128, 128),       # exact tile multiples
    (300, 200, 100),       # paper-ish d/τ, ragged n and τ (padding path)
    (1024, 64, 500),       # paper τ
    (64, 32, 7),           # tiny, heavy padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bound_ranks_kernel_vs_ref(n, d, tau, dtype):
    users, items = make_problem(jax.random.PRNGKey(n + tau), n, 300, d,
                                dtype=dtype)
    rt = _table_for(users.astype(jnp.float32), items.astype(jnp.float32), tau)
    q = items[1]
    got = ops.bound_ranks(users, q, rt.thresholds, rt.table, m=int(rt.m))
    want = ref.ref_bound_ranks(users, q, rt.thresholds, rt.table, int(rt.m))
    # r_lo/r_up gather table entries (exact given the same bucketize); est
    # interpolates with frac = (score - t_j)/span, which divides a ~1-ulp
    # matmul-schedule difference (kernel row blocks vs one ref matmul) by
    # a span that shrinks as 1/τ — at τ=500 that amplifies to ~1e-4 in
    # rank units, so est gets a wider f32 absolute band than the bounds.
    for g, w, name, atol32 in zip(got, want, ("r_lo", "r_up", "est"),
                                  (1e-4, 1e-4, 1e-3)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=2.0 if dtype == jnp.bfloat16 else atol32,
                                   err_msg=name)


def test_bound_ranks_matches_core_lookup():
    """Kernel path ≡ core.query.lookup_bounds on float32 (same bucketize)."""
    users, items = make_problem(jax.random.PRNGKey(5), 500, 400, 48)
    rt = _table_for(users, items, 200)
    q = items[9]
    uq = (users @ q).astype(jnp.float32)
    want = lookup_bounds(rt, uq)
    got = ops.bound_ranks(users, q, rt.thresholds, rt.table, m=int(rt.m))
    # est gets a wider absolute band than the bounds: the interpolation
    # frac divides ~1-ulp score-schedule differences by the τ-fine span
    # (see test_bound_ranks_kernel_vs_ref).
    for g, w, atol in zip(got, want, (1e-4, 1e-4, 1e-3)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5,
                                   atol=atol)


def test_query_fused_selection_matches_core():
    users, items = make_problem(jax.random.PRNGKey(6), 800, 600, 32)
    rt = _table_for(users, items, 128)
    q = items[17]
    a = query(rt, users, q, k=13, c=2.0)
    b = ops.query_fused(rt, users, q, k=13, c=2.0)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_allclose(np.asarray(a.est_rank),
                               np.asarray(b.est_rank), rtol=1e-5)


# ---------------------------------------------------------------- table_build
@pytest.mark.parametrize("n,d,S,tau", [
    (128, 128, 64, 128),
    (200, 200, 40, 100),   # ragged everything
    (384, 64, 96, 33),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_table_build_kernel_vs_ref(n, d, S, tau, dtype):
    key = jax.random.PRNGKey(n + S)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    users = jax.random.normal(k1, (n, d), jnp.float32).astype(dtype)
    samples = jax.random.normal(k2, (S, d), jnp.float32).astype(dtype)
    weights = jax.random.uniform(k3, (S,), jnp.float32, 0.5, 3.0)
    thresholds = jnp.sort(
        jax.random.normal(k4, (n, tau), jnp.float32) * d ** 0.5, axis=1)
    got = ops.build_table_rows(users, samples, weights, thresholds)
    want = ref.ref_table_rows(users, samples, weights, thresholds)
    # bf16 inputs round scores; near-threshold indicators may flip, so allow
    # a small absolute rank slack; f32 must match to float accuracy.
    if dtype == jnp.bfloat16:
        assert np.mean(np.abs(np.asarray(got) - np.asarray(want))) < 3.0
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-3)


def test_table_build_matches_core_estimator():
    """Kernel ≡ core.rank_table.estimate_table_rows (sort+suffix path)."""
    from repro.core.rank_table import estimate_table_rows
    key = jax.random.PRNGKey(77)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n, d, S, tau = 100, 50, 32, 64
    users = jax.random.normal(k1, (n, d))
    samples = jax.random.normal(k2, (S, d))
    weights = jax.random.uniform(k3, (S,), minval=1.0, maxval=2.0)
    thresholds = jnp.sort(jax.random.normal(k4, (n, tau)) * 7.0, axis=1)
    got = ops.build_table_rows(users, samples, weights, thresholds)
    scores = (users @ samples.T).astype(jnp.float32)
    want = estimate_table_rows(scores, weights, thresholds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-3)


# ---------------------------------------------------------------- exact_rank
@pytest.mark.parametrize("n,m,d", [
    (256, 512, 64),        # exact multiples
    (300, 700, 100),       # ragged n and m (zero-row padding correction)
    (64, 100, 200),        # paper d
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exact_rank_kernel_vs_ref(n, m, d, dtype):
    users, items = make_problem(jax.random.PRNGKey(m + d), n, m, d,
                                dtype=dtype)
    q = items[2]
    got = ops.exact_ranks(users, items, q)
    want = 1.0 + ref.ref_exact_counts(users, items, q)
    if dtype == jnp.bfloat16:
        # bf16 rounds u·p; ranks shift only at near-ties.
        assert np.mean(np.abs(np.asarray(got) - np.asarray(want))) < 2.0
    else:
        # q ∈ P ⇒ a mathematical tie at the self-item; different matmul
        # tilings (kernel blocks vs one ref matmul) round it either way.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1.0)


def test_exact_rank_kernel_vs_core(small_problem):
    from repro.core.exact import exact_ranks as core_exact
    users, items = small_problem
    # Random q (∉ P): no structural tie, so the two schedules agree almost
    # everywhere (residual near-ties are rounding-level rare).
    q = jax.random.normal(jax.random.PRNGKey(123), items[0].shape)
    got = np.asarray(ops.exact_ranks(users, items, q))
    want = np.asarray(core_exact(users, items, q)).astype(np.float32)
    assert np.mean(np.abs(got - want)) < 0.05
    assert np.max(np.abs(got - want)) <= 1.0

    # q ∈ P: every user carries a mathematical self-tie; each schedule may
    # round it either way, so ranks agree only to the ±1 tie band.
    q2 = items[4]
    got2 = np.asarray(ops.exact_ranks(users, items, q2))
    want2 = np.asarray(core_exact(users, items, q2)).astype(np.float32)
    assert np.max(np.abs(got2 - want2)) <= 1.0


# ------------------------------------------------------------------ property
try:  # optional test extra — `pip install repro[test]` (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

if given is not None:
    @given(n=st.integers(16, 300), tau=st.integers(3, 140),
           seed=st.integers(0, 1000))
    @settings(max_examples=15, deadline=None)
    def test_bound_ranks_property(n, tau, seed):
        """Kernel == oracle for arbitrary ragged shapes (padding invariance).

        The kernel pads users/τ and computes u·q per 256-row block; a score
        landing within 1 ulp of a threshold can bucketize ±1 vs the unpadded
        oracle matvec, shifting that user's bound by one table cell. Allow a
        vanishing fraction of such tie flips; everything else must be exact.
        """
        users, items = make_problem(jax.random.PRNGKey(seed), n, 64, 24)
        rt = _table_for(users, items, tau, key=seed)
        q = items[seed % 64]
        got = ops.bound_ranks(users, q, rt.thresholds, rt.table, m=int(rt.m))
        want = ref.ref_bound_ranks(users, q, rt.thresholds, rt.table,
                                   int(rt.m))
        for g, w in zip(got, want):
            d = np.abs(np.asarray(g) - np.asarray(w))
            exact = d <= 1e-4 + 1e-5 * np.abs(np.asarray(w))
            assert exact.mean() >= 1.0 - 2.0 / n, \
                f"{(~exact).sum()} mismatches of {n}"

else:
    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_bound_ranks_property():
        pass
