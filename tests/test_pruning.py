"""Block-pruned query execution (the PR-4 tentpole, `repro.core.pruning`).

Parity contract (matching tests/test_backends.py): `"pruned:<inner>"`
must return BIT-IDENTICAL selected indices and table-derived statistics
(R↓_k / R↑_k, integer-valued in rank space) to the UNPRUNED inner
backend on every case — both Lemma-1 regimes, B ∈ {1, 16}, static and
mutated indexes. `est_rank` compares at float accuracy: est is
continuous in the score u·q, whose LOW BITS legitimately differ between
the full-matrix matmul and the gathered kept-row matmul (same reason
batched-vs-single est differs repo-wide). The full r↓/r↑ arrays carry
the skip sentinel for pruned users and the n_accepted/n_pruned
diagnostics count sentinels, so those compare only within the pruned
backend itself, where per-query masking makes them B-independent.

Problem geometry: users are drawn from cluster-contiguous Gaussian
blobs, so summary blocks are coherent and phase A genuinely prunes
(asserted); the adversarial case uses i.i.d. users where every block
looks alike and the keep-everything fallback must engage. Sizes keep n
divisible by 8 shards × block_size so the suite also runs under the CI
job forcing 8 host devices (per-shard summaries + the pruned tree-merge).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core import pruning as PR
from repro.core.engine import ReverseKRanksEngine
from repro.core.query import lookup_bounds_batch
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig

INNERS = ("dense", "fused", "sharded")
K, BS = 7, 64                   # small block size so n=2048 has 32 blocks
N, M, D, NCL = 2048, 512, 16, 16
CFG_COARSE = RankTableConfig(tau=16, omega=4, s=8)


def clustered_problem(key, n=N, m=M, d=D, n_clusters=NCL, spread=0.1):
    """Cluster-contiguous users (the block-coherent favorable case)."""
    kc, ku, ki, kn = jax.random.split(key, 4)
    centers = jax.random.normal(kc, (n_clusters, d), jnp.float32) * 2.0
    assign = jnp.arange(n) * n_clusters // n        # contiguous, any n
    users = (centers[assign]
             + spread * jax.random.normal(ku, (n, d), jnp.float32))
    items = (centers[jax.random.randint(ki, (m,), 0, n_clusters)]
             + spread * jax.random.normal(kn, (m, d), jnp.float32))
    return users, items


@pytest.fixture(scope="module")
def problem():
    return clustered_problem(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def regimes(problem):
    """(cfg, rank_table, c) pinning both Lemma-1 cases (cf.
    tests/test_backends.py)."""
    users, items = problem
    exact_cfg = RankTableConfig(tau=64, omega=4, s=M // 4,
                                threshold_mode="exact")
    # clustered rank distributions are heavy-tailed, so closing the
    # search (c·R↓_k ≥ R↑_k) for EVERY query needs a generous c
    return {
        "guaranteed": (exact_cfg,
                       build_rank_table(users, items, exact_cfg,
                                        jax.random.PRNGKey(0)), 32.0),
        "non_guaranteed": (CFG_COARSE,
                           build_rank_table(users, items, CFG_COARSE,
                                            jax.random.PRNGKey(1)), 1.0),
    }


def off_grid_queries(items, B, seed=7):
    # offset 18: item 1 happens to close the coarse-table search even at
    # c = 1 on the clustered problem; starting at 18 keeps the anchor
    # query (and the B = 1 case) in the non-guaranteed regime
    base = items[(18 + jnp.arange(B) * 17) % items.shape[0]]
    return base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(seed), base.shape, jnp.float32))


def pruned_engine(users, rt, cfg, inner, **knobs):
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=f"pruned:{inner}")
    eng._backend.block_size = knobs.pop("block_size", BS)
    for k, v in knobs.items():
        setattr(eng._backend, k, v)
    return eng


def assert_selected_parity(got, want):
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    np.testing.assert_allclose(np.asarray(got.est_rank),
                               np.asarray(want.est_rank), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got.R_lo_k),
                                  np.asarray(want.R_lo_k))
    np.testing.assert_array_equal(np.asarray(got.R_up_k),
                                  np.asarray(want.R_up_k))
    np.testing.assert_array_equal(np.asarray(got.guaranteed),
                                  np.asarray(want.guaranteed))


# ------------------------------------------------------------ summaries
def test_envelopes_certify_members(problem, regimes):
    """Every user's (r↓, r↑) must lie inside its block's phase-A
    envelope bounds — the invariant all pruning correctness rests on."""
    users, _ = problem
    _, rt, _ = regimes["non_guaranteed"]
    summ = PR.build_block_summary(users, rt, block_size=BS)
    qs = off_grid_queries(problem[1], 8)
    scores = (users @ qs.T).astype(jnp.float32)
    r_lo, r_up, _ = lookup_bounds_batch(rt, scores)         # (n, B)
    r_lo_opt, r_up_pes = PR._envelope_bounds(summ, qs)      # (nb, B)
    r_lo, r_up = np.asarray(r_lo), np.asarray(r_up)
    lo_env, up_env = np.asarray(r_lo_opt), np.asarray(r_up_pes)
    for blk in range(summ.n_blocks):
        rows = slice(blk * BS, min((blk + 1) * BS, N))
        assert np.all(lo_env[blk] <= r_lo[rows].min(axis=0) + 1e-6)
        assert np.all(up_env[blk] >= r_up[rows].max(axis=0) - 1e-6)


def test_rhat_bounds_true_Rupk(problem, regimes):
    users, _ = problem
    _, rt, c = regimes["non_guaranteed"]
    summ = PR.build_block_summary(users, rt, block_size=BS)
    qs = off_grid_queries(problem[1], 8)
    _, r_hat = PR.phase_a(summ, qs, k=K, block_size=BS)
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    true_up = np.asarray(ref.query_batch(qs, k=K, c=c).R_up_k)
    assert np.all(np.asarray(r_hat) >= true_up - 1e-6)


def test_tail_block_summary():
    """n not a multiple of block_size: the partial tail block's rows
    count is exact and parity still holds."""
    users, items = clustered_problem(jax.random.PRNGKey(3), n=1000, m=256)
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    summ = PR.build_block_summary(users, rt, block_size=BS)
    rows = np.asarray(summ.rows)
    assert rows.sum() == 1000 and rows[-1] == 1000 - (1000 // BS) * BS
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    eng = pruned_engine(users, rt, CFG_COARSE, "dense",
                        max_union_frac=1.1)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


# ------------------------------------------------------- static parity
@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("B", [1, 16])
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_pruned_matches_inner(problem, regimes, inner, B, regime):
    users, items = problem
    cfg, rt, c = regimes[regime]
    ref = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=inner)
    eng = pruned_engine(users, rt, cfg, inner)
    qs = off_grid_queries(items, B)
    want = ref.query_batch(qs, k=K, c=c)
    got = eng.query_batch(qs, k=K, c=c)
    if regime == "guaranteed":
        assert bool(np.all(np.asarray(want.guaranteed)))
    else:
        assert not bool(np.asarray(want.guaranteed)[0])
    assert_selected_parity(got, want)
    st = eng._backend.stats
    assert st.n_blocks == N // BS
    # single-query == batched column (per-query masking makes the pruned
    # result independent of its batch-mates)
    one = eng.query(qs[0], k=K, c=c)
    np.testing.assert_array_equal(np.asarray(one.indices),
                                  np.asarray(got.indices[0]))
    np.testing.assert_allclose(np.asarray(one.est_rank),
                               np.asarray(got.est_rank[0]), rtol=1e-5,
                               atol=1e-4)


def test_pruning_actually_skips(problem, regimes):
    """Clustered users + clustered queries: phase A must certify real
    skips (the whole point), and phase B must still be exact."""
    users, items = problem
    cfg, rt, c = regimes["non_guaranteed"]
    eng = pruned_engine(users, rt, cfg, "dense")
    ref = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg)
    # queries from ONE cluster → the union keep set stays small
    qs = items[:8] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(5), (8, D), jnp.float32))
    assert_selected_parity(eng.query_batch(qs, k=K, c=c),
                           ref.query_batch(qs, k=K, c=c))
    st = eng._backend.stats
    assert st.fallback in ("", "dense")
    assert st.kept_per_query < 0.8          # per-query pruning engaged
    if not st.fallback:
        assert st.kept_union < st.n_blocks


def test_adversarial_all_blocks_survive():
    """i.i.d. users: every block looks alike, phase A keeps everything,
    and the dense fallback dispatches the inner backend unpruned."""
    from tests.conftest import make_problem
    users, items = make_problem(jax.random.PRNGKey(9), n=1024, m=256, d=D)
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    eng = pruned_engine(users, rt, CFG_COARSE, "dense")
    qs = off_grid_queries(items, 8)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    st = eng._backend.stats
    assert st.fallback == "dense" and st.kept_per_query > 0.5
    # forcing phase B past the fallback must still be exact
    eng2 = pruned_engine(users, rt, CFG_COARSE, "dense",
                         max_union_frac=1.1)
    assert_selected_parity(eng2.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    assert eng2._backend.stats.fallback == ""


# -------------------------------------------------------- delta parity
def churn(eng):
    new = jax.random.normal(jax.random.PRNGKey(11), (16, D), jnp.float32)
    ids = eng.insert_items(new)
    eng.delete_items([3, 17, int(ids[1])])
    eng.delete_users([9, N - 100])
    return ids


@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("B", [1, 16])
def test_delta_path_parity(problem, inner, B):
    users, items = problem
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1), backend=inner)
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend=f"pruned:{inner}")
    eng._backend.block_size = BS
    churn(ref)
    churn(eng)
    qs = off_grid_queries(items, B)
    want = ref.query_batch(qs, k=K, c=1.0)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert eng._backend.stats.fallback in ("", "dense")
    assert_selected_parity(got, want)


def test_delta_guard_falls_back_to_full_scan(problem):
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    big = jax.random.normal(jax.random.PRNGKey(5), (M // 3, D),
                            jnp.float32)          # |delta|/m > guard 0.25
    eng.insert_items(big)
    ref.insert_items(big)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    assert eng._backend.stats.fallback == "delta-guard"


def test_dead_users_never_selected(problem):
    """Deleting a would-be winner: the pruned path must exclude it via
    the live-count-aware R̂ seed exactly like the full scan."""
    users, items = problem
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    qs = off_grid_queries(items, 4)
    winners = np.unique(np.asarray(ref.query_batch(qs, k=K, c=1.0).indices))
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref.delete_users(winners[:3].tolist())
    eng.delete_users(winners[:3].tolist())
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    assert not np.isin(winners[:3], np.asarray(got.indices)).any()


# ------------------------------------------------- lifecycle / registry
def test_rebuild_regenerates_summaries(problem):
    """A rebuild hot-swap changes the index generation; the summary
    cache must miss and rebuild over the new arrays (identity-keyed)."""
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    bk = eng._backend
    snap0 = eng.current_snapshot()
    s0 = bk.summary_for(snap0.rank_table, snap0.users)
    assert bk.summary_for(snap0.rank_table, snap0.users) is s0  # cached
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(2), (8, D)))
    eng.rebuild(reason="test")
    snap1 = eng.current_snapshot()
    s1 = bk.summary_for(snap1.rank_table, snap1.users)
    assert s1 is not s0
    assert int(s1.m) == int(snap1.rank_table.m) == M + 8
    # queries on the rebuilt index still parity-exact
    ref = ReverseKRanksEngine(users=snap1.users,
                              rank_table=snap1.rank_table,
                              config=CFG_COARSE)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


def test_upsert_users_regenerates_summaries(problem):
    """User mutations change the user-array identity without a rebuild —
    the stale box would mis-certify the upserted row's scores."""
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    vec = 3.0 * jax.random.normal(jax.random.PRNGKey(13), (1, D))
    eng.upsert_users(vec, indices=[100])
    ref.upsert_users(vec, indices=[100])
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


def test_registry_and_engine_spec():
    assert "pruned" in BK.available_backends()
    bk = BK.get_backend("pruned")
    assert isinstance(bk, BK.PrunedBackend)
    assert bk.inner.name == "dense"
    assert BK.get_backend("pruned:fused").inner.name == "fused"
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("pruned:no-such-inner")


def test_sharded_alignment_fallback(problem):
    """Tiles straddling shard boundaries are refused up front: the
    sharded inner runs unpruned rather than mis-gathering."""
    users, items = problem
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    eng = pruned_engine(users, rt, CFG_COARSE, "sharded",
                        block_size=3 * BS)  # n % (P·bs) != 0 for any P>1
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE, backend="sharded")
    qs = off_grid_queries(items, 4)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    if jax.device_count() > 1:
        assert eng._backend.stats.fallback == "align"
