"""Block-pruned query execution (the PR-4 tentpole, `repro.core.pruning`).

Parity contract (matching tests/test_backends.py): `"pruned:<inner>"`
must return BIT-IDENTICAL selected indices and table-derived statistics
(R↓_k / R↑_k, integer-valued in rank space) to the UNPRUNED inner
backend on every case — both Lemma-1 regimes, B ∈ {1, 16}, static and
mutated indexes. `est_rank` compares at float accuracy: est is
continuous in the score u·q, whose LOW BITS legitimately differ between
the full-matrix matmul and the gathered kept-row matmul (same reason
batched-vs-single est differs repo-wide). The full r↓/r↑ arrays carry
the skip sentinel for pruned users and the n_accepted/n_pruned
diagnostics count sentinels, so those compare only within the pruned
backend itself, where per-query masking makes them B-independent.

Problem geometry: users are drawn from cluster-contiguous Gaussian
blobs, so summary blocks are coherent and phase A genuinely prunes
(asserted); the adversarial case uses i.i.d. users where every block
looks alike and the keep-everything fallback must engage. Sizes keep n
divisible by 8 shards × block_size so the suite also runs under the CI
job forcing 8 host devices (per-shard summaries + the pruned tree-merge).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as BK
from repro.core import pruning as PR
from repro.core.engine import ReverseKRanksEngine
from repro.core.query import lookup_bounds_batch
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig

INNERS = ("dense", "fused", "sharded")
K, BS = 7, 64                   # small block size so n=2048 has 32 blocks
N, M, D, NCL = 2048, 512, 16, 16
CFG_COARSE = RankTableConfig(tau=16, omega=4, s=8)


def clustered_problem(key, n=N, m=M, d=D, n_clusters=NCL, spread=0.1):
    """Cluster-contiguous users (the block-coherent favorable case)."""
    kc, ku, ki, kn = jax.random.split(key, 4)
    centers = jax.random.normal(kc, (n_clusters, d), jnp.float32) * 2.0
    assign = jnp.arange(n) * n_clusters // n        # contiguous, any n
    users = (centers[assign]
             + spread * jax.random.normal(ku, (n, d), jnp.float32))
    items = (centers[jax.random.randint(ki, (m,), 0, n_clusters)]
             + spread * jax.random.normal(kn, (m, d), jnp.float32))
    return users, items


@pytest.fixture(scope="module")
def problem():
    return clustered_problem(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def regimes(problem):
    """(cfg, rank_table, c) pinning both Lemma-1 cases (cf.
    tests/test_backends.py)."""
    users, items = problem
    exact_cfg = RankTableConfig(tau=64, omega=4, s=M // 4,
                                threshold_mode="exact")
    # clustered rank distributions are heavy-tailed, so closing the
    # search (c·R↓_k ≥ R↑_k) for EVERY query needs a generous c
    return {
        "guaranteed": (exact_cfg,
                       build_rank_table(users, items, exact_cfg,
                                        jax.random.PRNGKey(0)), 32.0),
        "non_guaranteed": (CFG_COARSE,
                           build_rank_table(users, items, CFG_COARSE,
                                            jax.random.PRNGKey(1)), 1.0),
    }


def off_grid_queries(items, B, seed=7):
    # offset 18: item 1 happens to close the coarse-table search even at
    # c = 1 on the clustered problem; starting at 18 keeps the anchor
    # query (and the B = 1 case) in the non-guaranteed regime
    base = items[(18 + jnp.arange(B) * 17) % items.shape[0]]
    return base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(seed), base.shape, jnp.float32))


def pruned_engine(users, rt, cfg, inner, **knobs):
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=f"pruned:{inner}")
    eng._backend.block_size = knobs.pop("block_size", BS)
    for k, v in knobs.items():
        setattr(eng._backend, k, v)
    return eng


def assert_selected_parity(got, want):
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    np.testing.assert_allclose(np.asarray(got.est_rank),
                               np.asarray(want.est_rank), rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got.R_lo_k),
                                  np.asarray(want.R_lo_k))
    np.testing.assert_array_equal(np.asarray(got.R_up_k),
                                  np.asarray(want.R_up_k))
    np.testing.assert_array_equal(np.asarray(got.guaranteed),
                                  np.asarray(want.guaranteed))


# ------------------------------------------------------------ summaries
def test_envelopes_certify_members(problem, regimes):
    """Every user's (r↓, r↑) must lie inside its block's phase-A
    envelope bounds — the invariant all pruning correctness rests on."""
    users, _ = problem
    _, rt, _ = regimes["non_guaranteed"]
    summ = PR.build_block_summary(users, rt, block_size=BS)
    qs = off_grid_queries(problem[1], 8)
    scores = (users @ qs.T).astype(jnp.float32)
    r_lo, r_up, _ = lookup_bounds_batch(rt, scores)         # (n, B)
    r_lo_opt, r_up_pes = PR._envelope_bounds(summ, qs)      # (nb, B)
    r_lo, r_up = np.asarray(r_lo), np.asarray(r_up)
    lo_env, up_env = np.asarray(r_lo_opt), np.asarray(r_up_pes)
    for blk in range(summ.n_blocks):
        rows = slice(blk * BS, min((blk + 1) * BS, N))
        assert np.all(lo_env[blk] <= r_lo[rows].min(axis=0) + 1e-6)
        assert np.all(up_env[blk] >= r_up[rows].max(axis=0) - 1e-6)


def test_rhat_bounds_true_Rupk(problem, regimes):
    users, _ = problem
    _, rt, c = regimes["non_guaranteed"]
    summ = PR.build_block_summary(users, rt, block_size=BS)
    qs = off_grid_queries(problem[1], 8)
    _, r_hat = PR.phase_a(summ, qs, k=K, block_size=BS)
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    true_up = np.asarray(ref.query_batch(qs, k=K, c=c).R_up_k)
    assert np.all(np.asarray(r_hat) >= true_up - 1e-6)


def test_tail_block_summary():
    """n not a multiple of block_size: the partial tail block's rows
    count is exact and parity still holds."""
    users, items = clustered_problem(jax.random.PRNGKey(3), n=1000, m=256)
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    summ = PR.build_block_summary(users, rt, block_size=BS)
    rows = np.asarray(summ.rows)
    assert rows.sum() == 1000 and rows[-1] == 1000 - (1000 // BS) * BS
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    eng = pruned_engine(users, rt, CFG_COARSE, "dense",
                        max_union_frac=1.1)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


# ------------------------------------------------------- static parity
@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("B", [1, 16])
@pytest.mark.parametrize("regime", ["guaranteed", "non_guaranteed"])
def test_pruned_matches_inner(problem, regimes, inner, B, regime):
    users, items = problem
    cfg, rt, c = regimes[regime]
    ref = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend=inner)
    eng = pruned_engine(users, rt, cfg, inner)
    qs = off_grid_queries(items, B)
    want = ref.query_batch(qs, k=K, c=c)
    got = eng.query_batch(qs, k=K, c=c)
    if regime == "guaranteed":
        assert bool(np.all(np.asarray(want.guaranteed)))
    else:
        assert not bool(np.asarray(want.guaranteed)[0])
    assert_selected_parity(got, want)
    st = eng._backend.stats
    assert st.n_blocks == N // BS
    # single-query == batched column (per-query masking makes the pruned
    # result independent of its batch-mates)
    one = eng.query(qs[0], k=K, c=c)
    np.testing.assert_array_equal(np.asarray(one.indices),
                                  np.asarray(got.indices[0]))
    np.testing.assert_allclose(np.asarray(one.est_rank),
                               np.asarray(got.est_rank[0]), rtol=1e-5,
                               atol=1e-4)


def test_pruning_actually_skips(problem, regimes):
    """Clustered users + clustered queries: phase A must certify real
    skips (the whole point), and phase B must still be exact."""
    users, items = problem
    cfg, rt, c = regimes["non_guaranteed"]
    eng = pruned_engine(users, rt, cfg, "dense")
    ref = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg)
    # queries from ONE cluster → the union keep set stays small
    qs = items[:8] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(5), (8, D), jnp.float32))
    assert_selected_parity(eng.query_batch(qs, k=K, c=c),
                           ref.query_batch(qs, k=K, c=c))
    st = eng._backend.stats
    assert st.fallback in ("", "dense")
    assert st.kept_per_query < 0.8          # per-query pruning engaged
    if not st.fallback:
        assert st.kept_union < st.n_blocks


def test_adversarial_all_blocks_survive():
    """i.i.d. users: every block looks alike, phase A keeps everything,
    and the dense fallback dispatches the inner backend unpruned."""
    from tests.conftest import make_problem
    users, items = make_problem(jax.random.PRNGKey(9), n=1024, m=256, d=D)
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE)
    eng = pruned_engine(users, rt, CFG_COARSE, "dense")
    qs = off_grid_queries(items, 8)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    st = eng._backend.stats
    assert st.fallback == "dense" and st.kept_per_query > 0.5
    # forcing phase B past the fallback must still be exact
    eng2 = pruned_engine(users, rt, CFG_COARSE, "dense",
                         max_union_frac=1.1)
    assert_selected_parity(eng2.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    assert eng2._backend.stats.fallback == ""


# -------------------------------------------------------- delta parity
def churn(eng):
    new = jax.random.normal(jax.random.PRNGKey(11), (16, D), jnp.float32)
    ids = eng.insert_items(new)
    eng.delete_items([3, 17, int(ids[1])])
    eng.delete_users([9, N - 100])
    return ids


@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("B", [1, 16])
def test_delta_path_parity(problem, inner, B):
    users, items = problem
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1), backend=inner)
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend=f"pruned:{inner}")
    eng._backend.block_size = BS
    churn(ref)
    churn(eng)
    qs = off_grid_queries(items, B)
    want = ref.query_batch(qs, k=K, c=1.0)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert eng._backend.stats.fallback in ("", "dense")
    assert_selected_parity(got, want)


def test_delta_guard_falls_back_to_full_scan(problem):
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    big = jax.random.normal(jax.random.PRNGKey(5), (M // 3, D),
                            jnp.float32)          # |delta|/m > guard 0.25
    eng.insert_items(big)
    ref.insert_items(big)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))
    assert eng._backend.stats.fallback == "delta-guard"


def test_dead_users_never_selected(problem):
    """Deleting a would-be winner: the pruned path must exclude it via
    the live-count-aware R̂ seed exactly like the full scan."""
    users, items = problem
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    qs = off_grid_queries(items, 4)
    winners = np.unique(np.asarray(ref.query_batch(qs, k=K, c=1.0).indices))
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref.delete_users(winners[:3].tolist())
    eng.delete_users(winners[:3].tolist())
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    assert not np.isin(winners[:3], np.asarray(got.indices)).any()


# ------------------------------------------------- lifecycle / registry
def test_rebuild_regenerates_summaries(problem):
    """A rebuild hot-swap changes the index generation; the summary
    cache must miss and rebuild over the new arrays (identity-keyed)."""
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    bk = eng._backend
    snap0 = eng.current_snapshot()
    s0 = bk.summary_for(snap0.rank_table, snap0.users)
    assert bk.summary_for(snap0.rank_table, snap0.users) is s0  # cached
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(2), (8, D)))
    eng.rebuild(reason="test")
    snap1 = eng.current_snapshot()
    s1 = bk.summary_for(snap1.rank_table, snap1.users)
    assert s1 is not s0
    assert int(s1.m) == int(snap1.rank_table.m) == M + 8
    # queries on the rebuilt index still parity-exact
    ref = ReverseKRanksEngine(users=snap1.users,
                              rank_table=snap1.rank_table,
                              config=CFG_COARSE)
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


def test_upsert_users_regenerates_summaries(problem):
    """User mutations change the user-array identity without a rebuild —
    the stale box would mis-certify the upserted row's scores."""
    users, items = problem
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    ref = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1))
    vec = 3.0 * jax.random.normal(jax.random.PRNGKey(13), (1, D))
    eng.upsert_users(vec, indices=[100])
    ref.upsert_users(vec, indices=[100])
    qs = off_grid_queries(items, 4)
    assert_selected_parity(eng.query_batch(qs, k=K, c=1.0),
                           ref.query_batch(qs, k=K, c=1.0))


def test_registry_and_engine_spec():
    assert "pruned" in BK.available_backends()
    bk = BK.get_backend("pruned")
    assert isinstance(bk, BK.PrunedBackend)
    assert bk.inner.name == "dense"
    assert BK.get_backend("pruned:fused").inner.name == "fused"
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("pruned:no-such-inner")


# --------------------------------------- geometry sketches (PR 6)
SPECS = ("float32", "bfloat16", "int8")


def test_cone_envelopes_tighter_than_box(problem, regimes):
    """The cone∩box envelope is an INTERSECTION: never looser than the
    box alone in rank space, and measurably tighter on clustered blocks
    (the mechanism the PR 6 speedup rests on)."""
    users, items = problem
    _, rt, _ = regimes["non_guaranteed"]
    box = PR.build_block_summary(users, rt, block_size=BS,
                                 with_cones=False)
    cone = PR.build_block_summary(users, rt, block_size=BS)
    assert box.norm_min is None and cone.norm_min is not None
    # μ̂ rows are unit (or exactly 0 — the vacuous cone) and every
    # member's norm sits inside its block's band
    mu_n = np.linalg.norm(np.asarray(cone.mu), axis=1)
    assert np.all((np.abs(mu_n - 1.0) < 1e-5) | (mu_n == 0.0))
    norms = np.linalg.norm(np.asarray(users, np.float32), axis=1)
    for blk in range(cone.n_blocks):
        rows = slice(blk * BS, min((blk + 1) * BS, N))
        assert np.asarray(cone.norm_min)[blk, 0] <= norms[rows].min() + 1e-5
        assert np.asarray(cone.norm_max)[blk, 0] >= norms[rows].max() - 1e-5
    qs = off_grid_queries(items, 8)
    lo_b, up_b = (np.asarray(a) for a in PR._envelope_bounds(box, qs))
    lo_c, up_c = (np.asarray(a) for a in PR._envelope_bounds(cone, qs))
    assert np.all(lo_c >= lo_b - 1e-6) and np.all(up_c <= up_b + 1e-6)
    assert (up_c - lo_c).mean() < (up_b - lo_b).mean()


def _assert_block_containment(summ, r_lo, r_up, lo_env, up_env, n,
                              widen_lo=0.0, widen_up=0.0):
    r_lo, r_up = np.asarray(r_lo), np.asarray(r_up)
    for blk in range(summ.n_blocks):
        rows = slice(blk * BS, min((blk + 1) * BS, n))
        assert np.all(lo_env[blk] - widen_lo
                      <= r_lo[rows].min(axis=0) + 1e-6)
        assert np.all(up_env[blk] + widen_up
                      >= r_up[rows].max(axis=0) - 1e-6)


@pytest.mark.parametrize("spec", SPECS)
def test_cone_band_containment_every_spec(problem, spec):
    """Cone+band envelopes bracket every member's dequant-aware (r↓, r↑)
    at every StorageSpec, and keep bracketing the delta-corrected bounds
    once widened by the phase-A (n_add, n_del) terms — the PR 5 → PR 6
    composition the docstring proof claims."""
    from repro.core.query import user_scores_batch
    from repro.core.rank_table import apply_delta_corrections
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, storage_dtype=spec)
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense")
    eng._backend.block_size = BS
    qs = off_grid_queries(items, 8)

    def member_bounds(snap, corr=None):
        su = snap.query_users()
        scores, slack = user_scores_batch(su, qs)
        r_lo, r_up, est = lookup_bounds_batch(snap.rank_table, scores,
                                              slack)
        if corr is not None:
            r_lo, r_up, est = apply_delta_corrections(
                scores, r_lo, r_up, est, corr, slack)
        return r_lo, r_up

    snap = eng.current_snapshot()
    summ = PR.build_block_summary(snap.query_users(), snap.rank_table,
                                  block_size=BS)
    lo_env, up_env = (np.asarray(a)
                      for a in PR._envelope_bounds(summ, qs))
    r_lo, r_up = member_bounds(snap)
    _assert_block_containment(summ, r_lo, r_up, lo_env, up_env, N)

    # item churn: the corrected bounds shift by at most (+n_add, −n_del),
    # exactly the widening phase A applies to the STATIC envelopes
    eng.insert_items(jax.random.normal(jax.random.PRNGKey(3), (12, D),
                                       jnp.float32))
    eng.delete_items([5, 29, 131])
    snap2 = eng.current_snapshot()
    assert snap2.corr is not None
    r_lo_c, r_up_c = member_bounds(snap2, corr=snap2.corr)
    n_add, n_del = snap2.delta.n_added, snap2.delta.n_deleted
    assert n_add == 12 and n_del == 3
    _assert_block_containment(summ, r_lo_c, r_up_c, lo_env, up_env, N,
                              widen_lo=n_del, widen_up=n_add)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           spec=st.sampled_from(SPECS),
           block_size=st.sampled_from([32, 64]),
           scale=st.floats(0.1, 10.0))
    def test_cone_band_containment_property(seed, spec, block_size,
                                            scale):
        """Random problems × specs × block sizes × data scales: the
        cone+band envelopes must contain the true per-block (r↓, r↑)
        range — including blocks holding near-antipodal or near-zero
        rows, where the cone math has its branch points."""
        from repro.core.query import user_scores_batch
        key = jax.random.PRNGKey(seed)
        ku, ki, kz, kq = jax.random.split(key, 4)
        n, m, d = 192, 96, 8
        users = scale * jax.random.normal(ku, (n, d), jnp.float32)
        # a few exactly-zero and antipodal rows to hit the degenerate
        # branches (vacuous cone, n↓ = 0, cosθ ≤ −cos r)
        users = users.at[:2].set(0.0).at[2].set(-users[3])
        items = scale * jax.random.normal(ki, (m, d), jnp.float32)
        cfg = RankTableConfig(tau=8, omega=2, s=8, storage_dtype=spec)
        rt = build_rank_table(users, items, cfg, kz)
        su = cfg.storage.pack_users(users)
        su = users if su is None else su
        summ = PR.build_block_summary(su, rt, block_size=block_size)
        qs = items[:4] * (1.0 + 1e-3 * jax.random.normal(
            kq, (4, d), jnp.float32))
        scores, slack = user_scores_batch(su, qs)
        r_lo, r_up, _ = lookup_bounds_batch(rt, scores, slack)
        lo_env, up_env = (np.asarray(a)
                          for a in PR._envelope_bounds(summ, qs))
        r_lo, r_up = np.asarray(r_lo), np.asarray(r_up)
        for blk in range(summ.n_blocks):
            rows = slice(blk * block_size,
                         min((blk + 1) * block_size, n))
            assert np.all(lo_env[blk] <= r_lo[rows].min(axis=0) + 1e-6)
            assert np.all(up_env[blk] >= r_up[rows].max(axis=0) - 1e-6)
else:
    @pytest.mark.skip(reason="hypothesis not installed (optional test "
                             "extra)")
    def test_cone_band_containment_property():
        pass


# ------------------------------------------ k-means layout (PR 6)
def shuffled_clustered(key):
    """Clustered users whose ROW ORDER carries no structure — the layout
    the build-time reorder exists to fix."""
    users, items = clustered_problem(key)
    sh = jax.random.permutation(jax.random.fold_in(key, 99), N)
    return users[sh], items


def test_kmeans_layout_recovers_contiguity():
    users, items = shuffled_clustered(jax.random.PRNGKey(21))
    perm = PR.kmeans_layout(users, block_size=BS, n_clusters=32)
    assert perm is not None and perm.dtype == np.int64
    assert np.array_equal(np.sort(perm), np.arange(N))      # a permutation
    # too-small matrices refuse to reorder (nothing to tile)
    assert PR.kmeans_layout(users[:BS], block_size=BS) is None
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    j = jnp.asarray(perm)
    s_raw = PR.build_block_summary(users, rt, block_size=BS)
    s_re = PR.build_block_summary(users[j], rt.take_rows(j), block_size=BS)
    qs = off_grid_queries(items, 8)
    lo_raw, up_raw = (np.asarray(a) for a in PR._envelope_bounds(s_raw, qs))
    lo_re, up_re = (np.asarray(a) for a in PR._envelope_bounds(s_re, qs))
    # shuffled blocks mix all 16 clusters → near-vacuous envelopes;
    # reordered blocks are (near-)single-cluster → strictly tighter
    assert (up_re - lo_re).mean() < (up_raw - lo_raw).mean()


@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("B", [1, 16])
def test_reordered_parity(inner, B):
    """build(cluster_reorder=True): bit-identical to the unpruned inner
    on the SAME reordered layout, and remap-translated indices identical
    to an engine that never reordered (pre-remap user coordinates).

    The cross-layout check needs the exact-threshold table: per-user
    (r↓, r↑, est) are then layout-invariant bit-for-bit (per-row ops),
    so selections can only differ through index TIE-BREAKS — and exact-
    mode est is continuous, so clustered Gaussian users don't tie. A
    coarse sampled grid quantizes est into genuine ties whose index
    tie-break legitimately differs between layouts (same reason the
    repo's parity contract is per-layout, not cross-layout)."""
    users, items = shuffled_clustered(jax.random.PRNGKey(23))
    exact_cfg = RankTableConfig(tau=64, omega=4, s=M // 4,
                                threshold_mode="exact")
    eng = ReverseKRanksEngine.build(users, items, exact_cfg,
                                    jax.random.PRNGKey(1),
                                    backend=f"pruned:{inner}",
                                    cluster_reorder=True)
    eng._backend.block_size = BS
    raw = ReverseKRanksEngine.build(users, items, exact_cfg,
                                    jax.random.PRNGKey(1), backend=inner)
    snap = eng.current_snapshot()
    remap = snap.user_remap
    assert remap is not None and np.array_equal(np.sort(remap),
                                                np.arange(N))
    ref = ReverseKRanksEngine(users=snap.users,
                              rank_table=snap.rank_table,
                              config=exact_cfg, backend=inner)
    qs = off_grid_queries(items, B)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    np.testing.assert_array_equal(
        snap.client_user_ids(np.asarray(got.indices)),
        np.asarray(raw.query_batch(qs, k=K, c=1.0).indices))


def test_reorder_then_mutate_parity():
    """The remap keeps translating across post-reorder churn, and user
    mutations address CURRENT coordinates (the documented contract)."""
    users, items = shuffled_clustered(jax.random.PRNGKey(29))
    eng = ReverseKRanksEngine.build(users, items, CFG_COARSE,
                                    jax.random.PRNGKey(1),
                                    backend="pruned:dense",
                                    cluster_reorder=True)
    eng._backend.block_size = BS
    snap = eng.current_snapshot()
    ref = ReverseKRanksEngine(users=snap.users,
                              rank_table=snap.rank_table,
                              config=CFG_COARSE, items=items,
                              build_key=jax.random.PRNGKey(1))
    churn(eng)
    new = jax.random.normal(jax.random.PRNGKey(11), (16, D), jnp.float32)
    ids = ref.insert_items(new)
    ref.delete_items([3, 17, int(ids[1])])
    ref.delete_users([9, N - 100])
    qs = off_grid_queries(items, 8)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    # translation still goes through the (unchanged) epoch-0 remap
    tr = eng.current_snapshot().client_user_ids(np.asarray(got.indices))
    assert np.array_equal(np.asarray(snap.user_remap)[tr],
                          np.asarray(got.indices))


def test_sharded_alignment_fallback(problem):
    """Tiles straddling shard boundaries are refused up front: the
    sharded inner runs unpruned rather than mis-gathering."""
    users, items = problem
    rt = build_rank_table(users, items, CFG_COARSE, jax.random.PRNGKey(1))
    eng = pruned_engine(users, rt, CFG_COARSE, "sharded",
                        block_size=3 * BS)  # n % (P·bs) != 0 for any P>1
    ref = ReverseKRanksEngine(users=users, rank_table=rt,
                              config=CFG_COARSE, backend="sharded")
    qs = off_grid_queries(items, 4)
    got = eng.query_batch(qs, k=K, c=1.0)
    assert_selected_parity(got, ref.query_batch(qs, k=K, c=1.0))
    if jax.device_count() > 1:
        assert eng._backend.stats.fallback == "align"
