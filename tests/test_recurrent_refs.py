"""Chunked-vs-sequential references for the recurrent mixers: the GLA-style
chunked WKV and the associative-scan RG-LRU must match step-by-step
recurrences to float tolerance (the TPU-adaptation correctness proof)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.recurrent import (_rglru_coeffs, init_rglru_block,
                                    init_rwkv_tmix, wkv_chunked)
from repro.configs import get_config, reduced


def seq_wkv(r, k, v, logw, u, s0):
    """Literal per-step recurrence: S_t = diag(w_t)S_{t-1} + k_t v_tᵀ,
    y_t = r_t(S_{t-1} + diag(u) k_t v_tᵀ)."""
    B, H, S, hd = r.shape
    s = np.asarray(s0, np.float64)
    ys = []
    rr, kk, vv = (np.asarray(t, np.float64) for t in (r, k, v))
    ww = np.exp(np.asarray(logw, np.float64))
    uu = np.asarray(u, np.float64)
    for t in range(S):
        kv = kk[:, :, t, :, None] * vv[:, :, t, None, :]
        y = np.einsum("bhd,bhde->bhe", rr[:, :, t],
                      s + uu[None, :, :, None] * kv)
        ys.append(y)
        s = ww[:, :, t][..., None] * s + kv
    return np.stack(ys, axis=2), s


@pytest.mark.parametrize("S,chunk", [(16, 4), (64, 64), (96, 32), (33, 33)])
def test_wkv_chunked_matches_sequential(S, chunk):
    B, H, hd = 2, 3, 8
    key = jax.random.PRNGKey(S)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, hd)) * 0.5)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jnp.zeros((B, H, hd, hd))
    y, s_fin = wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    y_ref, s_ref = seq_wkv(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=1e-4,
                               atol=1e-4)


def test_wkv_carries_state_across_chunks():
    """Nonzero s0 must influence every chunk's output (inter-chunk path)."""
    B, H, S, hd = 1, 1, 8, 4
    key = jax.random.PRNGKey(0)
    r = jnp.ones((B, H, S, hd))
    k = jnp.zeros((B, H, S, hd))          # no new writes
    v = jnp.zeros((B, H, S, hd))
    logw = jnp.zeros((B, H, S, hd))       # decay = 1 (no forgetting)
    u = jnp.zeros((H, hd))
    s0 = jnp.eye(hd)[None, None] * 2.0
    y, s_fin = wkv_chunked(r, k, v, logw, u, s0, chunk=4)
    np.testing.assert_allclose(np.asarray(y), 2.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s0), rtol=1e-6)


def test_rglru_assoc_scan_matches_stepwise():
    cfg = reduced(get_config("recurrentgemma-9b"))
    p = init_rglru_block(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    u = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.lru_width))
    a, b = _rglru_coeffs(p, u, jnp.float32)

    def op(ca, cb):
        (a1, b1), (a2, b2) = ca, cb
        return a1 * a2, b1 * a2 + b2

    _, h_scan = jax.lax.associative_scan(op, (a, b), axis=1)
    h = np.zeros((B, cfg.lru_width))
    a_np, b_np = np.asarray(a, np.float64), np.asarray(b, np.float64)
    for t in range(S):
        h = a_np[:, t] * h + b_np[:, t]
        np.testing.assert_allclose(np.asarray(h_scan[:, t]), h, rtol=1e-4,
                                   atol=1e-5)


def test_rglru_decay_in_unit_interval():
    cfg = reduced(get_config("recurrentgemma-9b"))
    p = init_rglru_block(jax.random.PRNGKey(3), cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (3, 7, cfg.lru_width)) * 5
    a, b = _rglru_coeffs(p, u, jnp.float32)
    a = np.asarray(a)
    assert np.all((a > 0) & (a < 1))
    assert np.all(np.isfinite(np.asarray(b)))
