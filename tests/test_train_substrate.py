"""Optimizer / schedule / pipeline / MF / trainer substrate tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra — `pip install repro[test]` (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.configs import get_config, reduced
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import (PipelineConfig, TokenPipeline,
                                 synthetic_ratings)
from repro.models.model import Model
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, global_norm)
from repro.train.trainer import make_train_step


# ------------------------------------------------------------------- AdamW
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((3,), 1e6)}
    _, _, metrics = adamw_update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) > 1e5        # reported pre-clip
    # post-clip effective grad has norm 1 ⇒ first Adam step ≤ lr per coord
    p2, _, _ = adamw_update(cfg, huge, adamw_init(params), params)
    assert float(jnp.abs(p2["w"]).max()) <= 1.0 + 1e-5


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


if given is not None:
    @given(step=st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_cosine_schedule_bounds(step):
        v = float(cosine_schedule(jnp.asarray(step), warmup=100,
                                  total=10_000))
        assert 0.0 <= v <= 1.0 + 1e-6


else:
    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_cosine_schedule_bounds():
        pass

# ---------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_step_dependent():
    pipe = TokenPipeline(PipelineConfig(vocab=128, seq_len=16,
                                        global_batch=4))
    a = pipe.batch_at(3)
    b = pipe.batch_at(3)
    c = pipe.batch_at(4)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # labels are next-token shifted views of one stream
    assert a["tokens"].shape == a["labels"].shape == (4, 16)
    assert int(a["tokens"].max()) < 128


def test_pipeline_host_sharding_partitions_batch():
    pipe = TokenPipeline(PipelineConfig(vocab=64, seq_len=8,
                                        global_batch=8))
    h0 = pipe.batch_at(0, host_index=0, host_count=2)
    h1 = pipe.batch_at(0, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


# ---------------------------------------------------------------------- MF
def test_mf_learns_low_rank_structure():
    key = jax.random.PRNGKey(0)
    ii, jj, rr = synthetic_ratings(key, 300, 200, n_obs=40_000)
    # Mean-loss SGD scales the per-example step by 1/batch, so lr must be
    # O(batch / per-user coverage) for visible progress in 10 epochs at
    # this scale; lr=10 reaches ~75% loss reduction.
    state, losses = train_mf(key, 300, 200, ii, jj, rr,
                             MFConfig(d=16, epochs=10, batch=2048, lr=10.0))
    assert losses[-1] < 0.6 * losses[0]
    assert all(a >= b - 1e-3 for a, b in zip(losses, losses[1:]))
    users, items = embeddings(state)
    assert users.shape == (300, 18) and items.shape == (200, 18)
    # bias folding preserves the rating model: u·v + bu + bv
    pred = float(users[5] @ items[7])
    want = float(state["u"][5] @ state["v"][7] + state["bu"][5]
                 + state["bv"][7])
    assert abs(pred - want) < 1e-4


# ----------------------------------------------------------------- trainer
def test_microbatch_accumulation_matches_full_batch():
    """Accumulated GRADIENTS must equal full-batch gradients (comparing
    post-AdamW params instead would amplify float-level grad noise through
    m/√v at step 1 into ±lr sign flips — not a meaningful signal)."""
    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")),
                              n_layers=2, vocab=256, remat="none")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=8,
                                        global_batch=4))
    batch = pipe.batch_at(0)

    loss_fn = lambda p, b: model.loss_fn(p, b)
    l_full, g_full = jax.value_and_grad(loss_fn)(params, batch)
    halves = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), batch)
    l_acc, g_acc = 0.0, jax.tree.map(jnp.zeros_like, params)
    for i in range(2):
        li, gi = jax.value_and_grad(loss_fn)(
            params, jax.tree.map(lambda x: x[i], halves))
        l_acc += li / 2
        g_acc = jax.tree.map(lambda a, g: a + g / 2, g_acc, gi)
    assert abs(float(l_full) - float(l_acc)) < 2e-2
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(g_full))
    dn = sum(float(jnp.abs(a - g).sum()) for a, g in zip(
        jax.tree.leaves(g_acc), jax.tree.leaves(g_full)))
    assert dn < 0.05 * gn                      # ≤5% relative L1 difference


def test_bf16_compute_params_close_to_f32():
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")),
                              n_layers=2, vocab=256, remat="none")
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=8,
                                        global_batch=2))
    batch = pipe.batch_at(0)
    opt = AdamWConfig(lr=1e-3)
    sa = jax.jit(make_train_step(model, opt, None,
                                 bf16_compute_params=False))
    sb = jax.jit(make_train_step(model, opt, None,
                                 bf16_compute_params=True))
    _, _, ma = sa(params, adamw_init(params), batch)
    _, _, mb = sb(params, adamw_init(params), batch)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 0.05
