"""Observability-subsystem tests (the PR-8 tentpole): metrics registry
semantics, histogram percentile reconstruction, trace-span nesting under
concurrent serving, the HTTP exporter, the elastic compiled-program scan
cache, and the online quality auditor — including the end-to-end
acceptance run (live audited overall-ratio inside the PR-5 bench
envelope on a churning `cached:pruned:dense` int8 serve).

Registry tests use PRIVATE `MetricsRegistry()` instances so they cannot
perturb the process-global one the serving modules publish into; the one
test that reads the global registry (the elastic callback gauge) is
read-only. Trace tests run behind a fixture that force-disables and
clears the ring buffer on both sides.
"""
import json
import math
import sys
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs import registry as obs
from repro.obs import trace
from repro.obs.audit import QualityAuditor


# ---------------------------------------------------------------- fixtures
@pytest.fixture
def reg():
    return obs.MetricsRegistry()


@pytest.fixture
def clean_trace():
    trace.disable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


# ------------------------------------------------------ counters / gauges
def test_counter_monotone(reg):
    c = reg.counter("c_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc(reg):
    g = reg.gauge("g")
    g.set(4.0)
    g.inc()
    g.inc(-2.0)                 # gauges may go down
    assert g.value == 3.0


def test_callback_gauge_and_explicit_set_wins(reg):
    g = reg.gauge("g_cb", set_fn=lambda: 42.0)
    assert g.value == 42.0
    g.set(5.0)                  # explicit set clears the callback
    assert g.value == 5.0
    # re-registering with a set_fn must NOT clobber an explicitly set
    # value (re-attach only happens on a pristine gauge)
    assert reg.gauge("g_cb", set_fn=lambda: 99.0).value == 5.0


def test_callback_gauge_exception_is_nan_and_survives_reset(reg):
    bad = reg.gauge("g_bad", set_fn=lambda: 1 / 0)
    assert math.isnan(bad.value)
    good = reg.gauge("g_good", set_fn=lambda: 7.0)
    reg.reset()                 # reset zeroes values, keeps callbacks
    assert good.value == 7.0
    assert math.isnan(bad.value)


def test_get_or_create_identity_and_conflicts(reg):
    c = reg.counter("name_a")
    assert reg.counter("name_a") is c
    with pytest.raises(TypeError):
        reg.gauge("name_a")     # same name, different kind
    h = reg.histogram("h", bounds=(1.0, 2.0))
    assert reg.histogram("h") is h          # bounds=None: no conflict
    with pytest.raises(ValueError):
        reg.histogram("h", bounds=(1.0, 3.0))
    # labels split series: distinct instruments, same name
    l1 = reg.counter("lbl_total", labels={"mode": "a"})
    l2 = reg.counter("lbl_total", labels={"mode": "b"})
    assert l1 is not l2
    assert reg.counter("lbl_total", labels={"mode": "a"}) is l1


def test_reset_in_place_keeps_references(reg):
    c = reg.counter("c_total")
    h = reg.histogram("h_ms", bounds=(1.0, 2.0))
    c.inc(3)
    h.observe(1.5)
    reg.reset()
    assert c.value == 0.0 and h.count == 0 and h.sum == 0.0
    assert reg.counter("c_total") is c      # same object, zeroed in place
    c.inc()
    assert c.value == 1.0


# ------------------------------------------------------------- histograms
def test_default_latency_bounds_shape():
    b = obs.default_latency_bounds()
    assert b[0] == 1e-3 and b[-1] >= 60_000.0
    assert all(b[i] < b[i + 1] for i in range(len(b) - 1))
    # ~4 buckets per octave: consecutive ratio is 2^(1/4)
    np.testing.assert_allclose(b[1] / b[0], 2.0 ** 0.25, rtol=1e-12)
    assert len(b) > 50


def test_histogram_bucket_boundaries():
    """Observations exactly AT a bound land in that bound's bucket
    (bucket i holds bounds[i-1] < v <= bounds[i])."""
    h = obs.Histogram("h", bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 9.0):
        h.observe(v)
    cum = dict(h._cumulative())
    assert cum[1.0] == 2        # 0.5 and the boundary hit 1.0
    assert cum[2.0] == 4        # + 1.5 and the boundary hit 2.0
    assert cum[4.0] == 5        # + the boundary hit 4.0
    assert cum[math.inf] == 6   # 9.0 overflows into +Inf
    assert h.count == 6 and h.sum == pytest.approx(18.0)


def test_percentile_exact_on_boundary_stream():
    """Any stream drawn from the bucket bounds themselves makes every
    bucket degenerate, so nearest-rank reconstruction is EXACT."""
    bounds = (1.0, 2.0, 4.0, 8.0)
    h = obs.Histogram("h", bounds=bounds)
    data = [1.0] * 3 + [2.0] * 5 + [4.0] * 1 + [8.0] * 11
    for v in data:
        h.observe(v)
    data.sort()
    for p in (0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0):
        rank = max(0, math.ceil(p / 100.0 * len(data)) - 1)
        assert h.percentile(p) == data[rank], f"p{p}"
    assert h.p50() == 8.0 and h.p99() == 8.0


def test_percentile_interpolation_bounded_by_bucket_width():
    """Arbitrary streams reconstruct within ONE bucket's observed
    min/max span of the true nearest-rank value."""
    rng = np.random.default_rng(0)
    bounds = tuple(obs.default_latency_bounds(0.1, 100.0, per_octave=4))
    h = obs.Histogram("h", bounds=bounds)
    data = np.concatenate([rng.uniform(0.2, 5.0, 400),
                           rng.uniform(20.0, 90.0, 100)])
    for v in data:
        h.observe(float(v))
    data.sort()
    for p in (1.0, 25.0, 50.0, 75.0, 95.0, 99.0):
        rank = max(0, math.ceil(p / 100.0 * data.size) - 1)
        true = data[rank]
        i = np.searchsorted(bounds, true)           # bisect_left
        lo = bounds[i - 1] if i > 0 else 0.0
        hi = bounds[i] if i < len(bounds) else math.inf
        assert abs(h.percentile(p) - true) <= hi - lo, f"p{p}"


def test_percentile_edge_cases():
    h = obs.Histogram("h", bounds=(1.0, 2.0))
    assert h.percentile(50.0) == 0.0        # empty histogram
    h.observe(1.5)
    assert h.percentile(0.0) == 1.5 and h.percentile(100.0) == 1.5
    with pytest.raises(ValueError):
        h.percentile(101.0)
    with pytest.raises(ValueError):
        obs.Histogram("bad", bounds=(2.0, 1.0))     # not increasing
    with pytest.raises(ValueError):
        obs.Histogram("bad", bounds=())             # empty


# -------------------------------------------------------------- exporters
def test_snapshot_and_prometheus_text(reg):
    reg.counter("req_total", "requests").inc(3)
    reg.gauge("depth", labels={"mode": "serve"}).set(2.0)
    h = reg.histogram("lat_ms", bounds=(1.0, 2.0, 4.0))
    h.observe(1.5)
    h.observe(3.0)

    snap = reg.snapshot()
    assert snap["req_total"][0]["value"] == 3.0
    assert snap["req_total"][0]["type"] == "counter"
    assert snap["depth"][0]["labels"] == {"mode": "serve"}
    hist = snap["lat_ms"][0]
    assert hist["count"] == 2 and hist["sum"] == pytest.approx(4.5)
    les = [b["le"] for b in hist["buckets"]]
    assert 2.0 in les and math.inf in les
    assert 1.0 not in les                   # empty buckets elided
    json.dumps(snap, default=str)           # must be JSON-able

    text = reg.to_prometheus_text()
    assert "# TYPE req_total counter" in text
    assert "req_total 3.0" in text
    assert 'depth{mode="serve"} 2.0' in text
    assert 'lat_ms_bucket{le="+Inf"} 2' in text
    assert 'lat_ms_bucket{le="2.0"} 1' in text
    assert "lat_ms_count 2" in text


def test_http_exporter_serves_both_formats(reg):
    reg.counter("scrape_total").inc(7)
    srv = obs.start_http_server(0, registry=reg)    # ephemeral port
    try:
        port = srv.server_address[1]
        base = f"http://127.0.0.1:{port}"
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            assert "scrape_total 7.0" in r.read().decode()
        with urllib.request.urlopen(f"{base}/metrics.json", timeout=10) as r:
            payload = json.loads(r.read().decode())
        assert payload["metrics"]["scrape_total"][0]["value"] == 7.0
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope", timeout=10)
    finally:
        srv.shutdown()
        srv.server_close()


# ------------------------------------------------------------------ trace
def test_disabled_trace_is_shared_null_span(clean_trace):
    assert not trace.is_enabled()
    sp = trace.span("x", a=1)
    assert sp is trace.span("y")            # one shared no-op object
    with sp as s:
        s.set(b=2)
    trace.event("e", 0.0, 1.0)
    assert trace.spans() == []


def test_span_nesting_and_attrs(clean_trace):
    trace.enable()
    with trace.span("outer", a=1) as sp:
        sp.set(b=2)                         # attrs may land mid-span
        with trace.span("inner"):
            pass
    recs = trace.spans()
    inner = [r for r in recs if r.name == "inner"][0]
    outer = [r for r in recs if r.name == "outer"][0]
    assert inner.depth == 1 and inner.parent == "outer"
    assert outer.depth == 0 and outer.parent is None
    assert outer.attrs == (("a", 1), ("b", 2))
    assert outer.duration_s >= 0 and outer.duration_ms >= 0


def test_event_is_retroactive_and_stack_attributed(clean_trace):
    trace.enable()
    with trace.span("tick"):
        trace.event("queue_wait", 123.0, 0.25, k=5)
    (ev,) = trace.spans("queue_wait")
    assert ev.t_start == 123.0 and ev.duration_s == 0.25
    assert ev.parent == "tick" and ev.depth == 1
    assert ev.attrs == (("k", 5),)


def test_span_nesting_under_concurrent_threads(clean_trace):
    """Each thread gets its OWN span stack: depth/parent never leak
    across threads no matter how the bodies interleave."""
    trace.enable()
    barrier = threading.Barrier(4)

    def work(tid):
        for _ in range(25):
            with trace.span("outer", tid=tid):
                barrier.wait(timeout=30)    # force interleaving
                with trace.span("inner", tid=tid):
                    pass

    threads = [threading.Thread(target=work, args=(i,), name=f"w{i}")
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = trace.spans()
    assert len([r for r in recs if r.name == "inner"]) == 100
    for r in recs:
        if r.name == "inner":
            assert r.depth == 1 and r.parent == "outer"
        else:
            assert r.depth == 0 and r.parent is None
        # attribution stays on the recording thread
        tid = dict(r.attrs)["tid"]
        assert r.thread == f"w{tid}"


def test_ring_buffer_capacity_and_clear(clean_trace):
    trace.enable()
    trace.set_capacity(8)
    try:
        for i in range(20):
            with trace.span("s", i=i):
                pass
        recs = trace.spans("s")
        assert len(recs) == 8               # only the most recent kept
        assert dict(recs[-1].attrs)["i"] == 19
        trace.clear()
        assert trace.spans() == []
        with pytest.raises(ValueError):
            trace.set_capacity(0)
    finally:
        trace.set_capacity(4096)


# --------------------------------------------- elastic compiled-programs
def test_elastic_jit_scan_cache_and_gauge():
    from repro.core import elastic

    n0 = elastic.compiled_program_count()
    entries = elastic._jit_entries()
    assert elastic._jit_entries() is entries        # memoized scan
    # the module-registered callback gauge samples the same scan
    g = obs.get_default().gauge("query_compiled_programs")
    assert int(g.value) == elastic.compiled_program_count() >= n0
    # mutating a counted module's namespace invalidates the cache key
    mod = sys.modules["repro.core.query"]
    mod._obs_scan_probe = 1
    try:
        assert elastic._jit_entries() is not entries
        assert elastic.compiled_program_count() == n0
    finally:
        del mod._obs_scan_probe


# ---------------------------------------------------------------- auditor
class _NoSnapshotEngine:
    """Engine stub with no `current_snapshot` — every sampled query is
    skipped by the scorer, which is exactly what the sampling-determinism
    tests need (no jax work, just the RNG/queue machinery)."""


def _observe_sequence(seed, n, fraction):
    reg = obs.MetricsRegistry()
    with QualityAuditor(_NoSnapshotEngine(), fraction=fraction, seed=seed,
                        registry=reg) as aud:
        picks = [aud.observe(np.zeros(4, np.float32), None, k=5, c=2.0)
                 for _ in range(n)]
        assert aud.flush(timeout=30)
        skipped = reg.counter("audit_skipped_total").value
        observed = reg.counter("audit_observed_total").value
        sampled = reg.counter("audit_sampled_total").value
    return picks, observed, sampled, skipped


def test_auditor_sampling_deterministic_under_seed():
    a, obs_a, samp_a, skip_a = _observe_sequence(seed=0, n=200, fraction=0.5)
    b, *_ = _observe_sequence(seed=0, n=200, fraction=0.5)
    c, *_ = _observe_sequence(seed=1, n=200, fraction=0.5)
    assert a == b                   # same seed + order → same subset
    assert a != c                   # a different seed moves the subset
    assert obs_a == 200 and samp_a == sum(a)
    assert 0 < samp_a < 200
    # snapshot-less samples are all counted as skips, never scored
    assert skip_a == samp_a


def test_auditor_fraction_endpoints():
    none, _, samp0, _ = _observe_sequence(seed=3, n=50, fraction=0.0)
    assert not any(none) and samp0 == 0
    every, _, samp1, _ = _observe_sequence(seed=3, n=50, fraction=1.0)
    assert all(every) and samp1 == 50


def test_auditor_rejects_bad_args():
    with pytest.raises(ValueError):
        QualityAuditor(_NoSnapshotEngine(), fraction=1.5,
                       registry=obs.MetricsRegistry())
    with pytest.raises(ValueError):
        QualityAuditor(_NoSnapshotEngine(), window=0,
                       registry=obs.MetricsRegistry())


def test_auditor_results_nan_before_first_score():
    with QualityAuditor(_NoSnapshotEngine(), fraction=0.0,
                        registry=obs.MetricsRegistry()) as aud:
        assert math.isnan(aud.overall_ratio)
        assert math.isnan(aud.accuracy)
        assert math.isnan(aud.bound_width)
        assert aud.scored == 0


# --------------------------------------------------- serving integration
@pytest.fixture(scope="module")
def serve_setup():
    import jax
    from repro.core.engine import ReverseKRanksEngine
    from repro.core.rank_table import build_rank_table
    from repro.core.types import RankTableConfig
    from tests.conftest import make_problem

    users, items = make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)
    cfg = RankTableConfig(tau=16, omega=4, s=8)
    rt = build_rank_table(users, items, cfg, jax.random.PRNGKey(1))
    eng = ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                              backend="cached:dense")
    qs = items[(1 + np.arange(8) * 13) % items.shape[0]]
    qs = qs * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), qs.shape))
    return eng, np.asarray(qs)


def test_serving_spans_nest_under_concurrent_submissions(serve_setup,
                                                         clean_trace):
    """The scheduler's tick span encloses the cache lookup, and every
    QUEUED request's queue wait is recorded, while 4 client threads
    hammer `submit` concurrently with the dispatcher. Since PR 10 folded
    the LRU probe into admission, a repeat of an already-cached query
    resolves at submit and never enters the queue — so the invariant is
    conservation (queue waits + admission hits == submissions), not one
    wait per request."""
    from repro.serve import MicroBatcher

    eng, qs = serve_setup
    trace.enable()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=10.0) as mb:
        def client(rounds):
            for _ in range(rounds):
                futs = [mb.submit(q, 7, 2.0) for q in qs[:4]]
                for f in futs:
                    f.result(timeout=120)

        threads = [threading.Thread(target=client, args=(3,))
                   for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = mb.stats()
    ticks = trace.spans("serve.tick")
    lookups = trace.spans("cache.lookup")
    waits = trace.spans("serve.queue_wait")
    assert ticks and lookups
    assert waits                             # first-round misses queued
    assert len(waits) + st.admission_hits == 4 * 3 * 4
    for r in ticks:
        assert r.depth == 0 and r.parent is None
    for r in lookups:
        assert r.parent == "serve.tick" and r.depth == 1
    for r in waits:
        assert r.parent == "serve.tick" and r.duration_s >= 0


def test_serving_metrics_flow_into_default_registry(serve_setup):
    from repro.serve import MicroBatcher

    reg = obs.get_default()
    before = reg.counter("serve_requests_total").value
    eng, qs = serve_setup
    with MicroBatcher(eng, max_batch=8, max_wait_ms=10.0) as mb:
        for f in [mb.submit(q, 7, 2.0) for q in qs]:
            f.result(timeout=120)
    assert reg.counter("serve_requests_total").value == before + len(qs)
    assert reg.histogram("serve_request_latency_ms").count > 0
    assert reg.histogram("serve_queue_wait_ms").count > 0


@pytest.mark.slow
def test_live_audit_ratio_within_envelope_end_to_end():
    """ACCEPTANCE: a churning `cached:pruned:dense` int8 serve on
    zipf-clustered data (the PR-5 smoke layout: d=64, τ=128, ω=8, s=32)
    audited at fraction 1.0 keeps the rolling overall-ratio inside the
    bench envelope (BENCH_PR5.json int8: 1.109; gate ≤ 1.15)."""
    import jax
    from benchmarks.common import zipf_clustered
    from repro.core.engine import ReverseKRanksEngine
    from repro.core.types import RankTableConfig
    from repro.serve import MicroBatcher

    users, items, _ = zipf_clustered(jax.random.PRNGKey(0), 4096, 1024, 64)
    cfg = RankTableConfig(tau=128, omega=8, s=32, storage_dtype="int8")
    eng = ReverseKRanksEngine.build(users, items, cfg, jax.random.PRNGKey(1),
                                    backend="cached:pruned:dense")
    qs = np.asarray(items[:32] * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), items[:32].shape)))
    churn_key = jax.random.PRNGKey(9)

    reg = obs.MetricsRegistry()
    with QualityAuditor(eng, fraction=1.0, seed=0, window=64,
                        registry=reg) as aud:
        with MicroBatcher(eng, max_batch=8, max_wait_ms=20.0,
                          auditor=aud) as mb:
            futs = []
            for i, q in enumerate(qs):
                if i and i % 8 == 0:        # churn between bursts
                    churn_key, sub = jax.random.split(churn_key)
                    eng.insert_items(jax.random.normal(sub, (4, 64)))
                    eng.delete_items(eng.live_item_ids()[:2])
                futs.append(mb.submit(q, 10, 2.0))
            for f in futs:
                f.result(timeout=300)
        assert aud.flush(timeout=300)
        assert aud.scored == len(qs)
        assert 1.0 <= aud.overall_ratio <= 1.15
        assert aud.accuracy >= 0.9
        assert np.isfinite(aud.bound_width)
        # the gauges mirror the rolling windows
        assert reg.gauge("audit_overall_ratio").value == pytest.approx(
            aud.overall_ratio)
        assert reg.gauge("audit_accuracy").value == pytest.approx(
            aud.accuracy)
