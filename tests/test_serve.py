"""Serving-subsystem tests (the PR-2 tentpole): micro-batching scheduler
partial-tick padding, caching-backend dedupe/LRU, and the wrapper
registry — all pinned to a BIT-IDENTITY contract against direct
`engine.query_batch` execution.

Why bit-identity is attainable: a batched matmul's output column (i, j)
depends only on user row i, query column j, and the accumulation order —
never on the other columns' VALUES — so padding a partial tick to the
compiled batch shape (or deduping duplicates out of it) cannot perturb
the real queries' scores, and everything downstream (bucketize, bounds,
top-k) is per-row deterministic. The one platform caveat: a width-1
dispatch lowers as a matvec with a DIFFERENT accumulation order (see the
PR-1 note in tests/test_backends.py), so width-1 blocks compare on the
table-derived integer-valued fields with `est` at float accuracy, and
the serving paths never shrink a multi-query dispatch below width 2.

Queries are perturbed off the items so no score lands exactly on a
threshold-grid point (where a 1-ulp difference could legitimately flip
the bucketize) — same convention as tests/test_backends.py.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional test extra — `pip install repro[test]` (see pyproject.toml)
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core import backends as BK
from repro.core.engine import ReverseKRanksEngine
from repro.core.rank_table import build_rank_table
from repro.core.types import RankTableConfig
from repro.serve import CachingBackend, MicroBatcher, QueueFull, pad_block
from tests.conftest import make_problem

ALL_BACKENDS = ("dense", "fused", "sharded")
K, C = 7, 2.0
MAX_BATCH = 8

# integer-valued-in-rank-space fields: must match bitwise even across the
# width-1 matvec lowering; `est` is continuous in the score's low bits.
_EXACT_FIELDS = ("indices", "r_lo", "r_up", "R_lo_k", "R_up_k",
                 "guaranteed", "n_accepted", "n_pruned")


def assert_bitwise(got, want, fields=None):
    for f in (fields or want._fields):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f"field {f!r} not bit-identical")


@pytest.fixture(scope="module")
def problem():
    return make_problem(jax.random.PRNGKey(42), n=512, m=400, d=16)


@pytest.fixture(scope="module")
def rank_table(problem):
    users, items = problem
    return build_rank_table(users, items, RankTableConfig(tau=16, omega=4,
                                                          s=8),
                            jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def queries(problem):
    """MAX_BATCH off-grid queries (see module docstring)."""
    _, items = problem
    base = items[(1 + jnp.arange(MAX_BATCH) * 13) % items.shape[0]]
    return base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(7), base.shape, jnp.float32))


def _engine(problem, rank_table, backend):
    users, _ = problem
    return ReverseKRanksEngine(users=users, rank_table=rank_table,
                               config=RankTableConfig(tau=16, omega=4, s=8),
                               backend=backend)


# ------------------------------------------------------------- scheduler
@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("size", [2, 3, MAX_BATCH - 1, MAX_BATCH])
def test_padded_partial_tick_bitwise(problem, rank_table, queries, backend,
                                     size):
    """(a) A partial tick padded to the compiled max_batch shape returns
    results bit-identical to direct query_batch on the UNPADDED block."""
    eng = _engine(problem, rank_table, backend)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=25.0) as mb:
        futs = [mb.submit(q, K, C) for q in queries[:size]]
        results = [f.result(timeout=120) for f in futs]
    direct = eng.query_batch(queries[:size], k=K, c=C)
    for i, res in enumerate(results):
        want = jax.tree_util.tree_map(lambda x, i=i: x[i], direct)
        assert_bitwise(res, want)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_singleton_tick_matches_direct(problem, rank_table, queries,
                                       backend):
    """A width-1 tick is padded like any other; vs direct B = 1 execution
    (a matvec lowering with different accumulation order) the table-
    derived fields still match exactly, `est` at float accuracy."""
    eng = _engine(problem, rank_table, backend)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=5.0) as mb:
        res = mb.submit(queries[0], K, C).result(timeout=120)
    direct = eng.query_batch(queries[:1], k=K, c=C)
    want = jax.tree_util.tree_map(lambda x: x[0], direct)
    assert_bitwise(res, want, fields=_EXACT_FIELDS)
    np.testing.assert_allclose(np.asarray(res.est_rank),
                               np.asarray(want.est_rank), rtol=1e-5,
                               atol=1e-4)


def test_scheduler_coalesces_and_reports(problem, rank_table, queries):
    """Full bursts dispatch as full ticks; stats see every request."""
    eng = _engine(problem, rank_table, "dense")
    eng.query_batch(queries, k=K, c=C)          # pre-compile the tick shape
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=200.0) as mb:
        futs = [mb.submit(q, K, C) for q in queries] * 1
        futs += [mb.submit(q, K, C) for q in queries]
        for f in futs:
            f.result(timeout=120)
        st = mb.stats()
    assert st.requests == 2 * MAX_BATCH
    assert st.ticks == 2                        # coalesced, not 16 ticks
    assert st.mean_fill == 1.0
    assert st.p99_ms >= st.p50_ms >= 0.0
    log = mb.tick_log
    assert all(t.batch == MAX_BATCH for t in log)
    assert all(len(t.latencies_ms) == t.batch for t in log)


def test_tick_log_and_stats_return_copies(problem, rank_table, queries):
    """`tick_log`/`stats()` hand out SNAPSHOTS: mutating the returned
    list (or calling them concurrently with dispatches) must never
    reach the scheduler's live `_ticks` deque."""
    eng = _engine(problem, rank_table, "dense")
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=10.0) as mb:
        for f in [mb.submit(q, K, C) for q in queries]:
            f.result(timeout=120)
        log = mb.tick_log
        assert log is not mb._ticks
        log.clear()                             # vandalize the copy
        log.append("junk")
        assert len(mb.tick_log) == 1            # live state untouched
        st_before = mb.stats()
        for f in [mb.submit(q, K, C) for q in queries]:
            f.result(timeout=120)
        # the earlier snapshots are immutable history, not live views
        assert st_before.requests == MAX_BATCH
        assert mb.stats().requests == 2 * MAX_BATCH
        assert len(mb.tick_log) == 2


def test_scheduler_separates_static_args(problem, rank_table, queries):
    """Requests with different (k, c) never share a tick (they cannot
    share a compiled batch program), yet all resolve correctly."""
    eng = _engine(problem, rank_table, "dense")
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=10.0) as mb:
        f1 = mb.submit(queries[0], K, C)
        f2 = mb.submit(queries[1], K + 2, C)
        f3 = mb.submit(queries[2], K, 1.0)
        r1, r2, r3 = (f.result(timeout=120) for f in (f1, f2, f3))
        assert len(mb.tick_log) == 3
    assert r1.indices.shape == (K,)
    assert r2.indices.shape == (K + 2,)
    assert r3.indices.shape == (K,)


def test_full_group_preempts_straggler_head(problem, rank_table, queries):
    """A FULL (k, c) group queued behind a lone different-key head
    dispatches immediately instead of waiting out the head's deadline
    (no head-of-line blocking); the head still dispatches by deadline."""
    eng = _engine(problem, rank_table, "dense")
    eng.query_batch(queries, k=K, c=C)          # pre-compile both shapes
    eng.query_batch(queries, k=K, c=1.0)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=400.0) as mb:
        t0 = time.monotonic()
        straggler = mb.submit(queries[0], K, 1.0)
        group = [mb.submit(q, K, C) for q in queries]   # fills max_batch
        for f in group:
            f.result(timeout=120)
        group_done = time.monotonic() - t0
        straggler.result(timeout=120)
        log = mb.tick_log
    assert group_done < 0.4, f"full group waited on the head ({group_done})"
    assert log[0].batch == MAX_BATCH            # the group went first
    assert [t.batch for t in log] == [MAX_BATCH, 1]


def test_scheduler_error_propagates(problem, rank_table):
    """A failing dispatch resolves every Future of the tick with the
    exception instead of hanging the client."""
    eng = _engine(problem, rank_table, "dense")
    with MicroBatcher(eng, max_batch=4, max_wait_ms=5.0) as mb:
        bad = mb.submit(jnp.zeros(3), K, C)     # wrong d: jit shape error
        with pytest.raises(Exception):
            bad.result(timeout=120)


def test_pad_block_shapes(queries):
    assert pad_block(queries[:3], MAX_BATCH).shape == (MAX_BATCH, 16)
    assert pad_block(queries, MAX_BATCH) is queries
    padded = np.asarray(pad_block(queries[:2], 4))
    np.testing.assert_array_equal(padded[2], padded[1])   # edge padding
    np.testing.assert_array_equal(padded[3], padded[1])
    with pytest.raises(ValueError, match="does not fit"):
        pad_block(queries, 4)


# ----------------------------------------------------------------- cache
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_cached_bitwise_all_backends(problem, rank_table, queries, backend):
    """(b) Dedupe + LRU-cached results are bit-identical to uncached
    dispatch: duplicate-heavy first tick (dedupe path), full-hit second
    tick (LRU path), overlapping third tick (mixed hit/miss path)."""
    eng = _engine(problem, rank_table, f"cached:{backend}")
    ref = _engine(problem, rank_table, backend)
    assert eng.backend_name == f"cached:{backend}"

    dup = queries[jnp.asarray([0, 1, 0, 2, 1, 0])]        # 6 rows, 3 unique
    assert_bitwise(eng.query_batch(dup, k=K, c=C),
                   ref.query_batch(dup, k=K, c=C))
    cache = eng._backend
    assert cache.misses == 6 and cache.hits == 0          # all cold rows

    assert_bitwise(eng.query_batch(dup, k=K, c=C),        # pure LRU hits
                   ref.query_batch(dup, k=K, c=C))
    assert cache.hits == 6

    mixed = queries[jnp.asarray([2, 3, 4, 0])]            # 2 hits, 2 misses
    assert_bitwise(eng.query_batch(mixed, k=K, c=C),
                   ref.query_batch(mixed, k=K, c=C))
    assert cache.hits == 8 and cache.misses == 8


def test_cached_keyed_by_k_and_c(problem, rank_table, queries):
    """Same query bytes under different (k, c) are different cache
    entries — the selection depends on both."""
    eng = _engine(problem, rank_table, "cached:dense")
    ref = _engine(problem, rank_table, "dense")
    qs = queries[:2]
    eng.query_batch(qs, k=K, c=C)
    for k, c in ((K, 1.0), (K + 2, C)):
        assert_bitwise(eng.query_batch(qs, k=k, c=c),
                       ref.query_batch(qs, k=k, c=c))
    assert eng._backend.hits == 0                         # no false sharing


def test_cached_lru_eviction_and_invalidation(problem, rank_table, queries):
    users, items = problem
    cache = CachingBackend("dense", capacity=2)
    rt = rank_table
    cache.query_batch(rt, users, queries[:3], k=K, c=C)
    assert cache.evictions == 1 and len(cache._lru) == 2
    # evicted head misses again; the two surviving entries hit
    cache.query_batch(rt, users, queries[:3], k=K, c=C)
    assert cache.hits == 2 and cache.misses == 4

    # rebuilding the index invalidates every cached result
    rt2 = build_rank_table(users, items,
                           RankTableConfig(tau=32, omega=4, s=8),
                           jax.random.PRNGKey(3))
    ref = BK.get_backend("dense")
    got = cache.query_batch(rt2, users, queries[:2], k=K, c=C)
    assert_bitwise(got, ref.query_batch(rt2, users, queries[:2], k=K, c=C))


def test_cached_through_scheduler_bitwise(problem, rank_table, queries):
    """The full serving stack — scheduler padding + cache dedupe (pad
    rows collapse into the last real query) — stays bit-identical to
    direct uncached execution of the unpadded block."""
    eng = _engine(problem, rank_table, "cached:dense")
    ref = _engine(problem, rank_table, "dense")
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=25.0) as mb:
        futs = [mb.submit(q, K, C) for q in queries[:3]]
        results = [f.result(timeout=120) for f in futs]
    direct = ref.query_batch(queries[:3], k=K, c=C)
    for i, res in enumerate(results):
        assert_bitwise(res, jax.tree_util.tree_map(lambda x, i=i: x[i],
                                                   direct))


# -------------------------------------------------- registry edge cases
def test_cached_unknown_inner_raises():
    """"cached:<unknown>" surfaces the available-backends ValueError."""
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("cached:no-such-backend")
    with pytest.raises(ValueError) as ei:
        BK.get_backend("cached:no-such-backend")
    for name in ALL_BACKENDS:
        assert name in str(ei.value)


def test_unknown_wrapper_prefix_raises():
    with pytest.raises(ValueError, match="unknown query backend"):
        BK.get_backend("zip:dense")


def test_cached_sharded_preserves_candidate_shape(problem, rank_table,
                                                  queries):
    """Wrapping "sharded" preserves its (B, k·P) candidate-set result
    shape — the cache stacks per-query slices, it does not reshape."""
    eng = _engine(problem, rank_table, "cached:sharded")
    P = jax.device_count()
    B = 4
    res = eng.query_batch(queries[:B], k=K, c=C)
    want = _engine(problem, rank_table, "sharded").query_batch(
        queries[:B], k=K, c=C)
    assert want.r_lo.shape == (B, K * P)      # sharded contract, uncached
    assert res.r_lo.shape == (B, K * P)
    assert res.r_up.shape == (B, K * P)
    assert res.indices.shape == (B, K)
    assert_bitwise(res, want)


def test_wrapper_backend_accepted_by_engine_build(problem):
    users, items = problem
    eng = ReverseKRanksEngine.build(
        users, items, RankTableConfig(tau=16, omega=4, s=8),
        jax.random.PRNGKey(0), backend="cached:dense")
    assert eng.backend_name == "cached:dense"
    res = eng.query(items[3], k=K, c=C)
    assert res.indices.shape == (K,)


# -------------------------------------------- PR 7 satellite regressions
def test_cache_key_canonicalizes_negzero_and_nan():
    """`_key_bytes` must give one key per semantically-equal query row:
    −0.0 vs +0.0 and differing NaN payloads score identically, so keying
    the raw f32 bit pattern (the old behavior) made such re-asks LRU
    misses — in both the raw and quantized key paths."""
    raw = CachingBackend("dense")
    quant = CachingBackend("dense", quantize_key_bits=8)
    d = 8
    a = np.linspace(-1.0, 1.0, d).astype(np.float32)
    a[0] = np.float32(0.0)
    b = a.copy()
    b[0] = np.float32(-0.0)
    assert a.tobytes() != b.tobytes()           # distinct raw bit patterns
    assert raw._key_bytes(a) == raw._key_bytes(b)
    assert quant._key_bytes(a) == quant._key_bytes(b)

    n1, n2 = a.copy(), a.copy()
    n1.view(np.uint32)[1] = np.uint32(0x7FC00001)   # qNaN, payload 1
    n2.view(np.uint32)[1] = np.uint32(0xFFC00000)   # −qNaN, payload 0
    assert np.isnan(n1[1]) and np.isnan(n2[1])
    assert n1.tobytes() != n2.tobytes()
    assert raw._key_bytes(n1) == raw._key_bytes(n2)
    # quantized path: NaN rows take the non-finite raw-bytes fallback,
    # which must ALSO see canonical bytes
    assert quant._key_bytes(n1) == quant._key_bytes(n2)

    # all-zero rows take the amax == 0 fallback — same requirement
    z1 = np.zeros(d, np.float32)
    z2 = np.full(d, -0.0, np.float32)
    assert z1.tobytes() != z2.tobytes()
    assert quant._key_bytes(z1) == quant._key_bytes(z2)

    # canonicalization works on a copy, never the caller's row
    keep = b.tobytes()
    raw._key_bytes(b)
    assert b.tobytes() == keep


def test_cache_hits_on_negzero_requery(problem, rank_table, queries):
    """End-to-end: re-asking a cached query with −0.0 instead of +0.0 in
    a coordinate is an LRU HIT serving the identical result."""
    users, _ = problem
    cache = CachingBackend("dense")
    q1 = np.asarray(queries[:1]).copy()
    q1[0, 0] = np.float32(0.0)
    q2 = q1.copy()
    q2[0, 0] = np.float32(-0.0)
    r1 = cache.query_batch(rank_table, users, jnp.asarray(q1), k=K, c=C)
    assert cache.misses == 1 and cache.hits == 0
    r2 = cache.query_batch(rank_table, users, jnp.asarray(q2), k=K, c=C)
    assert cache.misses == 1 and cache.hits == 1
    assert_bitwise(r2, r1)


def test_microbatcher_rejects_width_one(problem, rank_table, queries):
    """Boundary (satellite): max_batch=1 contradicts the module's
    "dispatches never shrink below width 2" invariant and is rejected;
    max_batch=2 — the boundary the invariant allows — works."""
    eng = _engine(problem, rank_table, "dense")
    with pytest.raises(ValueError, match="max_batch must be >= 2"):
        MicroBatcher(eng, max_batch=1)
    with MicroBatcher(eng, max_batch=2, max_wait_ms=5.0) as mb:
        res = mb.submit(queries[0], K, C).result(timeout=120)
    assert res.indices.shape == (K,)


def test_pad_block_width_boundaries(queries):
    """`pad_block` rejects the b = 0 / b > max_batch caller errors AND
    the max_batch < 2 target the old check let through."""
    with pytest.raises(ValueError, match="max_batch must be >= 2"):
        pad_block(queries[:1], 1)
    with pytest.raises(ValueError, match="does not fit"):
        pad_block(queries[:0], 4)
    with pytest.raises(ValueError, match="does not fit"):
        pad_block(queries, 4)


class _FailingEngine:
    """query_batch always raises — exercises the dispatch error path."""

    def query_batch(self, qs, *, k, c):
        raise RuntimeError("induced dispatch failure")


def test_close_under_rejection_flushes_terminal_tick():
    """Satellite: rejects carried by a tick whose dispatch FAILS are
    re-credited, and rejects left after the final tick are flushed into
    a terminal TickStats at close() — no rejection ever vanishes from
    the accounting, and stats() survives a latency-free log."""
    mb = MicroBatcher(_FailingEngine(), max_batch=2, max_wait_ms=60_000.0,
                      max_depth=1)
    try:
        fut = mb.submit(jnp.zeros(4, jnp.float32), K, C)   # queued (head)
        with pytest.raises(QueueFull):
            mb.submit(jnp.ones(4, jnp.float32), K, C)      # depth bound
    finally:
        mb.close()      # cuts the head tick; its dispatch raises
    with pytest.raises(RuntimeError, match="induced dispatch failure"):
        fut.result(timeout=120)
    log = mb.tick_log
    # the failed dispatch recorded no TickStats; the terminal record
    # carries its re-credited rejection
    assert len(log) == 1
    assert log[0].batch == 0 and log[0].latencies_ms == ()
    assert log[0].rejected == 1
    st = mb.stats()
    assert st.rejected == 1 and st.requests == 0 and st.ticks == 1
    assert st.p50_ms == 0.0 and st.p99_ms == 0.0      # no percentile crash
    assert sum(t.rejected for t in log) == st.rejected


def test_tick_compile_counter_flat_after_warmup(problem, rank_table,
                                                queries):
    """Tentpole observability: `TickStats.compiles` samples the query
    stack's compiled-program count around each dispatch. On the elastic
    backend a steady-state tick compiles NOTHING; the warm-up tick (a
    never-seen k makes it a guaranteed fresh trace) is where the programs
    appear."""
    eng = _engine(problem, rank_table, "elastic:dense")
    k_fresh = K + 3                 # unique static k → tick 1 must trace
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=200.0) as mb:
        for _ in range(2):
            futs = [mb.submit(q, k_fresh, C) for q in queries]
            for f in futs:
                f.result(timeout=120)
    log = mb.tick_log
    assert len(log) == 2
    assert log[0].compiles >= 1     # warm-up trace observed
    assert log[1].compiles == 0     # steady state: compile-once holds


# ------------------------------------------------- hypothesis property
if given is not None:
    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, MAX_BATCH - 1),   # query id
                              st.sampled_from([0.0, 0.5, 2.0])),  # gap ms
                    min_size=1, max_size=12))
    def test_random_arrival_patterns(arrivals):
        """(c) Under arbitrary arrival patterns (bursts, stragglers,
        duplicates) every request resolves to the direct per-query
        reference, and the tick accounting adds up."""
        import time
        users, items = make_problem(jax.random.PRNGKey(42), n=512, m=400,
                                    d=16)
        rt = build_rank_table(users, items,
                              RankTableConfig(tau=16, omega=4, s=8),
                              jax.random.PRNGKey(1))
        eng = ReverseKRanksEngine(
            users=users, rank_table=rt,
            config=RankTableConfig(tau=16, omega=4, s=8), backend="dense")
        base = items[(1 + jnp.arange(MAX_BATCH) * 13) % items.shape[0]]
        qs = base * (1.0 + 1e-4 * jax.random.normal(
            jax.random.PRNGKey(7), base.shape, jnp.float32))
        refs = eng.query_batch(qs, k=K, c=C)

        with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=3.0) as mb:
            futs = []
            for qi, gap_ms in arrivals:
                if gap_ms:
                    time.sleep(gap_ms / 1e3)
                futs.append((qi, mb.submit(qs[qi], K, C)))
            results = [(qi, f.result(timeout=120)) for qi, f in futs]
            st_agg = mb.stats()

        for qi, res in results:
            want = jax.tree_util.tree_map(lambda x: x[qi], refs)
            assert_bitwise(res, want, fields=_EXACT_FIELDS)
            np.testing.assert_allclose(np.asarray(res.est_rank),
                                       np.asarray(want.est_rank),
                                       rtol=1e-5, atol=1e-4)
        assert st_agg.requests == len(arrivals)
        log = mb.tick_log
        assert sum(t.batch for t in log) == len(arrivals)
        assert all(0 < t.fill_ratio <= 1.0 for t in log)
else:  # pragma: no cover - optional dep absent
    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_random_arrival_patterns():
        pass


# ---------------------------------------------- overlapped pipeline (PR 10)
class _SlowLeaf:
    """A host-readback leaf whose materialization sleeps: models a device
    result whose D2H is slow, so the completion stage lags dispatch and
    ticks verifiably pile up in flight — without touching real devices."""

    def __init__(self, arr, delay_s):
        self.arr = np.asarray(arr)
        self.delay_s = float(delay_s)

    def __array__(self, dtype=None, copy=None):
        time.sleep(self.delay_s)
        return np.asarray(self.arr, dtype)


class _EchoResult(tuple):
    pass


from typing import NamedTuple as _NamedTuple


class _Echo(_NamedTuple):
    rows: object


class _SlowReadbackEngine:
    """Duck engine: dispatch is instant (async-dispatch analogue), the
    result's host readback sleeps `delay_s`. Echoes the query block so
    per-request results identify their query."""

    def __init__(self, delay_s=0.03):
        self.delay_s = float(delay_s)
        self.calls = 0

    def query_batch(self, qs, *, k, c):
        self.calls += 1
        return _Echo(_SlowLeaf(np.asarray(qs), self.delay_s))


def _pipe_engine(problem, rank_table, backend, storage="float32"):
    users, items = problem
    cfg = RankTableConfig(tau=16, omega=4, s=8, storage_dtype=storage)
    rt = (rank_table if storage == "float32"
          else build_rank_table(users, items, cfg, jax.random.PRNGKey(1)))
    return ReverseKRanksEngine(users=users, rank_table=rt, config=cfg,
                               backend=backend)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("storage", ["float32", "int8"])
@pytest.mark.parametrize("size", [3, 2 * MAX_BATCH + 3])
def test_pipelined_vs_sync_bit_identity(problem, rank_table, backend,
                                        storage, size):
    """The tentpole contract: the double-buffered pipeline returns
    results bit-identical to the synchronous schedule (pipeline_depth=1)
    AND to direct query_batch, per backend × storage spec, for partial
    and multi-tick request streams."""
    eng = _pipe_engine(problem, rank_table, backend, storage)
    users, items = problem
    base = items[(1 + jnp.arange(size) * 7) % items.shape[0]]
    qs = base * (1.0 + 1e-4 * jax.random.normal(
        jax.random.PRNGKey(11), base.shape, jnp.float32))
    direct = eng.query_batch(qs, k=K, c=C)

    def run(depth):
        with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=25.0,
                          pipeline_depth=depth) as mb:
            futs = [mb.submit(q, K, C) for q in qs]
            return [f.result(timeout=120) for f in futs]

    piped, sync = run(2), run(1)
    for i, (p, s) in enumerate(zip(piped, sync)):
        want = jax.tree_util.tree_map(lambda x, i=i: x[i], direct)
        assert_bitwise(p, want)
        assert_bitwise(p, s)


def test_pipeline_depth_validation(problem, rank_table):
    eng = _engine(problem, rank_table, "dense")
    with pytest.raises(ValueError, match="pipeline_depth"):
        MicroBatcher(eng, max_batch=MAX_BATCH, pipeline_depth=0)


@pytest.mark.concurrency
def test_pipeline_overlaps_ticks_and_bounds_inflight():
    """With a slow completion stage, the dispatcher keeps cutting ticks
    until `pipeline_depth` are in flight — and never past it; the
    synchronous schedule (depth 1) never overlaps."""
    d = 8
    qs = np.random.default_rng(0).standard_normal(
        (4 * MAX_BATCH, d)).astype(np.float32)

    def run(depth):
        eng = _SlowReadbackEngine(delay_s=0.03)
        with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=1.0,
                          pipeline_depth=depth) as mb:
            futs = [mb.submit(q, K, C) for q in qs]
            for i, f in enumerate(futs):
                got = f.result(timeout=60)
                np.testing.assert_array_equal(got.rows, qs[i])
        return mb.tick_log, mb.stats()

    log2, st2 = run(2)
    assert max(t.inflight for t in log2) == 2      # overlapped, bounded
    assert st2.overlap_efficiency > 0.0
    log1, st1 = run(1)
    assert max(t.inflight for t in log1) == 1      # sync baseline
    assert st1.overlap_efficiency == 0.0


@pytest.mark.concurrency
def test_futures_resolve_in_dispatch_order():
    """Completion consumes in-flight ticks FIFO: futures resolve in
    submission order even with several ticks in flight."""
    d = 8
    qs = np.random.default_rng(1).standard_normal(
        (3 * MAX_BATCH, d)).astype(np.float32)
    order: list = []
    eng = _SlowReadbackEngine(delay_s=0.02)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=1.0,
                      pipeline_depth=3) as mb:
        futs = []
        for i, q in enumerate(qs):
            f = mb.submit(q, K, C)
            f.add_done_callback(lambda _, i=i: order.append(i))
            futs.append(f)
        for f in futs:
            f.result(timeout=60)
    assert order == sorted(order)


@pytest.mark.concurrency
def test_deadline_under_overlap_only_sheds_undispatched():
    """A request whose budget lapses while its tick is IN FLIGHT still
    resolves (dispatched = committed); one that lapses in the queue
    behind a busy pipeline is swept with the typed error."""
    d = 8
    qs = np.random.default_rng(2).standard_normal(
        (MAX_BATCH + 1, d)).astype(np.float32)
    eng = _SlowReadbackEngine(delay_s=0.05)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=1.0,
                      pipeline_depth=1) as mb:
        # full tick: cuts immediately, completes after ~50 ms — well past
        # its 20 ms budgets, but dispatch already committed it
        committed = [mb.submit(q, K, C, deadline_ms=20.0)
                     for q in qs[:MAX_BATCH]]
        # straggler: queued behind the busy pipeline, budget lapses there
        from repro.serve import DeadlineExceeded
        doomed = mb.submit(qs[-1], K, C, deadline_ms=10.0)
        for i, f in enumerate(committed):
            np.testing.assert_array_equal(f.result(timeout=60).rows, qs[i])
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=60)
    st = mb.stats()
    assert st.expired == 1
    assert sum(t.expired for t in mb.tick_log) == 1


def test_admission_hit_resolves_without_tick(problem, rank_table):
    """PR 10 admission path: an exact LRU hit resolves at submit —
    bitwise the cached result — occupying no queue or tick slot."""
    eng = _engine(problem, rank_table, "cached:dense")
    users, items = problem
    hot = items[0] * 1.0001
    want = eng.query(hot, k=K, c=C)
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=5.0) as mb:
        before = mb._m_admission.value    # registry counter is process-global
        f = mb.submit(hot, K, C)
        got = f.result(timeout=10)
        assert_bitwise(got, want)
        st = mb.stats()
        after = mb._m_admission.value
    assert st.admission_hits == 1
    assert st.requests == 1
    assert mb.tick_log == []            # never became a tick
    assert after == before + 1.0


def test_admission_miss_takes_normal_path(problem, rank_table):
    """A cold query under a cached backend still coalesces into a tick,
    and the NEXT ask of the same query hits at admission."""
    eng = _engine(problem, rank_table, "cached:dense")
    users, items = problem
    q = items[3] * 1.0001
    with MicroBatcher(eng, max_batch=MAX_BATCH, max_wait_ms=5.0) as mb:
        first = mb.submit(q, K, C).result(timeout=60)
        mb.flush()
        second = mb.submit(q, K, C).result(timeout=60)
        st = mb.stats()
    assert_bitwise(second, first)
    assert st.admission_hits == 1
    assert st.requests == 2
    assert sum(t.batch for t in mb.tick_log) == 1
