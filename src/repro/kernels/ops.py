"""jit'd public wrappers around the Pallas kernels (padding + reduction).

These are the entry points the engine uses; each pads inputs to kernel
tile multiples, invokes the raw pallas_call, and undoes the padding.
`interpret=True` everywhere in this container (CPU); on TPU the same
code path runs compiled by flipping `repro.kernels.INTERPRET`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import QueryResult, RankTable
from repro.kernels import exact_rank as _er
from repro.kernels import table_build as _tb
from repro.kernels import user_scores as _us

# Flipped to False on real TPU backends; interpret=True executes the same
# kernel bodies in Python on CPU for validation.
INTERPRET = True

_LANE = 128     # TPU lane width: pad τ and other minor dims to multiples.


def _pad_rows(x: jax.Array, mult: int, value: float = 0.0) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=value)


def _pad_cols_edge(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)), mode="edge")


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks(users: jax.Array, q: jax.Array, thresholds: jax.Array,
                table: jax.Array, *, m: int, block_n: int = 256
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused u·q + rank-table lookup for all users → (r↓, r↑, est)."""
    n, tau = thresholds.shape[0], thresholds.shape[1]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    # Padded user rows read padded threshold rows; edge-padding keeps them
    # ascending so the kernel math stays well-defined (results sliced off).
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    r_lo, r_up, est = _us.bound_ranks_kernel_call(
        up, q.astype(jnp.float32), tp, bp, m=m, tau_valid=tau,
        block_n=block_n, interpret=INTERPRET)
    return r_lo[:n], r_up[:n], est[:n]


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks_batched(users: jax.Array, qs: jax.Array,
                        thresholds: jax.Array, table: jax.Array, *, m: int,
                        block_n: int = 256
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched fused step 1: one (block_n, d) × (d, B) MXU matmul per user
    tile, all B queries bucketized against the same VMEM-resident
    threshold/table tile — the (n, d+2τ) HBM stream is read ONCE for the
    whole batch instead of once per query.

    qs is (B, d); returns (r↓, r↑, est), each (B, n) float32 (query-major,
    the `QueryBackend.bound_ranks` orientation).
    """
    n, tau = thresholds.shape[0], thresholds.shape[1]
    B = qs.shape[0]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    # B pads to a sublane multiple with zero queries; their score columns
    # are well-defined (score 0 against edge-padded thresholds) and are
    # sliced off below.
    qt = _pad_rows(qs.astype(jnp.float32), 8).T             # (d, Bp)
    r_lo, r_up, est = _us.bound_ranks_batched_kernel_call(
        up, qt, tp, bp, m=m, tau_valid=tau, block_n=block_n,
        interpret=INTERPRET)
    return r_lo[:n, :B].T, r_up[:n, :B].T, est[:n, :B].T


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks_batched_pruned(users: jax.Array, qs: jax.Array,
                               thresholds: jax.Array, table: jax.Array,
                               block_ids: jax.Array, *, m: int,
                               block_n: int = 256
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-grid batched step 1 (PR 4): like `bound_ranks_batched`, but
    the Pallas grid runs only over the user tiles named in `block_ids`
    ((nk,) int32, one id per block_n-row tile) via a scalar-prefetch
    block index map — skipped tiles are never read from HBM.

    Returns COMPACTED (r↓, r↑, est), each (B, nk·block_n) float32 in
    block-list order (tile j of the outputs is user tile block_ids[j]);
    the caller scatters back to user coordinates
    (`core.pruning.scatter_select`). Tail-tile padding rows carry
    well-defined junk exactly like the unpruned wrapper's — the scatter
    drops them.
    """
    tau = thresholds.shape[1]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    qt = _pad_rows(qs.astype(jnp.float32), 8).T             # (d, Bp)
    B = qs.shape[0]
    r_lo, r_up, est = _us.bound_ranks_batched_masked_kernel_call(
        up, qt, tp, bp, block_ids.astype(jnp.int32), m=m, tau_valid=tau,
        block_n=block_n, interpret=INTERPRET)
    return r_lo[:, :B].T, r_up[:, :B].T, est[:, :B].T


@functools.partial(jax.jit, static_argnames=("block_n",))
def build_table_rows(users: jax.Array, samples: jax.Array,
                     weights: jax.Array, thresholds: jax.Array, *,
                     block_n: int = 128) -> jax.Array:
    """Eq. (1) table rows for all users (fused matmul + weighted counts)."""
    n, tau = thresholds.shape
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n), _LANE)
    # Padded samples carry weight 0 ⇒ contribute nothing to Eq. (1).
    sp = _pad_rows(samples.astype(jnp.float32), 8)
    wp = _pad_rows(weights.astype(jnp.float32), 8, value=0.0)
    out = _tb.table_build_kernel_call(up, sp, wp, tp, tau_valid=tau,
                                      block_n=block_n, interpret=INTERPRET)
    return out[:n, :tau]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def exact_ranks(users: jax.Array, items: jax.Array, q: jax.Array, *,
                block_n: int = 256, block_m: int = 512) -> jax.Array:
    """Definition-1 ranks via the streaming kernel. Returns (n,) float32."""
    n, m = users.shape[0], items.shape[0]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    # P pads with zero rows: a padded item contributes I[0 > u·q], which is
    # subtracted exactly below (same f32 dot as the kernel's score_q).
    ip = _pad_rows(items.astype(jnp.float32), block_m)
    m_pad = ip.shape[0] - m
    partial = _er.exact_counts_kernel_call(up, ip, q.astype(jnp.float32),
                                           block_n=block_n, block_m=block_m,
                                           interpret=INTERPRET)
    counts = partial.sum(axis=1)[:n]
    if m_pad:
        uq = jax.lax.dot_general(
            up[:n], q.astype(jnp.float32)[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        counts = counts - m_pad * (0.0 > uq).astype(jnp.float32)
    return 1.0 + counts


def query_fused(rt: RankTable, users: jax.Array, q: jax.Array, k: int,
                c: float) -> QueryResult:
    """§4.3 query with step 1 on the fused Pallas kernel; steps 2-3 (O(n)
    top-k/filter tail) in plain jnp — identical selection semantics to
    repro.core.query.query."""
    from repro.core.query import select_topk
    m = int(rt.m)
    r_lo, r_up, est = bound_ranks(users, q, rt.thresholds, rt.table, m=m)
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)


def query_fused_batch(rt: RankTable, users: jax.Array, qs: jax.Array,
                      k: int, c: float) -> QueryResult:
    """Batched §4.3 queries with step 1 on the batched Pallas kernel —
    one table pass for the whole (B, d) query block; selection (steps 2-3)
    via the shared shape-polymorphic `select_topk`. Every QueryResult
    field gains a leading B axis."""
    from repro.core.query import select_topk
    m = int(rt.m)
    r_lo, r_up, est = bound_ranks_batched(users, qs, rt.thresholds,
                                          rt.table, m=m)
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)


# NOTE: there is deliberately no query_fused_*_delta here — the fused
# delta path is the generic `QueryBackend._delta_query` composed over
# `bound_ranks_batched` (see `repro.core.backends.FusedBackend`), so the
# delta pipeline exists exactly once.
