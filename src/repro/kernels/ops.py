"""jit'd public wrappers around the Pallas kernels (padding + reduction).

These are the entry points the engine uses; each pads inputs to kernel
tile multiples, invokes the raw pallas_call, and undoes the padding.
`interpret=True` everywhere in this container (CPU); on TPU the same
code path runs compiled by flipping `repro.kernels.INTERPRET`.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.types import QueryResult, RankTable, StoredUsers
from repro.kernels import exact_rank as _er
from repro.kernels import table_build as _tb
from repro.kernels import user_scores as _us


def _interpret_default() -> bool:
    """interpret=True executes the kernel bodies in Python on CPU for
    validation; on a real TPU set REPRO_INTERPRET=0 to run them compiled
    (the ROADMAP "TPU validation" procedure — no source edit needed)."""
    return os.environ.get("REPRO_INTERPRET", "1").strip().lower() not in (
        "0", "false", "no", "off")


# Flipped to False on real TPU backends — via the REPRO_INTERPRET env var
# at import time, or by assigning repro.kernels.ops.INTERPRET directly.
INTERPRET = _interpret_default()

_LANE = 128     # TPU lane width: pad τ and other minor dims to multiples.


def _pad_rows(x: jax.Array, mult: int, value: float = 0.0) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=value)


def _pad_cols_edge(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)), mode="edge")


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks(users: jax.Array, q: jax.Array, thresholds: jax.Array,
                table: jax.Array, *, m: int, block_n: int = 256
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused u·q + rank-table lookup for all users → (r↓, r↑, est)."""
    n, tau = thresholds.shape[0], thresholds.shape[1]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    # Padded user rows read padded threshold rows; edge-padding keeps them
    # ascending so the kernel math stays well-defined (results sliced off).
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    r_lo, r_up, est = _us.bound_ranks_kernel_call(
        up, q.astype(jnp.float32), tp, bp, m=m, tau_valid=tau,
        block_n=block_n, interpret=INTERPRET)
    return r_lo[:n], r_up[:n], est[:n]


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks_batched(users: jax.Array, qs: jax.Array,
                        thresholds: jax.Array, table: jax.Array, *, m: int,
                        block_n: int = 256
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched fused step 1: one (block_n, d) × (d, B) MXU matmul per user
    tile, all B queries bucketized against the same VMEM-resident
    threshold/table tile — the (n, d+2τ) HBM stream is read ONCE for the
    whole batch instead of once per query.

    qs is (B, d); returns (r↓, r↑, est), each (B, n) float32 (query-major,
    the `QueryBackend.bound_ranks` orientation).
    """
    n, tau = thresholds.shape[0], thresholds.shape[1]
    B = qs.shape[0]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    # B pads to a sublane multiple with zero queries; their score columns
    # are well-defined (score 0 against edge-padded thresholds) and are
    # sliced off below.
    qt = _pad_rows(qs.astype(jnp.float32), 8).T             # (d, Bp)
    r_lo, r_up, est = _us.bound_ranks_batched_kernel_call(
        up, qt, tp, bp, m=m, tau_valid=tau, block_n=block_n,
        interpret=INTERPRET)
    return r_lo[:n, :B].T, r_up[:n, :B].T, est[:n, :B].T


@functools.partial(jax.jit, static_argnames=("m", "block_n"))
def bound_ranks_batched_pruned(users: jax.Array, qs: jax.Array,
                               thresholds: jax.Array, table: jax.Array,
                               block_ids: jax.Array, *, m: int,
                               block_n: int = 256
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-grid batched step 1 (PR 4): like `bound_ranks_batched`, but
    the Pallas grid runs only over the user tiles named in `block_ids`
    ((nk,) int32, one id per block_n-row tile) via a scalar-prefetch
    block index map — skipped tiles are never read from HBM.

    Returns COMPACTED (r↓, r↑, est), each (B, nk·block_n) float32 in
    block-list order (tile j of the outputs is user tile block_ids[j]);
    the caller scatters back to user coordinates
    (`core.pruning.scatter_select`). Tail-tile padding rows carry
    well-defined junk exactly like the unpruned wrapper's — the scatter
    drops them.
    """
    tau = thresholds.shape[1]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE)
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    qt = _pad_rows(qs.astype(jnp.float32), 8).T             # (d, Bp)
    B = qs.shape[0]
    r_lo, r_up, est = _us.bound_ranks_batched_masked_kernel_call(
        up, qt, tp, bp, block_ids.astype(jnp.int32), m=m, tau_valid=tau,
        block_n=block_n, interpret=INTERPRET)
    return r_lo[:, :B].T, r_up[:, :B].T, est[:, :B].T


@functools.partial(jax.jit, static_argnames=("block_n",))
def build_table_rows(users: jax.Array, samples: jax.Array,
                     weights: jax.Array, thresholds: jax.Array, *,
                     block_n: int = 128) -> jax.Array:
    """Eq. (1) table rows for all users (fused matmul + weighted counts)."""
    n, tau = thresholds.shape
    up = _pad_rows(users.astype(jnp.float32), block_n)
    tp = _pad_cols_edge(_pad_rows(thresholds, block_n), _LANE)
    # Padded samples carry weight 0 ⇒ contribute nothing to Eq. (1).
    sp = _pad_rows(samples.astype(jnp.float32), 8)
    wp = _pad_rows(weights.astype(jnp.float32), 8, value=0.0)
    out = _tb.table_build_kernel_call(up, sp, wp, tp, tau_valid=tau,
                                      block_n=block_n, interpret=INTERPRET)
    return out[:n, :tau]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def exact_ranks(users: jax.Array, items: jax.Array, q: jax.Array, *,
                block_n: int = 256, block_m: int = 512) -> jax.Array:
    """Definition-1 ranks via the streaming kernel. Returns (n,) float32."""
    n, m = users.shape[0], items.shape[0]
    up = _pad_rows(users.astype(jnp.float32), block_n)
    # P pads with zero rows: a padded item contributes I[0 > u·q], which is
    # subtracted exactly below (same f32 dot as the kernel's score_q).
    ip = _pad_rows(items.astype(jnp.float32), block_m)
    m_pad = ip.shape[0] - m
    partial = _er.exact_counts_kernel_call(up, ip, q.astype(jnp.float32),
                                           block_n=block_n, block_m=block_m,
                                           interpret=INTERPRET)
    counts = partial.sum(axis=1)[:n]
    if m_pad:
        uq = jax.lax.dot_general(
            up[:n], q.astype(jnp.float32)[:, None],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        counts = counts - m_pad * (0.0 > uq).astype(jnp.float32)
    return 1.0 + counts


def query_fused(rt: RankTable, users: jax.Array, q: jax.Array, k: int,
                c: float) -> QueryResult:
    """§4.3 query with step 1 on the fused Pallas kernel; steps 2-3 (O(n)
    top-k/filter tail) in plain jnp — identical selection semantics to
    repro.core.query.query."""
    from repro.core.query import select_topk
    m = int(rt.m)
    r_lo, r_up, est = bound_ranks(users, q, rt.thresholds, rt.table, m=m)
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)


def query_fused_batch(rt: RankTable, users, qs: jax.Array,
                      k: int, c: float) -> QueryResult:
    """Batched §4.3 queries with step 1 on the batched Pallas kernel —
    one table pass for the whole (B, d) query block; selection (steps 2-3)
    via the shared shape-polymorphic `select_topk`. Every QueryResult
    field gains a leading B axis. Dispatches on the storage spec
    (`bound_ranks_batched_stored`); the f32 spec is the pre-spec path."""
    from repro.core.query import select_topk
    r_lo, r_up, est = bound_ranks_batched_stored(users, qs, rt)
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)


# NOTE: there is deliberately no query_fused_*_delta here — the fused
# delta path is the generic `QueryBackend._delta_query` composed over
# `bound_ranks_batched` (see `repro.core.backends.FusedBackend`), so the
# delta pipeline exists exactly once.


# --------------------------------------------- storage-spec dispatch (PR 5)
def _stored_parts(users, rt: RankTable):
    """Normalize (users, rt) into the quantized kernels' operand set.

    Raw f32 user matrices against a quantized table are served with
    identity scale and zero slack — the kernels' dequant math degenerates
    to the exact path, so mixed inputs (tests, debugging) stay correct.
    """
    if isinstance(users, StoredUsers):
        rows = users.rows
        n = rows.shape[0]
        uscale = (jnp.ones((n, 1), jnp.float32) if users.scale is None
                  else users.scale)
        uslack = (jnp.zeros((n, 1), jnp.float32) if users.row_slack is None
                  else users.row_slack)
    else:
        rows = users
        n = rows.shape[0]
        uscale = jnp.ones((n, 1), jnp.float32)
        uslack = jnp.zeros((n, 1), jnp.float32)
    return rows, uscale, uslack


def _pad_vec(x: jax.Array, mult: int, value: float) -> jax.Array:
    return _pad_rows(x, mult, value=value)



def _pad_quant_operands(kind: str, rows, uscale, uslack, thresholds,
                        table, thr_sc, thr_off, thr_dev, tab_sc, tab_off,
                        block_n: int):
    """Shared operand padding for the quantized kernel wrappers (full-grid
    and masked-grid) — the pad VALUES encode kernel soundness assumptions:
    scale pads 1.0 (no div-by-zero on junk rows), slack/offset/dev pad
    0.0, table pads 1.0, thresholds edge-pad to stay ascending. The int8
    kernel's closed-form bucketize never reads thresholds, so no padded
    copy is materialized for it."""
    up = _pad_rows(rows, block_n)
    usc = _pad_vec(uscale, block_n, 1.0)
    usl = _pad_vec(uslack, block_n, 0.0)
    tp = (None if kind == "int8" else
          _pad_cols_edge(_pad_rows(thresholds, block_n, value=0.0), _LANE))
    bp = _pad_cols_edge(_pad_rows(table, block_n, value=1.0), _LANE)
    if kind == "int8":
        quant = (_pad_vec(thr_sc, block_n, 1.0),
                 _pad_vec(thr_off, block_n, 0.0),
                 _pad_vec(thr_dev, block_n, 0.0),
                 _pad_vec(tab_sc, block_n, 1.0),
                 _pad_vec(tab_off, block_n, 0.0))
    else:
        quant = (None,) * 5
    return (up, usc, usl, tp, bp) + quant


@functools.partial(jax.jit, static_argnames=("kind", "m", "block_n"))
def _bound_ranks_batched_stored_impl(kind: str, rows, uscale, uslack, qs,
                                     thresholds, table, thr_sc, thr_off,
                                     thr_dev, tab_sc, tab_off, *, m: int,
                                     block_n: int = 256):
    """Pad + invoke the quantized batched kernel; returns (B, n) f32."""
    n, tau = thresholds.shape[0], thresholds.shape[1]
    B = qs.shape[0]
    up, usc, usl, tp, bp, tsc, tof, tdv, bsc, bof = _pad_quant_operands(
        kind, rows, uscale, uslack, thresholds, table, thr_sc, thr_off,
        thr_dev, tab_sc, tab_off, block_n)
    qt = _pad_rows(qs.astype(jnp.float32), 8).T             # (d, Bp)
    r_lo, r_up, est = _us.bound_ranks_batched_quant_kernel_call(
        kind, up, usc, usl, qt, tp, bp, tsc, tof, tdv, bsc, bof, m=m,
        tau_valid=tau, block_n=block_n, interpret=INTERPRET)
    return r_lo[:n, :B].T, r_up[:n, :B].T, est[:n, :B].T


def bound_ranks_batched_stored(users, qs: jax.Array, rt: RankTable, *,
                               block_n: int = 256
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Spec-dispatched batched fused step 1 — THE fused-backend entry.

    f32 storage with a raw user matrix routes to the pre-spec
    `bound_ranks_batched` (bit-identical no-op); bf16/int8 route to the
    quantized kernels, whose outputs carry the certified widening (r↓
    rounded down, r↑ up) exactly like the dense dequant-aware lookup.
    """
    kind = rt.spec_kind
    if kind == "f32" and not isinstance(users, StoredUsers):
        return bound_ranks_batched(users, qs, rt.thresholds, rt.table,
                                   m=int(rt.m), block_n=block_n)
    if kind == "f32":
        raise ValueError("quantized user storage requires a quantized "
                         "rank table (uniform StorageSpec)")
    rows, uscale, uslack = _stored_parts(users, rt)
    return _bound_ranks_batched_stored_impl(
        kind, rows, uscale, uslack, qs, rt.thresholds, rt.table,
        rt.thr_scale, rt.thr_off, rt.thr_dev, rt.tab_scale, rt.tab_off,
        m=int(rt.m), block_n=block_n)


def bound_ranks_tile(users, qs: jax.Array, rt: RankTable, *, m: int,
                     block_n: int = 256
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Spec-dispatched fused step 1 for ONE fixed-size user tile — the
    kernel unit of the compile-once elastic scan (`repro.core.elastic`).

    Identical math to `bound_ranks_batched_stored`, with two contract
    changes for use inside a traced fori_loop body:

      * `m` is an explicit STATIC argument (the caller cannot concretize
        the traced `rt.m` mid-trace, and the kernel wrappers take m
        statically);
      * returns USER-major (tile, B) float32 arrays, the orientation the
        scan accumulates in.

    The compile key of the underlying kernel program is
    (tile, d, B, τ, spec) — never the served n; every tile of every
    capacity bucket re-dispatches the same program.
    """
    kind = rt.spec_kind
    if kind == "f32" and not isinstance(users, StoredUsers):
        r_lo, r_up, est = bound_ranks_batched(
            users, qs, rt.thresholds, rt.table, m=m, block_n=block_n)
    elif kind == "f32":
        raise ValueError("quantized user storage requires a quantized "
                         "rank table (uniform StorageSpec)")
    else:
        rows, uscale, uslack = _stored_parts(users, rt)
        r_lo, r_up, est = _bound_ranks_batched_stored_impl(
            kind, rows, uscale, uslack, qs, rt.thresholds, rt.table,
            rt.thr_scale, rt.thr_off, rt.thr_dev, rt.tab_scale,
            rt.tab_off, m=m, block_n=block_n)
    return r_lo.T, r_up.T, est.T


@functools.partial(jax.jit, static_argnames=("kind", "m", "block_n"))
def _bound_ranks_batched_pruned_stored_impl(kind: str, rows, uscale,
                                            uslack, qs, thresholds, table,
                                            thr_sc, thr_off, thr_dev,
                                            tab_sc, tab_off, block_ids, *,
                                            m: int, block_n: int = 256):
    tau = thresholds.shape[1]
    B = qs.shape[0]
    up, usc, usl, tp, bp, tsc, tof, tdv, bsc, bof = _pad_quant_operands(
        kind, rows, uscale, uslack, thresholds, table, thr_sc, thr_off,
        thr_dev, tab_sc, tab_off, block_n)
    qt = _pad_rows(qs.astype(jnp.float32), 8).T
    r_lo, r_up, est = _us.bound_ranks_batched_quant_masked_kernel_call(
        kind, up, usc, usl, qt, tp, bp, tsc, tof, tdv, bsc, bof,
        block_ids.astype(jnp.int32), m=m, tau_valid=tau, block_n=block_n,
        interpret=INTERPRET)
    return r_lo[:, :B].T, r_up[:, :B].T, est[:, :B].T


def bound_ranks_batched_pruned_stored(users, qs: jax.Array, rt: RankTable,
                                      block_ids: jax.Array, *,
                                      block_n: int = 256
                                      ) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """Spec-dispatched masked-grid (pruned) step 1: skipped tiles are
    never DMA'd at ANY storage spec; kept tiles match the full-grid
    quantized kernel exactly. Returns compacted (B, nk·block_n) arrays
    in block-list order (see `bound_ranks_batched_pruned`)."""
    kind = rt.spec_kind
    if kind == "f32" and not isinstance(users, StoredUsers):
        return bound_ranks_batched_pruned(users, qs, rt.thresholds,
                                          rt.table, block_ids,
                                          m=int(rt.m), block_n=block_n)
    if kind == "f32":
        raise ValueError("quantized user storage requires a quantized "
                         "rank table (uniform StorageSpec)")
    rows, uscale, uslack = _stored_parts(users, rt)
    return _bound_ranks_batched_pruned_stored_impl(
        kind, rows, uscale, uslack, qs, rt.thresholds, rt.table,
        rt.thr_scale, rt.thr_off, rt.thr_dev, rt.tab_scale, rt.tab_off,
        block_ids, m=int(rt.m), block_n=block_n)
