"""Rank-table build kernel — the Eq. (1) hot loop of Algorithm 1.

For a tile of B users, fuses

    scores = U_tile @ Samplesᵀ          (B, S)   one MXU matmul
    T̂[:, j] = 1 + Σ_s w_s·I[score > t_j]  ∀j     VPU loop over τ columns

into a single VMEM-resident pass: the (B, S) score tile is produced and
consumed on-chip, never written to HBM. The τ-loop is a `fori_loop` whose
body does a (B, S) compare + weighted reduce — an O(S) vector op per
threshold, which keeps the working set at B·S floats instead of the
naive (B, S, τ) indicator tensor.

Samples are small (S = ω·s ≈ 640 for paper parameters), so the (S, d)
sample matrix is replicated into VMEM for every user tile: S·d·4B ≈ 0.5 MB
at d = 200. The wrapper tiles d only through the choice of B (B·d·4B plus
B·τ·4B must fit VMEM; ops.py picks B accordingly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _table_build_kernel(u_ref, smp_ref, w_ref, thr_ref, out_ref, *,
                        tau_valid: int):
    u = u_ref[...].astype(jnp.float32)                     # (B, d)
    smp = smp_ref[...].astype(jnp.float32)                 # (S, d)
    w = w_ref[...].astype(jnp.float32)                     # (S,)
    thr = thr_ref[...]                                     # (B, τp)
    taup = thr.shape[1]

    scores = jax.lax.dot_general(
        u, smp, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (B, S) on MXU

    def body(j, _):
        t_j = jax.lax.dynamic_slice_in_dim(thr, j, 1, axis=1)   # (B, 1)
        cnt = jnp.sum(jnp.where(scores > t_j, w[None, :], 0.0),
                      axis=1)                              # (B,)
        out_ref[:, pl.dslice(j, 1)] = 1.0 + cnt[:, None]
        return _

    jax.lax.fori_loop(0, tau_valid, body, None)
    # Padded columns (j >= tau_valid) are never written by the loop; they
    # are initialized here so outputs are deterministic.
    @pl.when(tau_valid < taup)
    def _pad():
        out_ref[:, pl.dslice(tau_valid, taup - tau_valid)] = jnp.ones(
            (u.shape[0], taup - tau_valid), jnp.float32)


def table_build_kernel_call(users: jax.Array, samples: jax.Array,
                            weights: jax.Array, thresholds: jax.Array, *,
                            tau_valid: int, block_n: int = 128,
                            interpret: bool = True) -> jax.Array:
    """Raw pallas_call; inputs pre-padded (ops.build_table_rows).

    users (n, d) [n % block_n == 0], samples (S, d), weights (S,),
    thresholds (n, τp) → table (n, τp) float32.
    """
    n, d = users.shape
    s_cnt = samples.shape[0]
    taup = thresholds.shape[1]
    nb = n // block_n
    kern = functools.partial(_table_build_kernel, tau_valid=tau_valid)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((s_cnt, d), lambda i: (0, 0)),    # replicated
            pl.BlockSpec((s_cnt,), lambda i: (0,)),
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, taup), jnp.float32),
        interpret=interpret,
    )(users, samples, weights, thresholds)
