"""Pallas TPU kernels for the paper's compute hot-spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrappers), ref.py (pure-jnp oracles).

  user_scores — fused U·q matvec + rank-table bucketize (§4.3 step 1,
                the O(nd) query hot loop; memory-bound, lookup rides free)
  table_build — fused U·Samplesᵀ + stratified weighted histogram (Eq. 1,
                Algorithm 1's per-user hot loop)
  exact_rank  — streaming Definition-1 counts (refinement / oracle;
                compute-bound item streaming)

Kernels run with interpret=True on CPU (this container) and compile
natively on TPU via `repro.kernels.ops.INTERPRET = False`.
"""
