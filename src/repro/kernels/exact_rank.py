"""Streaming exact-rank kernel — Definition 1 for a block of users.

Used by the refinement path (boundary users whose table bounds are too
loose) and as the in-framework exact oracle. The item set P streams
HBM→VMEM in tiles along a second grid axis; each (user-tile, item-tile)
cell emits a partial count, reduced by the wrapper:

    grid = (n/Bn, m/Bm)
    counts[i, j] = Σ_{p ∈ P_j} I[ U_i · p > U_i · q ]       (Bn,) per cell

u·q is recomputed per item tile (Bn·d MACs — negligible next to the
Bn·Bm·d tile matmul) to keep the kernel scratch-free: partial counts land
in a (n, m/Bm) HBM buffer summed outside. On real hardware the j-axis is
the innermost grid dimension, so U_i and q stay VMEM-resident across the
whole item stream (block re-use), giving the classic compute-bound
streaming schedule: arithmetic intensity ≈ Bn FLOP/byte of P traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _exact_rank_kernel(u_ref, p_ref, q_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)                     # (Bn, d)
    p = p_ref[...].astype(jnp.float32)                     # (Bm, d)
    q = q_ref[...].astype(jnp.float32)                     # (d,)
    score_q = jax.lax.dot_general(
        u, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (Bn, 1)
    up = jax.lax.dot_general(
        u, p, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (Bn, Bm) MXU
    out_ref[...] = jnp.sum((up > score_q).astype(jnp.float32), axis=1,
                           keepdims=True)


def exact_counts_kernel_call(users: jax.Array, items: jax.Array,
                             q: jax.Array, *, block_n: int = 256,
                             block_m: int = 512, interpret: bool = True
                             ) -> jax.Array:
    """Raw pallas_call; users (n,d) [n % Bn == 0], items (m,d) [m % Bm == 0].

    Returns (n, m/Bm) float32 partial counts (wrapper sums axis 1).
    Padded items must be constructed to never beat u·q (ops.exact_ranks
    pads P with -LARGE rows so padded inner products lose strictly).
    """
    n, d = users.shape
    m = items.shape[0]
    nb, mb = n // block_n, m // block_m
    return pl.pallas_call(
        _exact_rank_kernel,
        grid=(nb, mb),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, mb), jnp.float32),
        interpret=interpret,
    )(users, items, q)
