"""Pure-jnp oracles for every Pallas kernel in this package.

Each `ref_*` matches the corresponding kernel bit-for-bit in exact
arithmetic (float32 accumulation); tests sweep shapes/dtypes and
assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_bound_ranks(users: jax.Array, q: jax.Array, thresholds: jax.Array,
                    table: jax.Array, m: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.user_scores: fused u·q + rank-table lookup.

    Returns (r_lo, r_up, est), each (n,) float32 — identical semantics to
    repro.core.query.lookup_bounds but with the count-based bucketize the
    kernel uses (idx = Σ_j I[t_j ≤ score], equivalent to searchsorted
    side='right' on ascending thresholds).
    """
    n, tau = thresholds.shape
    score = (users.astype(jnp.float32) @ q.astype(jnp.float32))
    idx = jnp.sum(thresholds <= score[:, None], axis=1)      # (n,) in [0,τ]
    rows = jnp.arange(n)
    t_up = table[rows, jnp.clip(idx - 1, 0, tau - 1)]
    t_lo = table[rows, jnp.clip(idx, 0, tau - 1)]
    r_up = jnp.where(idx == 0, float(m + 1), t_up)
    r_lo = jnp.where(idx == tau, 1.0, t_lo)
    lo_thr = thresholds[rows, jnp.clip(idx - 1, 0, tau - 1)]
    hi_thr = thresholds[rows, jnp.clip(idx, 0, tau - 1)]
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((score - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau)
    est_in = r_up + (r_lo - r_up) * frac
    # margin-decayed out-of-range estimate (matches core.query.lookup_bounds)
    t_lo_edge = thresholds[:, 0]
    t_hi_edge = thresholds[:, tau - 1]
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(score - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - score, 0.0) / rng
    m1 = float(m + 1)
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau * m_above)
    est_below = m1 - (m1 - r_lo) * jnp.exp(-tau * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau, est_above, est_below))
    est = jnp.clip(est, r_lo, r_up)
    # sub-unit margin tie-break (matches core.query.lookup_bounds)
    return r_lo, r_up, est - 0.5 * m_above / (1.0 + m_above)


def ref_table_rows(users: jax.Array, samples: jax.Array, weights: jax.Array,
                   thresholds: jax.Array) -> jax.Array:
    """Oracle for kernels.table_build: Eq. (1) by direct comparison.

    users (n,d), samples (S,d), weights (S,), thresholds (n,τ) →
    table (n,τ):  1 + Σ_s w_s · I[u·p_s > t_j].
    """
    scores = users.astype(jnp.float32) @ samples.astype(jnp.float32).T
    # (n, S, τ) would blow memory at scale; the oracle runs on test sizes.
    gt = scores[:, :, None] > thresholds[:, None, :]
    return 1.0 + jnp.einsum("nst,s->nt", gt.astype(jnp.float32),
                            weights.astype(jnp.float32))


def ref_exact_counts(users: jax.Array, items: jax.Array, q: jax.Array
                     ) -> jax.Array:
    """Oracle for kernels.exact_rank: #{p : u·p > u·q} per user, float32."""
    uf = users.astype(jnp.float32)
    score_q = uf @ q.astype(jnp.float32)
    up = uf @ items.astype(jnp.float32).T
    return jnp.sum((up > score_q[:, None]).astype(jnp.float32), axis=1)
