"""Fused bound-rank kernels — the query's O(nd) hot loop (§4.3 step 1).

One pass over the user matrix produces (r↓, r↑, est) directly:

    HBM                          VMEM (per grid step i)
    U[i·B : (i+1)·B, :]   ──►    (B, d) user tile         ─┐
    q                     ──►    (d,)  query vector        ├─ MXU matvec
    thresholds[i·B:…, :]  ──►    (B, τ) ascending grid     │  (B,) scores
    table[i·B:…, :]       ──►    (B, τ) rank estimates    ─┘
                                  VPU: count-bucketize + gather + lerp
    r_lo/r_up/est[i·B:…]  ◄──    three (B,) outputs

The (n,) score vector never round-trips to HBM — on TPU the plain
matvec is memory-bound (~1 FLOP/byte), so the bucketize+lookup ride along
under the same HBM bytes. Block sizes: B = block_n users/step (multiple of
8 sublanes; τ and d land on 128-lane tiles after padding by ops.py).

The bucketize is branch-free: idx = Σ_j I[t_j ≤ s AND j < τ_valid], which
equals searchsorted(side='right') for ascending thresholds; padded τ
columns are masked via the `tau_valid` scalar so ops.py can pad τ to a
lane multiple without changing semantics.

BATCHED VARIANT (`_bound_rank_batched_kernel`, PR 1): the same grid over
user blocks, but the matvec becomes one (block_n, d) × (d, B) MXU matmul
and every query column bucketizes against the SAME VMEM-resident
threshold/table tile before the grid advances to the next user block. The
dominant n·(d + 2τ) HBM stream is therefore read once per BATCH instead
of once per query — the table-bandwidth amortization the batched engine
API exists for. Extra cost is pure VPU work (B× compares on data already
in VMEM), which is free under the memory-bound roofline until
B·τ ≈ arithmetic-intensity headroom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.query import _est_from_grid
from repro.core.types import EPS_BF16, _I8_TRANSFORM_PAD

# QUANTIZED-STORAGE VARIANTS (PR 5): the same grid and the same per-tile
# structure, but the HBM operands are the storage-tier arrays — bf16
# rows, or int8 codes plus (block_n, 1) per-row scale/offset vectors that
# ride the same tile index maps. The DMA moves the quantized bytes (the
# ~2×/4× bandwidth win); dequantization is VPU work on VMEM-resident
# tiles (the "int8-input / f32-accumulate" shape: the MXU matmul runs on
# in-register f32 casts of the int8 user tile). Quantization error is
# folded into the outputs — r↓ rounds down, r↑ rounds up, mirroring the
# dense `query._lookup_bounds_{bf16,int8}` certification — so Lemma-1
# selection over kernel outputs stays sound at every spec.


def _bound_rank_kernel(u_ref, q_ref, thr_ref, tab_ref, rlo_ref, rup_ref,
                       est_ref, *, m: int, tau_valid: int):
    u = u_ref[...].astype(jnp.float32)                    # (B, d)
    q = q_ref[...].astype(jnp.float32)                    # (d,)
    thr = thr_ref[...]                                    # (B, τp)
    tab = tab_ref[...]                                    # (B, τp)
    taup = thr.shape[1]

    score = jax.lax.dot_general(
        u, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]         # (B,) MXU matvec

    col = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)
    valid = col < tau_valid
    le = (thr <= score[:, None]) & valid
    idx = jnp.sum(le.astype(jnp.int32), axis=1)           # (B,) ∈ [0, τ]

    up_col = jnp.clip(idx - 1, 0, taup - 1)[:, None]
    lo_col = jnp.clip(idx, 0, tau_valid - 1)[:, None]
    t_up = jnp.take_along_axis(tab, up_col, axis=1)[:, 0]
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1)[:, 0]
    r_up = jnp.where(idx == 0, float(m + 1), t_up)
    r_lo = jnp.where(idx == tau_valid, 1.0, t_lo)

    lo_thr = jnp.take_along_axis(thr, up_col, axis=1)[:, 0]
    hi_thr = jnp.take_along_axis(thr, lo_col, axis=1)[:, 0]
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((score - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau_valid)
    est_in = r_up + (r_lo - r_up) * frac
    # margin-decayed out-of-range estimate (matches ref_bound_ranks)
    t_lo_edge = thr[:, 0]
    t_hi_edge = jnp.take_along_axis(
        thr, jnp.full((thr.shape[0], 1), tau_valid - 1, jnp.int32),
        axis=1)[:, 0]
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(score - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - score, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau_valid * m_above)
    est_below = float(m + 1) - (float(m + 1) - r_lo) * jnp.exp(
        -tau_valid * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau_valid, est_above, est_below))

    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    # sub-unit margin tie-break (matches ref_bound_ranks)
    est_ref[...] = jnp.clip(est, r_lo, r_up) - 0.5 * m_above / (1.0 + m_above)


def bound_ranks_kernel_call(users: jax.Array, q: jax.Array,
                            thresholds: jax.Array, table: jax.Array, *,
                            m: int, tau_valid: int, block_n: int = 256,
                            interpret: bool = True
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Raw pallas_call; inputs must be pre-padded (see ops.bound_ranks).

    users (n, d) [n % block_n == 0], q (d,), thresholds/table (n, τp) f32.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    nb = n // block_n
    kern = functools.partial(_bound_rank_kernel, m=m, tau_valid=tau_valid)
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 3
    vec_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # U tile
            pl.BlockSpec((d,), lambda i: (0,)),             # q (replicated)
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        ],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(users, q, thresholds, table)


def _bound_rank_batched_kernel(u_ref, qt_ref, thr_ref, tab_ref, rlo_ref,
                               rup_ref, est_ref, *, m: int, tau_valid: int):
    """Batched twin of `_bound_rank_kernel`: all B queries against one
    VMEM-resident user/threshold/table tile (see module docstring)."""
    u = u_ref[...].astype(jnp.float32)                    # (Bn, d)
    qt = qt_ref[...].astype(jnp.float32)                  # (d, B)
    thr = thr_ref[...]                                    # (Bn, τp)
    tab = tab_ref[...]                                    # (Bn, τp)
    taup = thr.shape[1]

    score = jax.lax.dot_general(
        u, qt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Bn, B) one matmul

    col = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)
    valid = col < tau_valid                               # (Bn, τp)
    # Every query column bucketizes against the SAME resident tile; the
    # (Bn, B, τp) compare is VPU work on data already in VMEM.
    le = (thr[:, None, :] <= score[:, :, None]) & valid[:, None, :]
    idx = jnp.sum(le.astype(jnp.int32), axis=2)           # (Bn, B) ∈ [0, τ]

    up_col = jnp.clip(idx - 1, 0, taup - 1)
    lo_col = jnp.clip(idx, 0, tau_valid - 1)
    t_up = jnp.take_along_axis(tab, up_col, axis=1)       # (Bn, B)
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1)
    r_up = jnp.where(idx == 0, float(m + 1), t_up)
    r_lo = jnp.where(idx == tau_valid, 1.0, t_lo)

    lo_thr = jnp.take_along_axis(thr, up_col, axis=1)
    hi_thr = jnp.take_along_axis(thr, lo_col, axis=1)
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((score - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau_valid)
    est_in = r_up + (r_lo - r_up) * frac
    # margin-decayed out-of-range estimate (matches ref_bound_ranks)
    t_lo_edge = thr[:, :1]                                # (Bn, 1)
    t_hi_edge = jnp.take_along_axis(
        thr, jnp.full((thr.shape[0], 1), tau_valid - 1, jnp.int32),
        axis=1)
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(score - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - score, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau_valid * m_above)
    est_below = float(m + 1) - (float(m + 1) - r_lo) * jnp.exp(
        -tau_valid * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau_valid, est_above, est_below))

    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    # sub-unit margin tie-break (matches ref_bound_ranks)
    est_ref[...] = jnp.clip(est, r_lo, r_up) - 0.5 * m_above / (1.0 + m_above)


def bound_ranks_batched_masked_kernel_call(
        users: jax.Array, qt: jax.Array, thresholds: jax.Array,
        table: jax.Array, block_ids: jax.Array, *, m: int, tau_valid: int,
        block_n: int = 256, interpret: bool = True
        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-grid twin of `bound_ranks_batched_kernel_call` (PR 4): the
    grid runs over the KEPT block list instead of every user tile.

    `block_ids` (nk,) int32 selects which user/threshold/table tiles each
    grid step loads — the tile index maps read it as a SCALAR-PREFETCH
    operand (`pltpu.PrefetchScalarGridSpec`), so the DMA engine fetches
    exactly the surviving tiles and the n·(d + 2τ) HBM stream shrinks to
    the kept fraction. Outputs are COMPACTED: grid step i writes tile i
    of three (nk·block_n, B) arrays (the caller scatters them back to
    user coordinates — writing through the same index map would leave
    skipped tiles uninitialized).

    Per-tile math is `_bound_rank_batched_kernel` verbatim — a kept
    tile's (block_n, d) × (d, B) matmul sees the identical operand tile
    as the full scan, so compacted results are bit-identical to the
    corresponding rows of the unpruned kernel.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    B = qt.shape[1]
    nk = block_ids.shape[0]
    kern = functools.partial(_bound_rank_batched_kernel, m=m,
                             tau_valid=tau_valid)

    def tile(i, ids):
        return (ids[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((block_n, d), tile),               # U tile (gathered)
            pl.BlockSpec((d, B), lambda i, ids: (0, 0)),    # Qᵀ (replicated)
            pl.BlockSpec((block_n, taup), tile),
            pl.BlockSpec((block_n, taup), tile),
        ],
        out_specs=[pl.BlockSpec((block_n, B), lambda i, ids: (i, 0))] * 3,
    )

    def wrapped(ids_ref, u_ref, qt_ref, thr_ref, tab_ref, rlo_ref, rup_ref,
                est_ref):
        # the prefetched id array steers the index maps only; the tile
        # body is the stock batched kernel
        kern(u_ref, qt_ref, thr_ref, tab_ref, rlo_ref, rup_ref, est_ref)

    out_shape = [jax.ShapeDtypeStruct((nk * block_n, B), jnp.float32)] * 3
    return pl.pallas_call(
        wrapped,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_ids, users, qt, thresholds, table)


def bound_ranks_batched_kernel_call(users: jax.Array, qt: jax.Array,
                                    thresholds: jax.Array, table: jax.Array,
                                    *, m: int, tau_valid: int,
                                    block_n: int = 256,
                                    interpret: bool = True
                                    ) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Raw batched pallas_call; inputs pre-padded (see ops.bound_ranks_batched).

    users (n, d) [n % block_n == 0], qt (d, B) [B a sublane multiple],
    thresholds/table (n, τp) f32. Returns three (n, B) float32 arrays.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    B = qt.shape[1]
    nb = n // block_n
    kern = functools.partial(_bound_rank_batched_kernel, m=m,
                             tau_valid=tau_valid)
    out_shape = [jax.ShapeDtypeStruct((n, B), jnp.float32)] * 3
    out_spec = pl.BlockSpec((block_n, B), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # U tile
            pl.BlockSpec((d, B), lambda i: (0, 0)),         # Qᵀ (replicated)
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(users, qt, thresholds, table)


def _est_tail(score, idx_hi, thr_up, thr_lo, edge_lo, edge_hi, r_lo, r_up,
              tau_valid: int, m: int):
    """§4.3-step-3 estimate on dequantized f32 grid values — THE shared
    implementation (`query._est_from_grid`); kernels call it on
    VMEM-resident tiles so the dense and fused quantized paths cannot
    drift on the interpolation/margin-decay/tie-break math."""
    return _est_from_grid(score, idx_hi, thr_up, thr_lo, edge_lo, edge_hi,
                          r_lo, r_up, tau_valid, float(m + 1))


def _bound_rank_batched_bf16_kernel(u_ref, uslack_ref, qt_ref, thr_ref,
                                    tab_ref, rlo_ref, rup_ref, est_ref, *,
                                    m: int, tau_valid: int):
    """bf16-storage twin of `_bound_rank_batched_kernel`.

    Certification mirrors `query._lookup_bounds_bf16`: the score interval
    [s−δ, s+δ] (δ = per-row slack · ‖q‖₁, covering the bf16 user rows) is
    cast to bf16 — the cast is monotone, so a two-sided count brackets the
    true bucketize index — and table reads widen by EPS_BF16 in the
    certified direction. All compares are VPU work on the VMEM-resident
    bf16 tile; HBM moved only bf16 bytes.
    """
    u = u_ref[...].astype(jnp.float32)                    # (Bn, d) ← bf16
    qt = qt_ref[...].astype(jnp.float32)                  # (d, B)
    thr = thr_ref[...]                                    # (Bn, τp) bf16
    taup = thr.shape[1]
    score = jax.lax.dot_general(
        u, qt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Bn, B)
    slack = uslack_ref[...] * jnp.sum(jnp.abs(qt), axis=0)[None, :]
    s_hi = (score + slack).astype(thr.dtype)              # (Bn, B) bf16
    s_lo = (score - slack).astype(thr.dtype)

    col = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)
    valid = (col < tau_valid)[:, None, :]
    le_hi = (thr[:, None, :] <= s_hi[:, :, None]) & valid
    idx_hi = jnp.sum(le_hi.astype(jnp.int32), axis=2)     # ≥ idx*
    lt_lo = (thr[:, None, :] < s_lo[:, :, None]) & valid
    idx_lo = jnp.sum(lt_lo.astype(jnp.int32), axis=2)     # ≤ idx*

    tab = tab_ref[...].astype(jnp.float32)                # (Bn, τp)
    up_col = jnp.clip(idx_lo - 1, 0, taup - 1)
    lo_col = jnp.clip(idx_hi, 0, tau_valid - 1)
    t_up = jnp.take_along_axis(tab, up_col, axis=1)
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1)
    r_up = jnp.where(idx_lo == 0, float(m + 1), t_up * (1.0 + EPS_BF16))
    r_lo = jnp.where(idx_hi == tau_valid, 1.0, t_lo * (1.0 - EPS_BF16))

    thr32 = thr.astype(jnp.float32)
    thr_up = jnp.take_along_axis(thr32, jnp.clip(idx_hi - 1, 0, taup - 1),
                                 axis=1)
    thr_lo = jnp.take_along_axis(thr32, lo_col, axis=1)
    edge_lo = thr32[:, :1]
    edge_hi = jnp.take_along_axis(
        thr32, jnp.full((thr.shape[0], 1), tau_valid - 1, jnp.int32),
        axis=1)
    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    est_ref[...] = _est_tail(score, idx_hi, thr_up, thr_lo, edge_lo,
                             edge_hi, r_lo, r_up, tau_valid, m)


def _bound_rank_batched_int8_kernel(u_ref, uscale_ref, uslack_ref, qt_ref,
                                    thr_sc_ref, thr_off_ref, thr_dev_ref,
                                    tab_ref, tab_sc_ref, tab_off_ref,
                                    rlo_ref, rup_ref, est_ref, *, m: int,
                                    tau_valid: int):
    """int8-storage twin of `_bound_rank_batched_kernel` — int8 inputs,
    f32 accumulate, CLOSED-FORM bucketize.

    The user tile is cast in-register and scaled per row; the bucketize
    is the uniform-grid closed form of `query._lookup_bounds_int8`
    (thresholds are an affine grid in code units within the certified
    per-row `thr_dev`), so the threshold matrix is NEVER DMA'd — the HBM
    stream per tile is the int8 user rows + int8 table codes + five
    (block_n, 1) f32 vectors, the ~4× bandwidth cut on the scan. Table
    codes dequantize per row and widen by (½ + pad)·scale in the
    certified direction.
    """
    u = u_ref[...].astype(jnp.float32)                    # (Bn, d) ← int8
    qt = qt_ref[...].astype(jnp.float32)                  # (d, B)
    score = jax.lax.dot_general(
        u, qt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * uscale_ref[...]
    slack = uslack_ref[...] * jnp.sum(jnp.abs(qt), axis=0)[None, :]

    sc_t = thr_sc_ref[...]                                # (Bn, 1)
    off_t = thr_off_ref[...]
    s_n = (score - off_t) / sc_t                          # (Bn, B) in codes
    d_n = slack / sc_t
    dev = thr_dev_ref[...] + 20.0 * _I8_TRANSFORM_PAD
    delta = 254.0 / (tau_valid - 1)
    count = lambda v: jnp.clip(
        jnp.floor((v + 127.0) / delta), -1.0, float(tau_valid)
    ).astype(jnp.int32) + 1
    idx_hi = jnp.clip(count(s_n + d_n + dev), 0, tau_valid)   # ≥ idx*
    idx_lo = jnp.clip(count(s_n - d_n - dev), 0, tau_valid)   # ≤ idx*

    tab_f = tab_ref[...].astype(jnp.float32)
    taup = tab_f.shape[1]
    sc_b = tab_sc_ref[...]
    off_b = tab_off_ref[...]
    deq = lambda c: jnp.take_along_axis(tab_f, c, axis=1) * sc_b + off_b
    widen = (0.5 + _I8_TRANSFORM_PAD) * sc_b
    up_col = jnp.clip(idx_lo - 1, 0, taup - 1)
    lo_col = jnp.clip(idx_hi, 0, tau_valid - 1)
    r_up = jnp.where(idx_lo == 0, float(m + 1), deq(up_col) + widen)
    r_lo = jnp.where(idx_hi == tau_valid, 1.0, deq(lo_col) - widen)

    grid_at = lambda c: ((c.astype(jnp.float32) * delta - 127.0) * sc_t
                         + off_t)
    thr_up = grid_at(jnp.clip(idx_hi - 1, 0, taup - 1))
    thr_lo = grid_at(lo_col)
    edge_lo = -127.0 * sc_t + off_t
    edge_hi = 127.0 * sc_t + off_t
    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    est_ref[...] = _est_tail(score, idx_hi, thr_up, thr_lo, edge_lo,
                             edge_hi, r_lo, r_up, tau_valid, m)


def _quant_kernel_and_operands(kind: str, users, uscale, uslack, qt,
                               thresholds, table, thr_sc, thr_off,
                               thr_dev, tab_sc, tab_off, *, m: int,
                               tau_valid: int):
    """(kernel, operands, per-operand block factories) for a storage kind.

    Each factory maps (block_n, d, taup, B) → the operand's block shape;
    vector operands are (block_n, 1) tiles riding the same row index map.
    Shared by the full-grid and the masked-grid (pruned) callers. The
    int8 kernel takes NO threshold operand (closed-form bucketize).
    """
    if kind == "bf16":
        kern = functools.partial(_bound_rank_batched_bf16_kernel, m=m,
                                 tau_valid=tau_valid)
        ops = (users, uslack, qt, thresholds, table)
        shapes = (lambda b, d, t, B: (b, d), lambda b, d, t, B: (b, 1),
                  "q", lambda b, d, t, B: (b, t), lambda b, d, t, B: (b, t))
        return kern, ops, shapes
    kern = functools.partial(_bound_rank_batched_int8_kernel, m=m,
                             tau_valid=tau_valid)
    ops = (users, uscale, uslack, qt, thr_sc, thr_off, thr_dev, table,
           tab_sc, tab_off)
    vec = lambda b, d, t, B: (b, 1)
    shapes = (lambda b, d, t, B: (b, d), vec, vec, "q", vec, vec, vec,
              lambda b, d, t, B: (b, t), vec, vec)
    return kern, ops, shapes


def bound_ranks_batched_quant_kernel_call(
        kind: str, users, uscale, uslack, qt, thresholds, table, thr_sc,
        thr_off, thr_dev, tab_sc, tab_off, *, m: int, tau_valid: int,
        block_n: int = 256, interpret: bool = True
        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Raw quantized-storage batched pallas_call (inputs pre-padded, see
    ops._bound_ranks_batched_stored_impl). Returns three (n, B) f32."""
    n, d = users.shape
    taup = table.shape[1]
    B = qt.shape[1]
    nb = n // block_n
    kern, ops, shapes = _quant_kernel_and_operands(
        kind, users, uscale, uslack, qt, thresholds, table, thr_sc,
        thr_off, thr_dev, tab_sc, tab_off, m=m, tau_valid=tau_valid)
    in_specs = [
        pl.BlockSpec((d, B), lambda i: (0, 0)) if s == "q"
        else pl.BlockSpec(s(block_n, d, taup, B), lambda i: (i, 0))
        for s in shapes]
    out_spec = pl.BlockSpec((block_n, B), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((n, B), jnp.float32)] * 3
    return pl.pallas_call(
        kern, grid=(nb,), in_specs=in_specs,
        out_specs=[out_spec] * 3, out_shape=out_shape,
        interpret=interpret)(*ops)


def bound_ranks_batched_quant_masked_kernel_call(
        kind: str, users, uscale, uslack, qt, thresholds, table, thr_sc,
        thr_off, thr_dev, tab_sc, tab_off, block_ids: jax.Array, *, m: int,
        tau_valid: int, block_n: int = 256, interpret: bool = True
        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-grid (pruned) twin of the quantized batched call: the grid
    runs only over the kept tiles named by the scalar-prefetch
    `block_ids`, exactly like `bound_ranks_batched_masked_kernel_call` —
    the (block_n, 1) scale/offset/slack vectors ride the same gathered
    tile index map as the rows they describe. Outputs are COMPACTED
    (nk·block_n, B) arrays in block-list order."""
    n, d = users.shape
    taup = table.shape[1]
    B = qt.shape[1]
    nk = block_ids.shape[0]
    kern, ops, shapes = _quant_kernel_and_operands(
        kind, users, uscale, uslack, qt, thresholds, table, thr_sc,
        thr_off, thr_dev, tab_sc, tab_off, m=m, tau_valid=tau_valid)

    def tile(i, ids):
        return (ids[i], 0)

    in_specs = [
        pl.BlockSpec((d, B), lambda i, ids: (0, 0)) if s == "q"
        else pl.BlockSpec(s(block_n, d, taup, B), tile)
        for s in shapes]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nk,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_n, B), lambda i, ids: (i, 0))] * 3,
    )

    def wrapped(ids_ref, *refs):
        kern(*refs)

    out_shape = [jax.ShapeDtypeStruct((nk * block_n, B), jnp.float32)] * 3
    return pl.pallas_call(
        wrapped, grid_spec=grid_spec, out_shape=out_shape,
        interpret=interpret)(block_ids, *ops)
