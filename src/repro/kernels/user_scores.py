"""Fused bound-rank kernels — the query's O(nd) hot loop (§4.3 step 1).

One pass over the user matrix produces (r↓, r↑, est) directly:

    HBM                          VMEM (per grid step i)
    U[i·B : (i+1)·B, :]   ──►    (B, d) user tile         ─┐
    q                     ──►    (d,)  query vector        ├─ MXU matvec
    thresholds[i·B:…, :]  ──►    (B, τ) ascending grid     │  (B,) scores
    table[i·B:…, :]       ──►    (B, τ) rank estimates    ─┘
                                  VPU: count-bucketize + gather + lerp
    r_lo/r_up/est[i·B:…]  ◄──    three (B,) outputs

The (n,) score vector never round-trips to HBM — on TPU the plain
matvec is memory-bound (~1 FLOP/byte), so the bucketize+lookup ride along
under the same HBM bytes. Block sizes: B = block_n users/step (multiple of
8 sublanes; τ and d land on 128-lane tiles after padding by ops.py).

The bucketize is branch-free: idx = Σ_j I[t_j ≤ s AND j < τ_valid], which
equals searchsorted(side='right') for ascending thresholds; padded τ
columns are masked via the `tau_valid` scalar so ops.py can pad τ to a
lane multiple without changing semantics.

BATCHED VARIANT (`_bound_rank_batched_kernel`, PR 1): the same grid over
user blocks, but the matvec becomes one (block_n, d) × (d, B) MXU matmul
and every query column bucketizes against the SAME VMEM-resident
threshold/table tile before the grid advances to the next user block. The
dominant n·(d + 2τ) HBM stream is therefore read once per BATCH instead
of once per query — the table-bandwidth amortization the batched engine
API exists for. Extra cost is pure VPU work (B× compares on data already
in VMEM), which is free under the memory-bound roofline until
B·τ ≈ arithmetic-intensity headroom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bound_rank_kernel(u_ref, q_ref, thr_ref, tab_ref, rlo_ref, rup_ref,
                       est_ref, *, m: int, tau_valid: int):
    u = u_ref[...].astype(jnp.float32)                    # (B, d)
    q = q_ref[...].astype(jnp.float32)                    # (d,)
    thr = thr_ref[...]                                    # (B, τp)
    tab = tab_ref[...]                                    # (B, τp)
    taup = thr.shape[1]

    score = jax.lax.dot_general(
        u, q[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]         # (B,) MXU matvec

    col = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)
    valid = col < tau_valid
    le = (thr <= score[:, None]) & valid
    idx = jnp.sum(le.astype(jnp.int32), axis=1)           # (B,) ∈ [0, τ]

    up_col = jnp.clip(idx - 1, 0, taup - 1)[:, None]
    lo_col = jnp.clip(idx, 0, tau_valid - 1)[:, None]
    t_up = jnp.take_along_axis(tab, up_col, axis=1)[:, 0]
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1)[:, 0]
    r_up = jnp.where(idx == 0, float(m + 1), t_up)
    r_lo = jnp.where(idx == tau_valid, 1.0, t_lo)

    lo_thr = jnp.take_along_axis(thr, up_col, axis=1)[:, 0]
    hi_thr = jnp.take_along_axis(thr, lo_col, axis=1)[:, 0]
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((score - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau_valid)
    est_in = r_up + (r_lo - r_up) * frac
    # margin-decayed out-of-range estimate (matches ref_bound_ranks)
    t_lo_edge = thr[:, 0]
    t_hi_edge = jnp.take_along_axis(
        thr, jnp.full((thr.shape[0], 1), tau_valid - 1, jnp.int32),
        axis=1)[:, 0]
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(score - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - score, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau_valid * m_above)
    est_below = float(m + 1) - (float(m + 1) - r_lo) * jnp.exp(
        -tau_valid * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau_valid, est_above, est_below))

    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    # sub-unit margin tie-break (matches ref_bound_ranks)
    est_ref[...] = jnp.clip(est, r_lo, r_up) - 0.5 * m_above / (1.0 + m_above)


def bound_ranks_kernel_call(users: jax.Array, q: jax.Array,
                            thresholds: jax.Array, table: jax.Array, *,
                            m: int, tau_valid: int, block_n: int = 256,
                            interpret: bool = True
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Raw pallas_call; inputs must be pre-padded (see ops.bound_ranks).

    users (n, d) [n % block_n == 0], q (d,), thresholds/table (n, τp) f32.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    nb = n // block_n
    kern = functools.partial(_bound_rank_kernel, m=m, tau_valid=tau_valid)
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 3
    vec_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # U tile
            pl.BlockSpec((d,), lambda i: (0,)),             # q (replicated)
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        ],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(users, q, thresholds, table)


def _bound_rank_batched_kernel(u_ref, qt_ref, thr_ref, tab_ref, rlo_ref,
                               rup_ref, est_ref, *, m: int, tau_valid: int):
    """Batched twin of `_bound_rank_kernel`: all B queries against one
    VMEM-resident user/threshold/table tile (see module docstring)."""
    u = u_ref[...].astype(jnp.float32)                    # (Bn, d)
    qt = qt_ref[...].astype(jnp.float32)                  # (d, B)
    thr = thr_ref[...]                                    # (Bn, τp)
    tab = tab_ref[...]                                    # (Bn, τp)
    taup = thr.shape[1]

    score = jax.lax.dot_general(
        u, qt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Bn, B) one matmul

    col = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)
    valid = col < tau_valid                               # (Bn, τp)
    # Every query column bucketizes against the SAME resident tile; the
    # (Bn, B, τp) compare is VPU work on data already in VMEM.
    le = (thr[:, None, :] <= score[:, :, None]) & valid[:, None, :]
    idx = jnp.sum(le.astype(jnp.int32), axis=2)           # (Bn, B) ∈ [0, τ]

    up_col = jnp.clip(idx - 1, 0, taup - 1)
    lo_col = jnp.clip(idx, 0, tau_valid - 1)
    t_up = jnp.take_along_axis(tab, up_col, axis=1)       # (Bn, B)
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1)
    r_up = jnp.where(idx == 0, float(m + 1), t_up)
    r_lo = jnp.where(idx == tau_valid, 1.0, t_lo)

    lo_thr = jnp.take_along_axis(thr, up_col, axis=1)
    hi_thr = jnp.take_along_axis(thr, lo_col, axis=1)
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((score - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau_valid)
    est_in = r_up + (r_lo - r_up) * frac
    # margin-decayed out-of-range estimate (matches ref_bound_ranks)
    t_lo_edge = thr[:, :1]                                # (Bn, 1)
    t_hi_edge = jnp.take_along_axis(
        thr, jnp.full((thr.shape[0], 1), tau_valid - 1, jnp.int32),
        axis=1)
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(score - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - score, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau_valid * m_above)
    est_below = float(m + 1) - (float(m + 1) - r_lo) * jnp.exp(
        -tau_valid * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau_valid, est_above, est_below))

    rlo_ref[...] = r_lo
    rup_ref[...] = r_up
    # sub-unit margin tie-break (matches ref_bound_ranks)
    est_ref[...] = jnp.clip(est, r_lo, r_up) - 0.5 * m_above / (1.0 + m_above)


def bound_ranks_batched_masked_kernel_call(
        users: jax.Array, qt: jax.Array, thresholds: jax.Array,
        table: jax.Array, block_ids: jax.Array, *, m: int, tau_valid: int,
        block_n: int = 256, interpret: bool = True
        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Masked-grid twin of `bound_ranks_batched_kernel_call` (PR 4): the
    grid runs over the KEPT block list instead of every user tile.

    `block_ids` (nk,) int32 selects which user/threshold/table tiles each
    grid step loads — the tile index maps read it as a SCALAR-PREFETCH
    operand (`pltpu.PrefetchScalarGridSpec`), so the DMA engine fetches
    exactly the surviving tiles and the n·(d + 2τ) HBM stream shrinks to
    the kept fraction. Outputs are COMPACTED: grid step i writes tile i
    of three (nk·block_n, B) arrays (the caller scatters them back to
    user coordinates — writing through the same index map would leave
    skipped tiles uninitialized).

    Per-tile math is `_bound_rank_batched_kernel` verbatim — a kept
    tile's (block_n, d) × (d, B) matmul sees the identical operand tile
    as the full scan, so compacted results are bit-identical to the
    corresponding rows of the unpruned kernel.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    B = qt.shape[1]
    nk = block_ids.shape[0]
    kern = functools.partial(_bound_rank_batched_kernel, m=m,
                             tau_valid=tau_valid)

    def tile(i, ids):
        return (ids[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((block_n, d), tile),               # U tile (gathered)
            pl.BlockSpec((d, B), lambda i, ids: (0, 0)),    # Qᵀ (replicated)
            pl.BlockSpec((block_n, taup), tile),
            pl.BlockSpec((block_n, taup), tile),
        ],
        out_specs=[pl.BlockSpec((block_n, B), lambda i, ids: (i, 0))] * 3,
    )

    def wrapped(ids_ref, u_ref, qt_ref, thr_ref, tab_ref, rlo_ref, rup_ref,
                est_ref):
        # the prefetched id array steers the index maps only; the tile
        # body is the stock batched kernel
        kern(u_ref, qt_ref, thr_ref, tab_ref, rlo_ref, rup_ref, est_ref)

    out_shape = [jax.ShapeDtypeStruct((nk * block_n, B), jnp.float32)] * 3
    return pl.pallas_call(
        wrapped,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_ids, users, qt, thresholds, table)


def bound_ranks_batched_kernel_call(users: jax.Array, qt: jax.Array,
                                    thresholds: jax.Array, table: jax.Array,
                                    *, m: int, tau_valid: int,
                                    block_n: int = 256,
                                    interpret: bool = True
                                    ) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Raw batched pallas_call; inputs pre-padded (see ops.bound_ranks_batched).

    users (n, d) [n % block_n == 0], qt (d, B) [B a sublane multiple],
    thresholds/table (n, τp) f32. Returns three (n, B) float32 arrays.
    """
    n, d = users.shape
    taup = thresholds.shape[1]
    B = qt.shape[1]
    nb = n // block_n
    kern = functools.partial(_bound_rank_batched_kernel, m=m,
                             tau_valid=tau_valid)
    out_shape = [jax.ShapeDtypeStruct((n, B), jnp.float32)] * 3
    out_spec = pl.BlockSpec((block_n, B), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # U tile
            pl.BlockSpec((d, B), lambda i: (0, 0)),         # Qᵀ (replicated)
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
            pl.BlockSpec((block_n, taup), lambda i: (i, 0)),
        ],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(users, qt, thresholds, table)
