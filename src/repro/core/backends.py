"""Pluggable query-execution backends for the reverse k-ranks engine.

One `QueryBackend` protocol, three registered implementations:

  "dense"   — pure-jnp XLA path (`core.query`): one (n,d)×(d,B) matmul +
              one streamed table pass per batch. The default; runs
              anywhere.
  "fused"   — Pallas path (`kernels.ops.bound_ranks_batched`): the same
              math with step 1 fused into a single HBM pass per user tile
              (interpret=True on CPU, compiled on TPU).
  "sharded" — mesh path (`core.distributed`): row-sharded users/table,
              local batched step 1, tree-merge top-k gathering (B, k·P)
              candidates in one collective.

The protocol is batched-first: `bound_ranks` takes a (B, d) query block
and returns (B, n) bound arrays; `select` realizes §4.3 steps 2-3 with a
leading batch axis; `query_batch` composes the two (backends may override
it with a fully fused pipeline, as "sharded" does). Single-query
execution everywhere is the B = 1 case of the batched path — there is no
separate per-query code to drift out of sync.

Wrapper backends compose by NAME with a `<prefix>:<inner>` spec: the
prefix selects a registered wrapper factory, which resolves the inner
backend recursively. The serving cache (`repro.serve.cache`) registers
`"cached"`, so `backend="cached:fused"` builds a `CachingBackend` around
the Pallas path (within-tick dedupe + cross-tick per-query LRU) without
the engine knowing anything about caching.

Registering a new backend::

    from repro.core.backends import QueryBackend, register_backend

    @register_backend("mine")
    class MyBackend(QueryBackend):
        def bound_ranks(self, rt, users, qs): ...

    eng = ReverseKRanksEngine.build(..., backend="mine")
    eng = ReverseKRanksEngine.build(..., backend="cached:mine")  # wrapped
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Optional, Type

import jax
import jax.numpy as jnp

from repro.core import query as query_mod
from repro.core.types import DeltaCorrection, QueryResult, RankTable, \
    RankTableConfig


class QueryBackend:
    """Base class / protocol for batched query execution.

    Subclasses implement `bound_ranks` (step 1, returning (B, n) arrays)
    and optionally override `select` / `query_batch`. `mesh` is accepted
    by every backend for a uniform constructor; only "sharded" uses it.

    Two dynamic-index hooks (see `repro.index`) have working defaults:

    * `query_batch(..., delta=)` — when a `DeltaCorrection` is passed, the
      backend must fuse it between step 1 and selection via the SHARED
      `rank_table.apply_delta_corrections`, so dense/fused/sharded cannot
      drift on a mutated index. The base implementation handles any
      backend whose `bound_ranks` returns full (B, n) arrays.
    * `build_index` — Algorithm 1 on this backend's substrate; "sharded"
      overrides it to build row-sharded end-to-end, and the maintenance
      loop's rebuilds go through the same hook as `Engine.build`.
    """

    name: str = "abstract"

    def __init__(self, mesh=None):
        self.mesh = mesh

    def bound_ranks(self, rt: RankTable, users: jax.Array, qs: jax.Array
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """§4.3 step 1 for a (B, d) query block → (r↓, r↑, est), each (B, n)."""
        raise NotImplementedError

    def select(self, rt: RankTable, r_lo: jax.Array, r_up: jax.Array,
               est: jax.Array, *, k: int, c: float) -> QueryResult:
        """§4.3 steps 2-3 on (B, n) bounds → QueryResult with leading B axis."""
        return query_mod.select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)

    def build_index(self, users: jax.Array, items: jax.Array,
                    cfg: RankTableConfig, key: jax.Array) -> RankTable:
        """Algorithm 1 on this backend's execution substrate."""
        from repro.core import rank_table as rt_mod
        return rt_mod.build_rank_table(users, items, cfg, key)

    def check_users_shape(self, n: int) -> None:
        """Raise if this backend cannot query a (n, d) user matrix —
        called by the engine BEFORE a mutation grows the user set, so a
        bad append fails with a clear error instead of breaking every
        subsequent query."""

    def _delta_query(self, rt: RankTable, users: jax.Array, qs: jax.Array,
                     *, k: int, c: float, delta: DeltaCorrection
                     ) -> QueryResult:
        """Generic delta path for (B, n)-bounds backends: step-1 bounds,
        the shared correction (needs the u·q score matrix — one extra
        (n, d) × (d, B) matmul), then selection against the live m."""
        from repro.core import rank_table as rt_mod
        r_lo, r_up, est = self.bound_ranks(rt, users, qs)   # (B, n)
        scores = (users @ qs.T).astype(jnp.float32)         # (n, B)
        r_lo, r_up, est = rt_mod.apply_delta_corrections(
            scores, r_lo.T, r_up.T, est.T, delta)
        return query_mod.select_topk(r_lo.T, r_up.T, est.T, k=k, c=c,
                                     m_items=delta.selection_m())

    def query_batch(self, rt: RankTable, users: jax.Array, qs: jax.Array,
                    *, k: int, c: float,
                    delta: Optional[DeltaCorrection] = None) -> QueryResult:
        if delta is not None:
            return self._delta_query(rt, users, qs, k=k, c=c, delta=delta)
        r_lo, r_up, est = self.bound_ranks(rt, users, qs)
        return self.select(rt, r_lo, r_up, est, k=k, c=c)


_REGISTRY: Dict[str, Type[QueryBackend]] = {}


def register_backend(name: str):
    """Class decorator: register a QueryBackend under `name`."""
    def deco(cls: Type[QueryBackend]) -> Type[QueryBackend]:
        # Only stamp a name the class doesn't already own directly, so
        # registering an existing class under an alias doesn't rename
        # every live instance of its first registration.
        if "name" not in cls.__dict__:
            cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


_WRAPPERS: Dict[str, Callable[..., QueryBackend]] = {}

# Wrapper prefixes resolvable by lazy import, so `get_backend("cached:…")`
# works without the caller importing repro.serve first (and core avoids a
# hard import cycle with the serving package).
_LAZY_WRAPPERS = {"cached": "repro.serve.cache"}


def register_wrapper(prefix: str):
    """Register `factory(inner_name, *, mesh=None) -> QueryBackend` under
    `prefix`, making `"<prefix>:<inner>"` a resolvable backend spec."""
    def deco(factory):
        _WRAPPERS[prefix] = factory
        return factory
    return deco


def available_backends() -> list[str]:
    """Concrete registered names; any of them also composes as
    `"<wrapper>:<name>"` (e.g. "cached:dense")."""
    return sorted(_REGISTRY)


def get_backend(spec, *, mesh=None) -> QueryBackend:
    """Resolve `spec`: a registered name, a `"<wrapper>:<inner>"` spec, or
    an already-built instance."""
    if isinstance(spec, QueryBackend):
        if mesh is not None:
            raise ValueError(
                "mesh= only applies when the backend is given by NAME; "
                "construct the instance with its mesh instead")
        return spec
    if isinstance(spec, str) and ":" in spec:
        prefix, _, inner = spec.partition(":")
        factory = _WRAPPERS.get(prefix)
        if factory is None and prefix in _LAZY_WRAPPERS:
            importlib.import_module(_LAZY_WRAPPERS[prefix])
            factory = _WRAPPERS.get(prefix)
        if factory is not None:
            return factory(inner, mesh=mesh)
        # unknown prefix: fall through to the unknown-backend error below
    try:
        cls = _REGISTRY[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown query backend {spec!r}; available: "
            f"{available_backends()}") from None
    obj = cls(mesh=mesh)
    obj.name = spec                 # requested (possibly aliased) name
    return obj


def _stock_pipeline(backend: QueryBackend, cls: Type["QueryBackend"]) -> bool:
    """True when the instance uses `cls`'s own bound_ranks and the base
    `select` — the end-to-end fast paths are only equivalent to
    bound_ranks+select in that case; a subclass overriding either hook
    must get the composed path so its logic actually runs."""
    t = type(backend)
    return (t.select is QueryBackend.select
            and t.bound_ranks is cls.bound_ranks)


@register_backend("dense")
class DenseBackend(QueryBackend):
    """Pure-jnp batched execution (the portable default)."""

    def bound_ranks(self, rt, users, qs):
        return query_mod.bound_ranks_batch(rt, users, qs)

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        if not _stock_pipeline(self, DenseBackend):
            return super().query_batch(rt, users, qs, k=k, c=c, delta=delta)
        if delta is not None:
            # one jit region: the correction reuses the step-1 score matrix
            return query_mod.query_batch_delta(rt, users, qs, delta, k, c)
        # one jit region end-to-end (matmul + lookup + select fuse)
        return query_mod.query_batch(rt, users, qs, k, c)


@register_backend("fused")
class FusedBackend(QueryBackend):
    """Pallas fused step 1 (interpret=True on CPU; compiled on TPU)."""

    def bound_ranks(self, rt, users, qs):
        from repro.kernels import ops as kops
        return kops.bound_ranks_batched(users, qs, rt.thresholds, rt.table,
                                        m=int(rt.m))

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        if not _stock_pipeline(self, FusedBackend):
            return super().query_batch(rt, users, qs, k=k, c=c, delta=delta)
        if delta is not None:
            # the inherited delta pipeline over this backend's
            # bound_ranks IS the fused delta path: kernel step 1, the
            # shared correction (one extra XLA matmul for u·q), shared
            # selection
            return self._delta_query(rt, users, qs, k=k, c=c, delta=delta)
        from repro.kernels import ops as kops
        return kops.query_fused_batch(rt, users, qs, k, c)


@register_backend("sharded")
class ShardedBackend(QueryBackend):
    """Row-sharded mesh execution with the tree-merge top-k.

    `query_batch` gathers only (B, k·P) candidates in ONE collective (its
    QueryResult carries candidate-set bounds of shape (B, k·P), not
    (B, n) — see `core.distributed`). The delta correction runs INSIDE the
    shard_map on row-sharded correction arrays, before the per-shard
    top-k, preserving the wire budget on mutated indexes. `bound_ranks`
    falls back to the dense path: materializing full (B, n) bounds defeats
    the O(k·P) wire budget and exists for debugging/parity checks only.

    `build_index` routes through `distributed.build_sharded`, so tables
    are row-sharded END-TO-END (never built on one device and re-sharded)
    — both for `Engine.build(backend="sharded")` and for the maintenance
    loop's rebuilds, which call the same hook.
    """

    def __init__(self, mesh=None):
        from repro.core import distributed as D
        super().__init__(mesh=D.flat_mesh(
            mesh if mesh is not None else jax.devices()))
        self._fns: dict = {}

    def bound_ranks(self, rt, users, qs):
        return query_mod.bound_ranks_batch(rt, users, qs)

    def build_index(self, users, items, cfg, key):
        from repro.core import distributed as D
        nshards = self.mesh.devices.size
        if cfg.threshold_mode == "exact":
            # oracle-only mode: exact f_min/f_max needs the full item set
            # per user row, which the row-parallel build never
            # materializes — build dense (small tests only) rather than
            # silently degrading to sampled thresholds
            return super().build_index(users, items, cfg, key)
        if users.shape[0] % nshards or items.shape[0] % nshards:
            # streaming churn drifts the live item count off the mesh
            # multiple; the row-parallel build's shard_map would raise an
            # opaque divisibility error (and a maintenance-loop rebuild
            # would then fail on every retry). Fall back to the dense
            # build — the resulting table queries fine on this backend as
            # long as n itself stays shard-divisible.
            return super().build_index(users, items, cfg, key)
        return D.build_sharded(users, items, cfg, key, self.mesh)

    def check_users_shape(self, n):
        nshards = self.mesh.devices.size
        if n % nshards:
            raise ValueError(
                f"sharded backend row-shards {n} users over {nshards} "
                "devices; appends must keep n divisible by the mesh size "
                "(pad the append batch or rebuild on a resized mesh)")

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        from repro.core import distributed as D
        n = users.shape[0]
        shape = None if delta is None else (delta.n_add, delta.n_del)
        key = (k, float(c), n, shape)
        fn = self._fns.get(key)
        if fn is None:
            fn = D.make_batch_query_fn(self.mesh, k=k, n=n, c=float(c),
                                       with_delta=delta is not None)
            self._fns[key] = fn
        if delta is None:
            return fn(rt, users, qs)
        return fn(rt, users, qs, delta)
