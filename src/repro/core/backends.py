"""Pluggable query-execution backends for the reverse k-ranks engine.

One `QueryBackend` protocol, three registered implementations:

  "dense"   — pure-jnp XLA path (`core.query`): one (n,d)×(d,B) matmul +
              one streamed table pass per batch. The default; runs
              anywhere.
  "fused"   — Pallas path (`kernels.ops.bound_ranks_batched`): the same
              math with step 1 fused into a single HBM pass per user tile
              (interpret=True on CPU, compiled on TPU).
  "sharded" — mesh path (`core.distributed`): row-sharded users/table,
              local batched step 1, tree-merge top-k gathering (B, k·P)
              candidates in one collective.

The protocol is batched-first: `bound_ranks` takes a (B, d) query block
and returns (B, n) bound arrays; `select` realizes §4.3 steps 2-3 with a
leading batch axis; `query_batch` composes the two (backends may override
it with a fully fused pipeline, as "sharded" does). Single-query
execution everywhere is the B = 1 case of the batched path — there is no
separate per-query code to drift out of sync.

Wrapper backends compose by NAME with a `<prefix>:<inner>` spec: the
prefix selects a registered wrapper factory, which resolves the inner
backend recursively. The serving cache (`repro.serve.cache`) registers
`"cached"`, so `backend="cached:fused"` builds a `CachingBackend` around
the Pallas path (within-tick dedupe + cross-tick per-query LRU) without
the engine knowing anything about caching.

Registering a new backend::

    from repro.core.backends import QueryBackend, register_backend

    @register_backend("mine")
    class MyBackend(QueryBackend):
        def bound_ranks(self, rt, users, qs): ...

    eng = ReverseKRanksEngine.build(..., backend="mine")
    eng = ReverseKRanksEngine.build(..., backend="cached:mine")  # wrapped
"""
from __future__ import annotations

import importlib
from collections import OrderedDict
from typing import Callable, Dict, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as query_mod
from repro.core.types import DeltaCorrection, QueryResult, RankTable, \
    RankTableConfig, StoredUsers, take_user_rows
from repro.obs import trace


class QueryBackend:
    """Base class / protocol for batched query execution.

    Subclasses implement `bound_ranks` (step 1, returning (B, n) arrays)
    and optionally override `select` / `query_batch`. `mesh` is accepted
    by every backend for a uniform constructor; only "sharded" uses it.

    Two dynamic-index hooks (see `repro.index`) have working defaults:

    * `query_batch(..., delta=)` — when a `DeltaCorrection` is passed, the
      backend must fuse it between step 1 and selection via the SHARED
      `rank_table.apply_delta_corrections`, so dense/fused/sharded cannot
      drift on a mutated index. The base implementation handles any
      backend whose `bound_ranks` returns full (B, n) arrays.
    * `build_index` — Algorithm 1 on this backend's substrate; "sharded"
      overrides it to build row-sharded end-to-end, and the maintenance
      loop's rebuilds go through the same hook as `Engine.build`.
    """

    name: str = "abstract"

    def __init__(self, mesh=None):
        self.mesh = mesh
        self._degrade_level = 0

    def degrade(self, level: int) -> None:
        """Degrade-ladder hook (repro.serve.degrade): rung `level` stays
        in effect until the next call (0 = normal serving). The base
        backend has no cheaper mode, so the default just records the
        level; backends with a latency/quality knob override (e.g. the
        pruned backend's dense fallback) and wrappers delegate inward."""
        self._degrade_level = int(level)

    def bound_ranks(self, rt: RankTable, users: jax.Array, qs: jax.Array
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """§4.3 step 1 for a (B, d) query block → (r↓, r↑, est), each (B, n)."""
        raise NotImplementedError

    def select(self, rt: RankTable, r_lo: jax.Array, r_up: jax.Array,
               est: jax.Array, *, k: int, c: float) -> QueryResult:
        """§4.3 steps 2-3 on (B, n) bounds → QueryResult with leading B axis."""
        return query_mod.select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)

    def build_index(self, users: jax.Array, items: jax.Array,
                    cfg: RankTableConfig, key: jax.Array) -> RankTable:
        """Algorithm 1 on this backend's execution substrate."""
        from repro.core import rank_table as rt_mod
        return rt_mod.build_rank_table(users, items, cfg, key)

    def check_users_shape(self, n: int) -> None:
        """Raise if this backend cannot query a (n, d) user matrix —
        called by the engine BEFORE a mutation grows the user set, so a
        bad append fails with a clear error instead of breaking every
        subsequent query."""

    def _delta_query(self, rt: RankTable, users, qs: jax.Array,
                     *, k: int, c: float, delta: DeltaCorrection
                     ) -> QueryResult:
        """Generic delta path for (B, n)-bounds backends: step-1 bounds,
        the shared correction (needs the u·q score matrix — one extra
        (n, d) × (d, B) matmul), then selection against the live m. The
        score slack of quantized user storage rides into the correction's
        certified count ranges (`apply_delta_corrections`)."""
        from repro.core import rank_table as rt_mod
        r_lo, r_up, est = self.bound_ranks(rt, users, qs)   # (B, n)
        scores, slack = query_mod.user_scores_batch(users, qs)  # (n, B)
        r_lo, r_up, est = rt_mod.apply_delta_corrections(
            scores, r_lo.T, r_up.T, est.T, delta, slack=slack)
        return query_mod.select_topk(r_lo.T, r_up.T, est.T, k=k, c=c,
                                     m_items=delta.selection_m())

    def query_batch(self, rt: RankTable, users: jax.Array, qs: jax.Array,
                    *, k: int, c: float,
                    delta: Optional[DeltaCorrection] = None) -> QueryResult:
        if delta is not None:
            return self._delta_query(rt, users, qs, k=k, c=c, delta=delta)
        r_lo, r_up, est = self.bound_ranks(rt, users, qs)
        return self.select(rt, r_lo, r_up, est, k=k, c=c)

    def dispatch_device(self, rt: RankTable, users, qs, *, k: int, c: float,
                        delta: Optional[DeltaCorrection] = None
                        ) -> QueryResult:
        """Serving-path dispatch entry (PR 10): take a HOST (numpy) query
        block, stage it to the device in ONE transfer, and return the
        tick's QueryResult as DEVICE HANDLES with no host sync — JAX
        async dispatch means the arrays are unmaterialized futures the
        caller materializes later (`jax.device_get` on a completion
        thread, never on the dispatch thread). The base implementation
        delegates to `query_batch`, which already returns unblocked
        device arrays; backends with a donation story override to route
        through a buffer-donating compiled entry (`ElasticBackend`), so
        the tick's input buffer is recycled instead of re-allocated.

        Contract: results are BIT-IDENTICAL to `query_batch` on the same
        block — this entry changes where buffers live, never values."""
        qs = jnp.asarray(qs)            # one H2D for the whole tick
        if delta is None:
            # no delta kwarg on the static path (same compatibility
            # contract as engine.query_batch_at)
            return self.query_batch(rt, users, qs, k=k, c=c)
        return self.query_batch(rt, users, qs, k=k, c=c, delta=delta)


_REGISTRY: Dict[str, Type[QueryBackend]] = {}


def register_backend(name: str):
    """Class decorator: register a QueryBackend under `name`."""
    def deco(cls: Type[QueryBackend]) -> Type[QueryBackend]:
        # Only stamp a name the class doesn't already own directly, so
        # registering an existing class under an alias doesn't rename
        # every live instance of its first registration.
        if "name" not in cls.__dict__:
            cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


_WRAPPERS: Dict[str, Callable[..., QueryBackend]] = {}

# Wrapper prefixes resolvable by lazy import, so `get_backend("cached:…")`
# and `get_backend("elastic:…")` work without the caller importing the
# wrapper's module first (and this module avoids hard import cycles with
# them). "elastic:<inner>" is the compile-once scan-over-tiles wrapper
# (repro.core.elastic) — note the prefix alone is not a backend name; the
# inner defaults to dense ("elastic:" ≡ "elastic:dense").
_LAZY_WRAPPERS = {"cached": "repro.serve.cache",
                  "elastic": "repro.core.elastic"}


def register_wrapper(prefix: str):
    """Register `factory(inner_name, *, mesh=None) -> QueryBackend` under
    `prefix`, making `"<prefix>:<inner>"` a resolvable backend spec."""
    def deco(factory):
        _WRAPPERS[prefix] = factory
        return factory
    return deco


def available_backends() -> list[str]:
    """Concrete registered names; any of them also composes as
    `"<wrapper>:<name>"` (e.g. "cached:dense")."""
    return sorted(_REGISTRY)


def get_backend(spec, *, mesh=None) -> QueryBackend:
    """Resolve `spec`: a registered name, a `"<wrapper>:<inner>"` spec, or
    an already-built instance."""
    if isinstance(spec, QueryBackend):
        if mesh is not None:
            raise ValueError(
                "mesh= only applies when the backend is given by NAME; "
                "construct the instance with its mesh instead")
        return spec
    if isinstance(spec, str) and ":" in spec:
        prefix, _, inner = spec.partition(":")
        factory = _WRAPPERS.get(prefix)
        if factory is None and prefix in _LAZY_WRAPPERS:
            importlib.import_module(_LAZY_WRAPPERS[prefix])
            factory = _WRAPPERS.get(prefix)
        if factory is not None:
            return factory(inner, mesh=mesh)
        # unknown prefix: fall through to the unknown-backend error below
    try:
        cls = _REGISTRY[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown query backend {spec!r}; available: "
            f"{available_backends()}") from None
    obj = cls(mesh=mesh)
    obj.name = spec                 # requested (possibly aliased) name
    return obj


def _stock_pipeline(backend: QueryBackend, cls: Type["QueryBackend"]) -> bool:
    """True when the instance uses `cls`'s own bound_ranks and the base
    `select` — the end-to-end fast paths are only equivalent to
    bound_ranks+select in that case; a subclass overriding either hook
    must get the composed path so its logic actually runs."""
    t = type(backend)
    return (t.select is QueryBackend.select
            and t.bound_ranks is cls.bound_ranks)


@register_backend("dense")
class DenseBackend(QueryBackend):
    """Pure-jnp batched execution (the portable default)."""

    def bound_ranks(self, rt, users, qs):
        return query_mod.bound_ranks_batch(rt, users, qs)

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        if not _stock_pipeline(self, DenseBackend):
            return super().query_batch(rt, users, qs, k=k, c=c, delta=delta)
        if delta is not None:
            # one jit region: the correction reuses the step-1 score matrix
            return query_mod.query_batch_delta(rt, users, qs, delta, k, c)
        # one jit region end-to-end (matmul + lookup + select fuse)
        return query_mod.query_batch(rt, users, qs, k, c)


@register_backend("fused")
class FusedBackend(QueryBackend):
    """Pallas fused step 1 (interpret=True on CPU; compiled on TPU)."""

    def bound_ranks(self, rt, users, qs):
        from repro.kernels import ops as kops
        return kops.bound_ranks_batched_stored(users, qs, rt)

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        if not _stock_pipeline(self, FusedBackend):
            return super().query_batch(rt, users, qs, k=k, c=c, delta=delta)
        if delta is not None:
            # the inherited delta pipeline over this backend's
            # bound_ranks IS the fused delta path: kernel step 1, the
            # shared correction (one extra XLA matmul for u·q), shared
            # selection
            return self._delta_query(rt, users, qs, k=k, c=c, delta=delta)
        from repro.kernels import ops as kops
        return kops.query_fused_batch(rt, users, qs, k, c)


@register_backend("sharded")
class ShardedBackend(QueryBackend):
    """Row-sharded mesh execution with the tree-merge top-k.

    `query_batch` gathers only (B, k·P) candidates in ONE collective (its
    QueryResult carries candidate-set bounds of shape (B, k·P), not
    (B, n) — see `core.distributed`). The delta correction runs INSIDE the
    shard_map on row-sharded correction arrays, before the per-shard
    top-k, preserving the wire budget on mutated indexes. `bound_ranks`
    falls back to the dense path: materializing full (B, n) bounds defeats
    the O(k·P) wire budget and exists for debugging/parity checks only.

    `build_index` routes through `distributed.build_sharded`, so tables
    are row-sharded END-TO-END (never built on one device and re-sharded)
    — both for `Engine.build(backend="sharded")` and for the maintenance
    loop's rebuilds, which call the same hook.
    """

    def __init__(self, mesh=None):
        from repro.core import distributed as D
        super().__init__(mesh=D.flat_mesh(
            mesh if mesh is not None else jax.devices()))
        self._fns: dict = {}

    def bound_ranks(self, rt, users, qs):
        return query_mod.bound_ranks_batch(rt, users, qs)

    def build_index(self, users, items, cfg, key):
        from repro.core import distributed as D
        nshards = self.mesh.devices.size
        if cfg.threshold_mode == "exact":
            # oracle-only mode: exact f_min/f_max needs the full item set
            # per user row, which the row-parallel build never
            # materializes — build dense (small tests only) rather than
            # silently degrading to sampled thresholds
            return super().build_index(users, items, cfg, key)
        if users.shape[0] % nshards or items.shape[0] % nshards:
            # streaming churn drifts the live item count off the mesh
            # multiple; the row-parallel build's shard_map would raise an
            # opaque divisibility error (and a maintenance-loop rebuild
            # would then fail on every retry). Fall back to the dense
            # build — the resulting table queries fine on this backend as
            # long as n itself stays shard-divisible.
            return super().build_index(users, items, cfg, key)
        return D.build_sharded(users, items, cfg, key, self.mesh)

    def check_users_shape(self, n):
        nshards = self.mesh.devices.size
        if n % nshards:
            raise ValueError(
                f"sharded backend row-shards {n} users over {nshards} "
                "devices; appends must keep n divisible by the mesh size "
                "(pad the append batch or rebuild on a resized mesh)")

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        from repro.core import distributed as D
        n = users.shape[0]
        shape = None if delta is None else (delta.n_add, delta.n_del)
        # storage structure rides in the key only for bookkeeping — the
        # built fn constructs its shard_map per argument structure at
        # trace time, so one fn serves every spec of the same (k, c, n)
        key = (k, float(c), n, shape, rt.spec_kind,
               isinstance(users, StoredUsers))
        fn = self._fns.get(key)
        if fn is None:
            fn = D.make_batch_query_fn(self.mesh, k=k, n=n, c=float(c),
                                       with_delta=delta is not None)
            self._fns[key] = fn
        if delta is None:
            return fn(rt, users, qs)
        return fn(rt, users, qs, delta)


@register_backend("pruned")
class PrunedBackend(QueryBackend):
    """Two-phase block-pruned execution (PR 4, `repro.core.pruning`).

    Wraps an inner backend: phase A scores per-block summaries against the
    whole query batch and certifies which user tiles can still hold
    non-Lemma-1-pruned users; phase B runs the inner backend's step-1 math
    over the surviving tiles only, with skipped users materialized at a
    dominated sentinel so the §4.3 selection returns BIT-IDENTICAL
    indices to the full scan (see the pruning module docstring for the
    invariants). Resolves as `"pruned"` (dense inner) or
    `"pruned:<inner>"`:

      pruned:dense    gathered-row phase B, one jit region;
      pruned:fused    masked-grid Pallas kernel — skipped tiles are never
                      DMA'd (`ops.bound_ranks_batched_pruned`);
      pruned:sharded  per-shard summaries; each shard gathers its own
                      surviving tiles before the unchanged tree-merge
                      (`distributed.make_pruned_batch_query_fn`);
      other inners    generic composition over `inner.bound_ranks` on the
                      compacted sub-problem.

    Summaries are cached per index GENERATION (array identity of
    users/thresholds/table, same contract as the serving cache), so
    mutations and rebuild hot-swaps regenerate them automatically;
    `build_index` pre-warms the cache so the first query after a build
    pays no summary pass. `use_cones=False` drops the PR 6 norm-band +
    angular-cone sketches and prunes on coordinate boxes alone (an A/B
    surface for the bench; the default keeps the intersected — strictly
    tighter — envelopes). A build-time cluster reorder
    (`Engine.build(cluster_reorder=True)` / rebuild) is invisible here:
    the reordered snapshot arrays key a fresh summary generation, and n
    is unchanged, so the sharded tile-alignment contract is unaffected.

    Fallbacks (always full-scan-correct, surfaced in `stats.fallback`):
      * `max_union_frac` — when phase A keeps more than this fraction of
        blocks, the gather would re-stream nearly everything; dispatch
        the inner backend directly (adversarial-case overhead is then
        phase A alone, the ≤ 1.1× acceptance bound);
      * `delta_guard` — past this |delta|/m ratio the widened envelopes
        stop pruning; skip phase A entirely;
      * sharded tile alignment — n must split into whole blocks per
        shard, else the sharded inner runs unpruned.
    """

    _SUMMARY_CACHE = 4          # index generations kept warm

    def __init__(self, inner="dense", *, mesh=None,
                 block_size: Optional[int] = None,
                 max_union_frac: float = 0.5, delta_guard: float = 0.25,
                 use_cones: bool = True):
        super().__init__(mesh=mesh)
        from repro.core import pruning
        self._pruning = pruning
        self.inner = get_backend(inner, mesh=mesh)
        self.name = f"pruned:{self.inner.name}"
        self.block_size = int(block_size or pruning.DEFAULT_BLOCK)
        self.max_union_frac = float(max_union_frac)
        self.delta_guard = float(delta_guard)
        self.use_cones = bool(use_cones)
        self._summaries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._sharded_fns: dict = {}
        self.stats = pruning.PruneStats()   # last query_batch's accounting

    # ----------------------------------------------------------- plumbing
    def bound_ranks(self, rt, users, qs):
        """Full (B, n) bounds are a debugging surface; pruning applies to
        the end-to-end query (sentinels would surprise bound callers)."""
        return self.inner.bound_ranks(rt, users, qs)

    def build_index(self, users, items, cfg, key):
        rt = self.inner.build_index(users, items, cfg, key)
        self.summary_for(rt, users)         # pre-warm this generation
        return rt

    def check_users_shape(self, n):
        return self.inner.check_users_shape(n)

    def degrade(self, level):
        """Rung ≥ 1 disables the `max_union_frac` dense fallback: an
        adversarially non-pruning query pays the certified two-phase
        gather over its kept blocks instead of a full-scan latency spike
        (the bimodal p99 that breaks deadline SLOs under load). Bounds
        and results are unchanged — this rung has no contract cost."""
        super().degrade(level)
        self.inner.degrade(level)

    def summary_for(self, rt: RankTable, users: jax.Array):
        """The `BlockSummary` for this index generation (identity-cached;
        a mutation or rebuild swaps the arrays and lazily regenerates)."""
        key = (id(users), id(rt.thresholds), id(rt.table), self.block_size,
               self.use_cones)
        hit = self._summaries.get(key)
        if hit is not None:
            self._summaries.move_to_end(key)
            return hit[1]
        summary = self._pruning.build_block_summary(
            users, rt, block_size=self.block_size,
            with_cones=self.use_cones)
        # the value keeps the keyed arrays alive, so their id()s cannot
        # be recycled while the entry exists (cf. serve.cache weakrefs)
        self._summaries[key] = ((users, rt.thresholds, rt.table), summary)
        while len(self._summaries) > self._SUMMARY_CACHE:
            self._summaries.popitem(last=False)
        return summary

    # -------------------------------------------------------------- query
    def _full_scan(self, rt, users, qs, *, k, c, delta, why: str,
                   n_blocks: int) -> QueryResult:
        self.stats = self._pruning.PruneStats(
            n_blocks=n_blocks, kept_union=n_blocks, kept_per_query=1.0,
            fallback=why)
        if delta is None:
            return self.inner.query_batch(rt, users, qs, k=k, c=c)
        return self.inner.query_batch(rt, users, qs, k=k, c=c, delta=delta)

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        with trace.span("prune.query", batch=qs.shape[0], k=k):
            res = self._query_impl(rt, users, qs, k=k, c=c, delta=delta)
        # publish this batch's accounting (skip rate, kept fractions,
        # fallback) as gauges — the live half of the §6.3 prune columns
        self.stats.publish()
        return res

    def _query_impl(self, rt, users, qs, *, k, c, delta=None):
        P = self._pruning
        n = users.shape[0]
        bs = self.block_size
        nb = -(-n // bs)
        sharded = isinstance(self.inner, ShardedBackend)
        if sharded:
            nshards = self.inner.mesh.devices.size
            if n % (nshards * bs):
                # tiles must not straddle shard boundaries
                return self._full_scan(rt, users, qs, k=k, c=c, delta=delta,
                                       why="align", n_blocks=nb)
        if delta is not None:
            m_base = max(int(rt.m), 1)
            if (delta.n_add + delta.n_del) / m_base > self.delta_guard:
                return self._full_scan(rt, users, qs, k=k, c=c, delta=delta,
                                       why="delta-guard", n_blocks=nb)
        with trace.span("prune.phase_a", n_blocks=nb) as sp_a:
            summary = self.summary_for(rt, users)
            if delta is None:
                keep, _ = P.phase_a(summary, qs, k=k, block_size=bs)
            else:
                keep, _ = P.phase_a(summary, qs, k=k, block_size=bs,
                                    n_add=float(delta.n_add),
                                    n_del=float(delta.n_del),
                                    user_live=delta.user_live,
                                    with_live=True)
            keep_np = np.asarray(keep)                      # host sync
            union = np.flatnonzero(keep_np.any(axis=0))
            per_q = float(keep_np.mean())
            sp_a.set(kept_union=int(union.size))
        # degrade rung ≥ 1 lifts the union cap to 1.0 — the fallback is
        # unreachable (union ≤ nb) and every query stays on the bounded
        # pruned path (see degrade())
        union_cap = (1.0 if self._degrade_level >= 1
                     else self.max_union_frac)
        if union.size > union_cap * nb:
            res = self._full_scan(rt, users, qs, k=k, c=c, delta=delta,
                                  why="dense", n_blocks=nb)
            self.stats.kept_union = int(union.size)
            self.stats.kept_per_query = per_q
            return res
        self.stats = P.PruneStats(n_blocks=nb, kept_union=int(union.size),
                                  kept_per_query=per_q)
        min_blocks = -(-k // bs)
        with trace.span("prune.phase_b", kept=int(union.size),
                        n_blocks=nb):
            if sharded:
                return self._sharded_query(rt, users, qs, keep_np, k=k,
                                           c=c, delta=delta,
                                           min_blocks=min_blocks)
            ids_np = P.bucket_blocks(union, n_blocks=nb,
                                     min_blocks=min_blocks)
            ids = jnp.asarray(ids_np)
            # padding tiles repeat kept ids; mark them invalid so a user
            # is never a selection candidate twice
            blk_valid = jnp.asarray(
                np.arange(ids_np.size) < max(union.size, 1))
            stock_dense = (type(self.inner) is DenseBackend
                           and _stock_pipeline(self.inner, DenseBackend))
            if stock_dense and delta is None:
                return P.pruned_query_batch(rt, users, qs, ids, blk_valid,
                                            keep, k, c, block_size=bs)
            if stock_dense:
                return P.pruned_query_batch_delta(rt, users, qs, delta,
                                                  ids, blk_valid, keep, k,
                                                  c, block_size=bs)
            # compacted step 1 on the inner backend (masked-grid kernel
            # for the stock fused path, generic gather otherwise)
            if (type(self.inner) is FusedBackend
                    and type(self.inner).bound_ranks
                    is FusedBackend.bound_ranks):
                from repro.kernels import ops as kops
                r_lo, r_up, est = kops.bound_ranks_batched_pruned_stored(
                    users, qs, rt, ids, block_n=bs)
            else:
                ridx = P.row_indices(ids, bs)
                g = jnp.minimum(ridx, n - 1)
                sub_rt = rt.take_rows(g)
                r_lo, r_up, est = self.inner.bound_ranks(
                    sub_rt, take_user_rows(users, g), qs)
            if delta is None:
                return P.finish_compacted(r_lo, r_up, est, ids, blk_valid,
                                          keep, rt.m, k, c, n=n,
                                          block_size=bs)
            return P.delta_finish_compacted(users, qs, delta, r_lo, r_up,
                                            est, ids, blk_valid, keep, k,
                                            c, n=n, block_size=bs)

    def _sharded_query(self, rt, users, qs, keep_np, *, k, c, delta,
                       min_blocks):
        from repro.core import distributed as D
        P = self._pruning
        mesh = self.inner.mesh
        nshards = mesh.devices.size
        n = users.shape[0]
        bs = self.block_size
        nb = keep_np.shape[1]
        nb_loc = nb // nshards
        union = keep_np.any(axis=0)
        per_shard = union.reshape(nshards, nb_loc)
        width = P.bucket_width(int(per_shard.sum(axis=1).max()),
                               n_blocks=nb_loc, min_blocks=min_blocks)
        ids = np.zeros((nshards, width), np.int32)
        valid = np.zeros((nshards, width), bool)
        for s in range(nshards):
            kept = np.flatnonzero(per_shard[s])
            if kept.size == 0:
                continue                    # ids stay 0, valid stays False
            reps = -(-width // kept.size)
            ids[s] = np.tile(kept, reps)[:width]
            # the duplicate tail stays invalid so repeated rows cannot
            # produce duplicate candidates in the tree-merge
            valid[s, :kept.size] = True
        shape = None if delta is None else (delta.n_add, delta.n_del)
        fkey = (k, float(c), n, width, shape)
        fn = self._sharded_fns.get(fkey)
        if fn is None:
            fn = D.make_pruned_batch_query_fn(
                mesh, k=k, n=n, c=float(c), block_size=bs,
                with_delta=delta is not None)
            self._sharded_fns[fkey] = fn
        args = (rt, users, qs, jnp.asarray(ids), jnp.asarray(valid),
                jnp.asarray(keep_np))
        if delta is None:
            return fn(*args)
        return fn(*args, delta)


@register_wrapper("pruned")
def _make_pruned(inner: str, *, mesh=None) -> PrunedBackend:
    """Registry hook: `get_backend("pruned:<inner>")` lands here."""
    return PrunedBackend(inner, mesh=mesh)
