"""The paper's primary contribution: c-approximate reverse k-ranks queries.

Modules:
  types       — RankTableConfig / RankTable / QueryResult pytrees
  exact       — O(nmd) oracle (Definitions 1-2)
  rank_table  — Algorithm 1 pre-processing (vectorized, O((n+m)d + m log m))
  query       — §4.3 O(nd) query processing (batched-first)
  qsrp        — QSRP baseline (ICDE'24), extended to c-approximation
  metrics     — §5 accuracy / overall-ratio criteria
  backends    — pluggable query-execution backends (dense/fused/sharded,
                the "pruned:<inner>" two-phase wrapper, "cached:<inner>")
  pruning     — block-summary pruning: the coarse-to-fine §4.3 scan
  engine      — public ReverseKRanksEngine API (incl. the PR-3 mutation
                API: insert/delete items, upsert/delete users, rebuild)
  distributed — multi-pod sharded build + query (shard_map)

The index-lifecycle layer behind the mutation API (delta buffer,
epoch-versioned snapshots, maintenance loop) lives in `repro.index`.
"""
from repro.core.backends import (QueryBackend, available_backends,
                                 get_backend, register_backend)
from repro.core.engine import ReverseKRanksEngine
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.query import query, query_batch
from repro.core.rank_table import build_rank_table
from repro.core.types import (DeltaCorrection, QueryResult, RankTable,
                              RankTableConfig, StorageSpec, StoredUsers)

__all__ = [
    "ReverseKRanksEngine", "exact_ranks", "reverse_k_ranks", "query",
    "query_batch", "build_rank_table", "DeltaCorrection", "QueryResult",
    "RankTable", "RankTableConfig", "StorageSpec", "StoredUsers",
    "QueryBackend", "available_backends", "get_backend",
    "register_backend",
]
