"""Block-summary pruning — the two-phase coarse-to-fine §4.3 scan (PR 4).

Every full-scan backend streams the whole (n, d) user matrix and (n, τ)
rank table per batch even though Lemma 1 proves most users are prunable:
any user with r↓ > R↑_k can never enter the answer set. This module lifts
the Lemma-1 prune test from per-user to per-BLOCK granularity so whole
user tiles are skipped before their bytes are ever read:

  build time   `build_block_summary` folds each block of `block_size`
               consecutive users into a tiny sketch — per-dimension
               coordinate extremes (a box around the block's user
               vectors) and column-wise envelopes of the block's
               threshold/table rows;
  phase A      `phase_a` scores every block against the whole (B, d)
               query batch in one (n/block, d)-shaped pass: the box gives
               a certified score range [s↓, s↑] per (block, query), the
               envelopes turn s↑ into a LOWER bound on every member's r↓
               and s↓ into an UPPER bound on every member's r↑. Sorting
               blocks by that r↑ bound and accumulating live row counts
               to k seeds a certified upper bound R̂ ≥ R↑_k, and a block
               is kept iff its r↓ bound ≤ R̂ — every user Lemma 1 could
               possibly retain lives in a kept block;
  phase B      the existing step-1 math runs only over kept blocks
               (gathered rows on the dense path, a scalar-prefetch
               masked-grid Pallas kernel on the fused path); skipped
               users are materialized at the dominated sentinel
               m_sel + 2, which `query.lemma1_key` orders past every
               admissible key, so `select_topk` returns bit-identical
               selected indices to the full scan.

Why the selection stays exact (the invariants the tests pin):

  * ≥ k users satisfy r↑ ≤ R↑_k ≤ R̂, and each of them (indeed any user
    with r↓ ≤ R̂) forces its block to be kept — so the k smallest r↓ and
    r↑ all come from kept rows and `kth_smallest` over the materialized
    arrays reproduces the exact R↓_k / R↑_k;
  * a skipped user has r↓ > R̂ ≥ R↑_k: in the non-guaranteed regime it is
    Lemma-1 pruned (and can never simultaneously pass the accept test,
    which would need c·R↓_k ≥ r↑ ≥ r↓ > R↑_k > c·R↓_k); in the
    guaranteed regime its est ≥ r↓ > R̂ ≥ R↑_k ≥ the k-th smallest est.
    Either way its key strictly exceeds every possible winner's, so the
    sentinel never perturbs the top-k. (The n_accepted/n_pruned
    DIAGNOSTIC counters can differ from the full scan's — a skipped
    user's true bounds are unknown — but indices, est_rank and the
    R↓_k/R↑_k statistics are exact.)

Floating point: the per-user score is an MXU dot product, the block
bound a different summation order, so phase A widens the score range by
a relative slack covering worst-case f32 accumulation error before the
comparison — a borderline user can only be kept, never lost. The
envelope bucketize reuses `query._bucketize`, so the storage-dtype cast
(bf16 tables) is applied on both sides of the comparison and stays
monotone.

Geometry sketches (PR 6): every block additionally stores a NORM BAND
[n↓, n↑] ⊇ {‖u‖₂ : u ∈ block} and an ANGULAR CONE (μ̂, cos r) with
û·μ̂ ≥ cos r for every member direction û = u/‖u‖. In exact arithmetic
s = u·q = ‖u‖·‖q‖·cos∠(u, q), and the spherical triangle inequality
gives ∠(u, q) ∈ [max(0, θ − r), min(π, θ + r)] with θ = ∠(q, μ̂), so the
block score range is also contained in

    ‖q‖ · [ n(c↓)·c↓ , n(c↑)·c↑ ],   c↑ = cos(max(0, θ − r)),
                                     c↓ = cos(min(π, θ + r)),

where n(c) = n↑ if c ≥ 0 else n↓ (the norm extremizing a signed
cosine). Phase A INTERSECTS this range with the coordinate-box range:
the true score lies in both, so the intersection is certified and never
looser than either sketch alone — boxes win on axis-aligned mass,
cones on tight direction bundles with spread coordinates. cos(θ ∓ r)
is evaluated trig-free through the cosine addition formulas, with the
clamped boundary cases selected by the equivalent tests cosθ ≥ cos r
(θ ≤ r) and cosθ ≤ −cos r (θ + r ≥ π). Certification under f32:

  * every unit-vector dot (cos r at build, cosθ at query) is widened by
    a rounding slack covering the d-term accumulation AND the operand
    normalizations (build-side cos r rounds DOWN — the cone only
    widens; query-side cosθ widens in the direction that extremizes
    each bound);
  * n↓/n↑ and ‖q‖ carry relative slacks for the sum-of-squares + sqrt;
  * the final products add the same member-dot slack the box path uses,
    with Σ|u_j·q_j| ≤ ‖u‖·‖q‖ ≤ n↑·‖q‖ (Cauchy-Schwarz), so a member's
    COMPUTED phase-B score — not just its exact value — stays inside;
  * degenerate blocks are safe by construction: a (near-)zero mean
    direction is stored as μ̂ = 0, which forces cosθ = 0 and cos r < 0
    and relaxes the cone to the vacuous ±n↑·‖q‖; a zero-norm member
    forces n↓ = 0, so the band always brackets its score 0; a zero
    query zeroes both cone bounds around the true score 0.

The PR 5 storage widenings compose unchanged: `user_slack` (quantized
user rows) widens the INTERSECTED range — the member's certified score
interval is ± row_slack·‖q‖₁ around the dequantized score that BOTH
sketches bound — and `score_eps`/widened thr/tab envelopes act after
the score range is formed, exactly as for the box alone.

Build-time layout (`kmeans_layout`): both sketches only pay when
blocks are geometrically TIGHT, which the caller's row order does not
guarantee (i.i.d. or shuffled-mixture users defeat any per-tile
sketch). `Engine.build/rebuild` can k-means-cluster the f32 user
matrix and PHYSICALLY REORDER rows so consecutive `block_size` tiles
hold like users, publishing the old→new permutation through
`IndexSnapshot.user_remap` (composed over the lineage, exactly like
compaction). The reorder changes WHERE a user row lives, never what a
query returns for it: selected indices stay bit-identical to the
unpruned inner backend on the same (reordered) snapshot, and clients
translate to pre-remap ids via the composed remap.

Delta path (`repro.index`): the correction shifts every rank by
[-n_del, +n_add], so phase A widens the block bounds by the padded
correction widths and subtracts per-block dead-user counts from the live
row counts; `PrunedBackend` falls back to the full scan past a
delta-ratio guard where the widened envelopes stop paying.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Direct-from-module imports (not `from repro.core import query`): the
# package __init__ rebinds the `query` attribute to the query FUNCTION.
from repro.core import rank_table as rt_mod
from repro.core.query import _bucketize, lemma1_select, \
    lookup_bounds_batch, user_scores_batch
from repro.core.types import DeltaCorrection, EPS_BF16, QueryResult, \
    RankTable, StoredUsers, _I8_TRANSFORM_PAD, kth_smallest, take_user_rows

# Summary block size. MUST match the fused kernel's user-tile block_n so a
# kept block is exactly one kernel grid step (and the per-tile matmul is
# bit-identical to the full scan's — same tile composition, same
# accumulation order).
DEFAULT_BLOCK = 256

# Relative widening of the certified score range per unit of dimension:
# f32 dot-product rounding is bounded by ~d·2^-24 of the absolute-value
# bound Σ|u_j·q_j|; 4e-7·d covers it with a 6x margin, the absolute term
# guards all-zero rows.
_SCORE_SLACK = 4e-7
_SCORE_SLACK_ABS = 1e-6

# Absolute floor of the unit-vector dot slack (cone sketches): cos r and
# cos θ are dots of normalized operands, so magnitudes are ≤ 1 and the
# d-term accumulation bound _SCORE_SLACK·d plus this floor covers the
# dot, both normalizations and the sin = sqrt(1 − c²) evaluation.
_COS_SLACK_ABS = 1e-6


def _cos_slack(d: int) -> float:
    """f32 rounding slack for a dot product of two unit vectors of
    dimension d (see _COS_SLACK_ABS)."""
    return _SCORE_SLACK * d + _COS_SLACK_ABS


class BlockSummary(NamedTuple):
    """Per-block sketch of the user matrix + rank table (a pytree).

    dim_min/dim_max: (nb, d) float32 — coordinate extremes of the block's
                     user vectors: for any q, every member's score lies in
                     [dim_min·q⁺ + dim_max·q⁻, dim_max·q⁺ + dim_min·q⁻].
    thr_min/thr_max: (nb, τ) storage dtype — column-wise envelope of the
                     block's threshold rows (ascending along τ).
    tab_min/tab_max: (nb, τ) storage dtype — column-wise envelope of the
                     block's table rows (non-increasing along τ).
    rows:            (nb,) int32 — real rows in the block (the tail block
                     of a non-multiple n is partial).
    m:               () int32 — |P|, for the out-of-range bound m + 1.
    """

    dim_min: jax.Array
    dim_max: jax.Array
    thr_min: jax.Array
    thr_max: jax.Array
    tab_min: jax.Array
    tab_max: jax.Array
    rows: jax.Array
    m: jax.Array
    # Storage-spec extensions (PR 5), None on an exact f32 index:
    #   user_slack: (nb, 1) f32 — max per-row certified score-error
    #     coefficient in the block (quantized user rows); phase A widens
    #     the box score range by user_slack · ‖q‖₁.
    #   score_eps: () f32 — marks CERTIFIED-WIDENED f32 envelopes (the
    #     quantized-table summary form): thr/tab envelopes are built over
    #     dequantized ± quantization-error rows, and phase A additionally
    #     widens the score side by score_eps · max|s| (the bf16
    #     monotone-cast rounding; 0 for int8).
    user_slack: Optional[jax.Array] = None
    score_eps: Optional[jax.Array] = None
    # Geometry sketches (PR 6), None when built with with_cones=False:
    #   norm_min/norm_max: (nb, 1) f32 — certified band around every
    #     member's ‖u‖₂ (f32-rounding widened at build).
    #   mu: (nb, d) f32 — unit mean member direction (exact 0 rows when
    #     the directions cancel — the cone then reads as vacuous).
    #   cos_r: (nb, 1) f32 — certified LOWER bound on û·μ̂ over member
    #     directions û, i.e. cos of the cone's max angular radius,
    #     rounding-widened DOWN at build.
    norm_min: Optional[jax.Array] = None
    norm_max: Optional[jax.Array] = None
    mu: Optional[jax.Array] = None
    cos_r: Optional[jax.Array] = None

    @property
    def n_blocks(self) -> int:
        return self.dim_min.shape[0]

    @property
    def tau(self) -> int:
        return self.thr_min.shape[1]


@dataclasses.dataclass
class PruneStats:
    """Skip-rate accounting for one pruned `query_batch` call."""

    n_blocks: int = 0           # summary blocks in the index
    kept_union: int = 0         # blocks phase B executed (union over B)
    kept_per_query: float = 0.0  # mean per-query kept fraction
    # "" (pruned), "dense" (union too big), "delta-guard" (|delta|/m over
    # the guard), "align" (sharded tiles straddle shard boundaries)
    fallback: str = ""

    @property
    def union_fraction(self) -> float:
        return self.kept_union / max(self.n_blocks, 1)

    @property
    def skip_rate(self) -> float:
        return 1.0 - self.union_fraction

    def publish(self, registry=None) -> None:
        """Mirror this batch's accounting into metrics gauges (the live
        half of the bench's §6.3 prune columns): `prune_skip_rate`,
        `prune_kept_per_query`, and per-reason fallback counters."""
        from repro.obs import registry as obs
        reg = registry if registry is not None else obs.get_default()
        reg.gauge("prune_skip_rate",
                  "1 - kept-union fraction of the last pruned batch"
                  ).set(self.skip_rate)
        reg.gauge("prune_kept_per_query",
                  "mean per-query kept-block fraction, last batch"
                  ).set(self.kept_per_query)
        reg.counter("prune_batches_total",
                    "pruned query_batch calls",
                    labels={"fallback": self.fallback or "none"}).inc()


def _pad_rows(x: jax.Array, total: int, value) -> jax.Array:
    pad = total - x.shape[0]
    if pad == 0:
        return x
    width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, width, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_size", "with_cones"))
def build_block_summary(users, rt: RankTable,
                        block_size: int = DEFAULT_BLOCK,
                        with_cones: bool = True) -> BlockSummary:
    """Fold (users, rank table) into per-block sketches — one O(n·(d+τ))
    pass at build/rebuild time, O(n/block · (d+τ)) resident thereafter.

    On an exact f32 index the envelopes are computed over the STORED
    threshold/table values (exact under min/max), so phase A's
    comparisons see exactly what the per-user lookup sees — the pre-spec
    path, bit-identical. On a quantized index (bf16/int8 storage spec)
    the envelopes are CERTIFIED f32 intervals: each stored row is widened
    to the interval provably containing its true f32 values (± half a
    quantization step for int8 codes, ± EPS_BF16 relative for bf16 table
    entries) BEFORE the column min/max, so the phase-A bounds bracket
    every member's widened (r↓, r↑) from the dequant-aware lookup —
    Lemma-1 tile pruning stays exact at every spec.

    `with_cones` adds the PR 6 norm-band + angular-cone fields (built
    over the same dequantized f32 rows the box sees — the quantized-user
    `user_slack` widening then covers both sketches identically).
    """
    if isinstance(users, StoredUsers):
        u32 = users.rows.astype(jnp.float32)
        if users.scale is not None:
            u32 = u32 * users.scale
        slack_rows = users.row_slack
    else:
        u32 = users.astype(jnp.float32)
        slack_rows = None
    n, d = u32.shape
    nb = -(-n // block_size)
    total = nb * block_size
    inf = jnp.inf
    u_lo = _pad_rows(u32, total, inf).reshape(nb, block_size, d)
    u_hi = _pad_rows(u32, total, -inf).reshape(nb, block_size, d)
    tau = rt.thresholds.shape[1]
    kind = rt.spec_kind
    if kind == "f32":
        if slack_rows is not None:
            raise ValueError("quantized user storage requires a quantized "
                             "rank table (uniform StorageSpec)")
        thr_lo_rows = thr_hi_rows = rt.thresholds
        tab_lo_rows = tab_hi_rows = rt.table
        user_slack = score_eps = None
        st = rt.thresholds.dtype
    elif kind == "bf16":
        thr32 = rt.thresholds.astype(jnp.float32)
        tab32 = rt.table.astype(jnp.float32)
        thr_lo_rows = thr_hi_rows = thr32
        tab_lo_rows = tab32 * (1.0 - EPS_BF16)
        tab_hi_rows = tab32 * (1.0 + EPS_BF16)
        score_eps = jnp.asarray(EPS_BF16, jnp.float32)
        st = jnp.float32
    else:                                       # int8 per-row affine codes
        half = 0.5 + _I8_TRANSFORM_PAD
        thr32 = rt.thresholds.astype(jnp.float32) * rt.thr_scale + rt.thr_off
        tab32 = rt.table.astype(jnp.float32) * rt.tab_scale + rt.tab_off
        thr_lo_rows = thr32 - half * rt.thr_scale
        thr_hi_rows = thr32 + half * rt.thr_scale
        tab_lo_rows = tab32 - half * rt.tab_scale
        tab_hi_rows = tab32 + half * rt.tab_scale
        score_eps = jnp.asarray(0.0, jnp.float32)
        st = jnp.float32
    if kind != "f32":
        user_slack = (None if slack_rows is None else _pad_rows(
            slack_rows.astype(jnp.float32), total, 0.0
        ).reshape(nb, block_size).max(axis=1, keepdims=True))
    thr_lo = _pad_rows(thr_lo_rows, total,
                       jnp.asarray(inf, st)).reshape(nb, block_size, tau)
    thr_hi = _pad_rows(thr_hi_rows, total,
                       jnp.asarray(-inf, st)).reshape(nb, block_size, tau)
    tab_lo = _pad_rows(tab_lo_rows, total,
                       jnp.asarray(inf, st)).reshape(nb, block_size, tau)
    tab_hi = _pad_rows(tab_hi_rows, total,
                       jnp.asarray(-inf, st)).reshape(nb, block_size, tau)
    rows = jnp.minimum(
        jnp.full((nb,), block_size, jnp.int32),
        (n - jnp.arange(nb) * block_size).astype(jnp.int32))
    norm_min = norm_max = mu = cos_r = None
    if with_cones:
        cs = _cos_slack(d)
        norms = jnp.sqrt(jnp.sum(u32 * u32, axis=1))        # (n,)
        # band widened for the sum-of-squares + sqrt rounding; zero rows
        # keep n↓ = 0 exactly (their score 0 must stay bracketed)
        norm_min = _pad_rows(norms * (1.0 - cs), total, inf
                             ).reshape(nb, block_size).min(
                                 axis=1, keepdims=True)
        norm_max = _pad_rows(norms * (1.0 + cs), total, 0.0
                             ).reshape(nb, block_size).max(
                                 axis=1, keepdims=True)
        # unit directions; exact-zero rows map to the zero direction
        # (their dot with μ̂ is 0, which only widens the cone)
        uhat = u32 / jnp.maximum(norms, 1e-30)[:, None]
        uh = _pad_rows(uhat, total, 0.0).reshape(nb, block_size, d)
        mu_raw = uh.sum(axis=1)                             # (nb, d)
        mu_n = jnp.sqrt(jnp.sum(mu_raw * mu_raw, axis=1, keepdims=True))
        # a cancelled mean direction is stored as EXACTLY 0: the query
        # side then sees cosθ = 0 and cos_r < 0 — the vacuous cone —
        # instead of an ill-normalized reference axis
        mu = jnp.where(mu_n > 1e-20,
                       mu_raw / jnp.maximum(mu_n, 1e-30), 0.0)
        dots = (uh * mu[:, None, :]).sum(axis=2)            # (nb, bs)
        valid = jnp.arange(block_size)[None, :] < rows[:, None]
        dots = jnp.where(valid, dots, 2.0)
        cos_r = jnp.clip(dots.min(axis=1, keepdims=True) - cs,
                         -1.0, 1.0)
    return BlockSummary(
        dim_min=u_lo.min(axis=1), dim_max=u_hi.max(axis=1),
        thr_min=thr_lo.min(axis=1), thr_max=thr_hi.max(axis=1),
        tab_min=tab_lo.min(axis=1), tab_max=tab_hi.max(axis=1),
        rows=rows, m=rt.m, user_slack=user_slack, score_eps=score_eps,
        norm_min=norm_min, norm_max=norm_max, mu=mu, cos_r=cos_r)


@jax.jit
def _kmeans_step(u: jax.Array, centers: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """One Lloyd iteration: assign rows to nearest center (expanded
    ‖u − c‖² = ‖u‖² − 2u·c + ‖c‖², one (n, d) × (d, K) matmul), then
    recenter; empty clusters keep their old center."""
    K = centers.shape[0]
    d2 = (jnp.sum(u * u, axis=1, keepdims=True)
          - 2.0 * (u @ centers.T)
          + jnp.sum(centers * centers, axis=1)[None, :])
    assign = jnp.argmin(d2, axis=1)
    sums = jax.ops.segment_sum(u, assign, num_segments=K)
    counts = jax.ops.segment_sum(jnp.ones((u.shape[0],), jnp.float32),
                                 assign, num_segments=K)
    new = jnp.where(counts[:, None] > 0.0,
                    sums / jnp.maximum(counts, 1.0)[:, None], centers)
    return assign, new


def kmeans_layout(users, *, block_size: int = DEFAULT_BLOCK,
                  n_clusters: Optional[int] = None, iters: int = 8,
                  seed: int = 0) -> Optional[np.ndarray]:
    """Build-time geometry-aware row layout (PR 6, module docstring).

    K-means-clusters the f32 user matrix (fixed PRNG seed — rebuilds are
    deterministic) and returns the permutation that groups each cluster
    into consecutive rows, ordered WITHIN each cluster by distance to its
    center: `perm[new] = old`. The secondary sort matters for mixed
    populations — rows only loosely attached to their cluster (a noise
    floor, stragglers between blobs) sink to the tail blocks of each
    segment instead of polluting every block's envelope, so the damage
    of unclusterable rows is confined to the few blocks that hold them.
    Ties (equal distance) break by original row id, keeping the layout
    deterministic. Returns None when the matrix spans fewer than two
    summary blocks (nothing to tighten).

    The caller applies `users[perm]` / `rank_table.take_rows(perm)` and
    publishes the inverse old→new map through the snapshot's
    `user_remap` channel; n is unchanged, so every backend shape
    contract (sharded divisibility included) survives the reorder.
    """
    u = jnp.asarray(users, jnp.float32)
    n = u.shape[0]
    if -(-n // block_size) < 2:
        return None
    K = int(n_clusters) if n_clusters else int(
        np.clip(n // (4 * block_size), 2, 128))
    K = min(K, n)
    key = jax.random.PRNGKey(seed)
    centers = u[jax.random.choice(key, n, shape=(K,), replace=False)]
    assign = jnp.zeros((n,), jnp.int32)
    for _ in range(max(int(iters), 1)):
        assign, centers = _kmeans_step(u, centers)
    d2 = jnp.sum((u - centers[assign]) ** 2, axis=1)
    # np.lexsort sorts by the LAST key first: assign, then distance,
    # then row id (lexsort's index tie-break is positional ⇒ stable)
    return np.lexsort((np.asarray(d2), np.asarray(assign))).astype(
        np.int64)


def _envelope_bounds(summary: BlockSummary, qs: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """Certified per-(block, query) bounds: (r_lo_opt, r_up_pes), each
    (nb, B), with r_lo_opt ≤ min r↓ and r_up_pes ≥ max r↑ over members.

    The score range is the box range intersected with the norm-band ×
    angular-cone range when the summary carries geometry sketches (PR 6;
    certification in the module docstring) — strictly no looser, often
    much tighter on direction-bundled blocks.

    Derivation mirrors `query.lookup_bounds_batch`: for a member with
    score s and bucketize index idx = #{t_j ≤ s}, the envelope score s↑
    and column-min thresholds give idx ≤ idx↑ := #{thr_min_j ≤ s↑}, and
    the table's non-increasing columns give r↓ = T[idx] ≥ tab_min[idx↑];
    symmetrically s↓ with thr_max bounds idx from below and tab_max
    bounds r↑ from above. Sharing `query._bucketize` keeps the
    storage-dtype cast identical (and monotone) on both sides.
    """
    d = qs.shape[1]
    qp = jnp.maximum(qs, 0.0).astype(jnp.float32)          # (B, d)
    qn = jnp.minimum(qs, 0.0).astype(jnp.float32)
    s_hi = summary.dim_max @ qp.T + summary.dim_min @ qn.T  # (nb, B)
    s_lo = summary.dim_min @ qp.T + summary.dim_max @ qn.T
    absmax = jnp.maximum(jnp.abs(summary.dim_min), jnp.abs(summary.dim_max))
    slack = (_SCORE_SLACK * d) * (absmax @ jnp.abs(qs).T) + _SCORE_SLACK_ABS
    s_hi = s_hi + slack
    s_lo = s_lo - slack
    if summary.norm_min is not None:
        # cone ∩ box (module docstring): s = ‖u‖·‖q‖·cos∠(u, q) with
        # ∠(u, q) ∈ [max(0, θ − r), min(π, θ + r)] — evaluated trig-free
        # via the cosine addition formulas, every cosine/norm widened in
        # the direction that can only loosen the bound
        cs = _cos_slack(d)
        q32 = qs.astype(jnp.float32)
        q_norm = jnp.sqrt(jnp.sum(q32 * q32, axis=1))       # (B,)
        q_hat = q32 / jnp.maximum(q_norm, 1e-30)[:, None]
        cos_t = summary.mu @ q_hat.T                        # (nb, B)
        cos_r = summary.cos_r                               # (nb, 1)
        sin_r = jnp.sqrt(jnp.maximum(1.0 - cos_r * cos_r, 0.0))
        ct_hi = jnp.clip(cos_t + cs, -1.0, 1.0)     # θ rounded down
        ct_lo = jnp.clip(cos_t - cs, -1.0, 1.0)     # θ rounded up
        st_hi = jnp.sqrt(jnp.maximum(1.0 - ct_hi * ct_hi, 0.0))
        st_lo = jnp.sqrt(jnp.maximum(1.0 - ct_lo * ct_lo, 0.0))
        # θ ≤ r ⇒ the cone contains q̂'s direction: cos max is 1;
        # θ + r ≥ π ⇒ it contains −q̂: cos min is −1
        c_hi = jnp.where(ct_hi >= cos_r, 1.0,
                         ct_hi * cos_r + st_hi * sin_r) + cs
        c_lo = jnp.where(ct_lo <= -cos_r, -1.0,
                         ct_lo * cos_r - st_lo * sin_r) - cs
        n_lo, n_hi = summary.norm_min, summary.norm_max     # (nb, 1)
        q_lo = (q_norm * (1.0 - cs))[None, :]
        q_up = (q_norm * (1.0 + cs))[None, :]
        # member-dot rounding, Cauchy-Schwarz-bounded: Σ|u_j·q_j| ≤
        # ‖u‖·‖q‖ ≤ n↑·‖q‖ — the cone analogue of the box's absmax term
        pad = (_SCORE_SLACK * d) * (n_hi * q_up) + _SCORE_SLACK_ABS
        s_hi_cone = jnp.where(c_hi >= 0.0, n_hi * c_hi * q_up,
                              n_lo * c_hi * q_lo) + pad
        s_lo_cone = jnp.where(c_lo >= 0.0, n_lo * c_lo * q_lo,
                              n_hi * c_lo * q_up) - pad
        s_hi = jnp.minimum(s_hi, s_hi_cone)
        s_lo = jnp.maximum(s_lo, s_lo_cone)
    if summary.user_slack is not None:
        # quantized user rows: the members' certified score intervals are
        # ± row_slack·‖q‖₁ around the dequantized score the box bounds
        extra = summary.user_slack * jnp.sum(jnp.abs(qs), axis=1)[None, :]
        s_hi = s_hi + extra
        s_lo = s_lo - extra

    tau = summary.tau
    m_plus_1 = (summary.m + 1).astype(jnp.float32)
    if summary.score_eps is not None:
        # CERTIFIED-WIDENED envelopes (quantized table): thr/tab already
        # carry the per-row quantization widening; the score side adds
        # the bf16 monotone-cast rounding of the member comparison (the
        # member compares in bf16, which can move a score by eps·|s|)
        e = summary.score_eps * jnp.maximum(jnp.abs(s_lo), jnp.abs(s_hi)) \
            + _SCORE_SLACK_ABS
        idx_hi = _bucketize(summary.thr_min, s_hi + e)    # ≥ member idx_hi
        # above-all-thresholds branch: a member BELOW its top threshold
        # still looks up a widened table entry, and quantization widening
        # can push a rank-1 entry below 1.0 (bf16: 1·(1−eps)) — the
        # envelope must floor at the widened minimum (last column of the
        # non-increasing tab_min), not at the exact 1.0
        r_lo_opt = jnp.where(
            idx_hi == tau, jnp.minimum(1.0, summary.tab_min[:, -1:]),
            jnp.take_along_axis(summary.tab_min,
                                jnp.clip(idx_hi, 0, tau - 1), axis=1))
        idx_lo = _bucketize(summary.thr_max, s_lo - e)    # ≤ member idx_lo
        top = jnp.maximum(m_plus_1, summary.tab_max[:, :1])
        r_up_pes = jnp.where(
            idx_lo == 0, top,
            jnp.take_along_axis(summary.tab_max,
                                jnp.clip(idx_lo - 1, 0, tau - 1), axis=1))
        # the widened thr/tab values are RECOMPUTED on the member path
        # (dequant + half-step pad inside the lookup) and XLA is free to
        # re-associate/fuse that arithmetic differently there, so the two
        # sides agree only to a few f32 ulp — pad one ppm relative
        # (≲ 1e-2 rank units at any practical m) to keep the envelopes a
        # certified superset of what the member lookup actually returns.
        # The f32 branch below needs none of this: both sides read the
        # same stored values and only min/max/compare them.
        return r_lo_opt * (1.0 - 1e-6), r_up_pes * (1.0 + 1e-6)
    idx_hi = _bucketize(summary.thr_min, s_hi)    # ≥ member idx
    tab_min = summary.tab_min.astype(jnp.float32)
    r_lo_opt = jnp.where(
        idx_hi == tau, 1.0,
        jnp.take_along_axis(tab_min, jnp.clip(idx_hi, 0, tau - 1), axis=1))
    idx_lo = _bucketize(summary.thr_max, s_lo)    # ≤ member idx
    tab_max = summary.tab_max.astype(jnp.float32)
    # max(m+1, column-0 envelope): a bf16 table entry can round a hair
    # above m+1, and the idx==0 branch must still dominate it
    top = jnp.maximum(m_plus_1, tab_max[:, :1])
    r_up_pes = jnp.where(
        idx_lo == 0, top,
        jnp.take_along_axis(tab_max, jnp.clip(idx_lo - 1, 0, tau - 1),
                            axis=1))
    return r_lo_opt, r_up_pes


@functools.partial(jax.jit,
                   static_argnames=("k", "block_size", "with_live"))
def phase_a(summary: BlockSummary, qs: jax.Array, *, k: int,
            block_size: int, n_add=0.0, n_del=0.0,
            user_live: Optional[jax.Array] = None, with_live: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """Coarse pass: certify, per query, which blocks can hold answers.

    Returns (keep, R̂): keep is (B, nb) bool — True where the block might
    contain a non-Lemma-1-pruned user for that query; R̂ is the (B,)
    certified upper bound on R↑_k that seeds the test. n_add/n_del widen
    the envelopes for a delta correction (padded widths — conservative);
    `user_live` (with_live=True) subtracts per-block dead rows from the
    live counts so R̂ never leans on deleted users.
    """
    r_lo_opt, r_up_pes = _envelope_bounds(summary, qs)      # (nb, B)
    r_lo_eff = r_lo_opt - jnp.asarray(n_del, jnp.float32)
    r_up_eff = r_up_pes + jnp.asarray(n_add, jnp.float32)
    live = summary.rows
    if with_live:
        nb = summary.n_blocks
        dead = _pad_rows(~user_live, nb * block_size, False)
        live = live - dead.reshape(nb, block_size).sum(
            axis=1).astype(jnp.int32)
    # R̂ seed: sort blocks by pessimistic r↑, accumulate live rows to k —
    # the k-th smallest r↑ over all users is ≤ the bound of the block
    # where the cumulative count crosses k.
    order = jnp.argsort(r_up_eff, axis=0)                   # (nb, B)
    vals = jnp.take_along_axis(r_up_eff, order, axis=0)
    cum = jnp.cumsum(live[order], axis=0)                   # (nb, B)
    enough = cum >= k
    pos = jnp.argmax(enough, axis=0)                        # first crossing
    B = qs.shape[0]
    r_hat = jnp.where(enough[-1], vals[pos, jnp.arange(B)], jnp.inf)
    keep = (r_lo_eff <= r_hat[None, :]) & (live > 0)[:, None]
    return keep.T, r_hat


# --------------------------------------------------------------- phase B
def bucket_width(count: int, *, n_blocks: int, min_blocks: int = 1) -> int:
    """Round a kept-block count up to a bucketed execution width so
    streaming keep-mask churn reuses compiled phase-B programs (the
    delta buffer's `_bucket` trick). Granularity is n_blocks/16 (floor 8)
    rather than powers of two: a pow-2 bucket can nearly DOUBLE the
    executed tile count (283 kept → 512 executed at nb = 1024), wiping
    out most of the skip win, while 1/16-granularity caps the padding
    overhead at ~6% of the index for ≤ ~16 compiled variants."""
    g = max(8, n_blocks // 16)
    target = max(count, int(min_blocks), 1)
    return min(max(-(-target // g) * g, target), max(n_blocks, target))


def bucket_blocks(kept: np.ndarray, *, n_blocks: int, min_blocks: int = 1
                  ) -> np.ndarray:
    """Pad the kept-block id list to the bucketed width. Padding repeats
    kept ids — duplicates recompute identical values, and the per-query
    keep mask (not the id list) decides what survives materialization."""
    kept = np.asarray(kept, np.int32)
    if kept.size == 0:
        kept = np.zeros(1, np.int32)            # degenerate: nothing live
    width = bucket_width(kept.size, n_blocks=n_blocks,
                         min_blocks=min_blocks)
    reps = -(-width // kept.size)
    return np.tile(kept, reps)[:width]


def row_indices(block_ids: jax.Array, block_size: int) -> jax.Array:
    """(nk,) block ids → (nk·block_size,) row ids (may exceed n on the
    tail block; gathers clip, scatters drop)."""
    return (block_ids[:, None] * block_size
            + jnp.arange(block_size, dtype=jnp.int32)[None, :]).reshape(-1)


def materialize(vals: jax.Array, block_ids: jax.Array, keep_q: jax.Array,
                n: int, sentinel, block_size: int) -> jax.Array:
    """Expand compacted (B, nk·bs) phase-B values into dense (B, n)
    arrays, then re-mask with the PER-QUERY keep mask.

    Implemented as a GATHER through the inverse block map (XLA CPU
    lowers scatters to serial element loops — gathering the (B, n)
    output from a sentinel-extended source is several times faster and
    handles duplicate padding ids for free). Global columns of unkept
    blocks read the appended sentinel column.

    The per-query mask (not the executed union) decides sentinel vs
    computed: a user computed only because another query in the batch
    kept its block still reads as sentinel for queries that pruned it —
    which makes every query's materialized arrays independent of its
    batch-mates, so B = 1 and B = 16 execution are bit-identical.
    """
    B = vals.shape[0]
    nk = block_ids.shape[0]
    nb = keep_q.shape[1]
    inv = jnp.full((nb,), nk * block_size, jnp.int32)
    inv = inv.at[block_ids].set(
        jnp.arange(nk, dtype=jnp.int32) * block_size, mode="drop")
    cols = jnp.arange(n, dtype=jnp.int32)
    blk_of = cols // block_size
    src = jnp.minimum(inv[blk_of] + cols % block_size, nk * block_size)
    padded = jnp.concatenate(
        [vals, jnp.full((B, 1), sentinel, jnp.float32)], axis=1)
    out = jnp.take(padded, src, axis=1)
    keep_rows = jnp.take(keep_q, blk_of, axis=1)            # (B, n)
    return jnp.where(keep_rows, out, sentinel)


def _finish_impl(r_lo_c: jax.Array, r_up_c: jax.Array, est_c: jax.Array,
                 block_ids: jax.Array, blk_valid: jax.Array,
                 keep_q: jax.Array, m_items, k: int, c: float, n: int,
                 block_size: int) -> QueryResult:
    """§4.3 steps 2-3 on the COMPACTED (B, nk·bs) phase-B arrays.

    Selecting on the compacted arrays instead of a scattered (B, n) copy
    cuts the selection from O(B·n) to O(B·n_kept) — at a 72% skip rate
    that is most of the remaining non-step-1 time. Exactness carries over
    from the materialized argument (module docstring): every user that
    can influence R↓_k/R↑_k or the top-k is kept FOR ITS QUERY, rows not
    kept-for-this-query (including duplicate padding tiles and tail
    padding past n, masked via `blk_valid`/row bounds) read the dominated
    sentinel, and the compacted row order restricted to valid tiles is
    ascending in global index, so `top_k` tie-breaking matches the full
    scan's. Only the two (B, n) bound fields of the result contract are
    materialized (through the gather in `materialize`); the diagnostic
    accept/prune counts are recomputed from them with the same formulas
    `select_topk` uses, so they equal the scattered path's bit-for-bit.
    """
    ridx = row_indices(block_ids, block_size)               # (nk·bs,)
    sentinel = (jnp.asarray(m_items) + 2).astype(jnp.float32)
    live_blk = keep_q[:, block_ids] & blk_valid[None, :]    # (B, nk)
    live = (jnp.repeat(live_blk, block_size, axis=1)
            & (ridx < n)[None, :])                          # (B, nk·bs)
    r_lo_s = jnp.where(live, r_lo_c, sentinel)
    r_up_s = jnp.where(live, r_up_c, sentinel)
    est_s = jnp.where(live, est_c, sentinel)
    R_lo_k = kth_smallest(r_lo_s, k)                        # exact globals
    R_up_k = kth_smallest(r_up_s, k)
    sel, guaranteed, _, _ = lemma1_select(
        r_lo_s, r_up_s, est_s, R_lo_k=R_lo_k, R_up_k=R_up_k, k=k, c=c,
        m_items=jnp.asarray(m_items))
    indices = jnp.take(ridx, sel).astype(jnp.int32)         # global rows
    est_rank = jnp.take_along_axis(est_s, sel, axis=-1)
    r_lo_m = materialize(r_lo_c, block_ids, keep_q, n, sentinel,
                         block_size)
    r_up_m = materialize(r_up_c, block_ids, keep_q, n, sentinel,
                         block_size)
    accepted = r_up_m <= (c * R_lo_k)[..., None]
    pruned = r_lo_m > R_up_k[..., None]
    return QueryResult(
        indices=indices, est_rank=est_rank, r_lo=r_lo_m, r_up=r_up_m,
        R_lo_k=R_lo_k, R_up_k=R_up_k, guaranteed=guaranteed,
        n_accepted=jnp.sum(accepted, axis=-1).astype(jnp.int32),
        n_pruned=jnp.sum(pruned, axis=-1).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("k", "n", "block_size"))
def finish_compacted(r_lo_c: jax.Array, r_up_c: jax.Array,
                     est_c: jax.Array, block_ids: jax.Array,
                     blk_valid: jax.Array, keep_q: jax.Array, m_items,
                     k: int, c: float, n: int, block_size: int
                     ) -> QueryResult:
    """Jitted phase-B tail for backends that produce compacted (B, nk·bs)
    bounds OUTSIDE a jit (the fused Pallas kernel, generic inner
    backends)."""
    return _finish_impl(r_lo_c, r_up_c, est_c, block_ids, blk_valid,
                        keep_q, m_items, k, c, n, block_size)


def _gathered_bounds(rt: RankTable, users, qs: jax.Array,
                     block_ids: jax.Array, block_size: int,
                     corr: Optional[DeltaCorrection] = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compacted step 1 (+ optional delta correction): gather kept rows,
    one (n_kept, d) × (d, B) matmul, one streamed pass over the kept
    threshold/table rows — the correction's count pass also only touches
    kept rows. Row gathers go through the storage-aware `take_rows`
    helpers, so int8 scale vectors (and quantized-user slack rows) travel
    with their rows. Returns (B, nk·bs) arrays."""
    n = users.shape[0]
    ridx = row_indices(block_ids, block_size)
    g = jnp.minimum(ridx, n - 1)
    scores, slack = user_scores_batch(take_user_rows(users, g),
                                      qs)                   # (nk·bs, B)
    r_lo, r_up, est = lookup_bounds_batch(rt.take_rows(g), scores, slack)
    if corr is not None:
        r_lo, r_up, est = rt_mod.apply_delta_corrections(
            scores, r_lo, r_up, est, corr.take_rows(g), slack=slack)
    return r_lo.T, r_up.T, est.T


@functools.partial(jax.jit, static_argnames=("k", "block_size"))
def pruned_query_batch(rt: RankTable, users: jax.Array, qs: jax.Array,
                       block_ids: jax.Array, blk_valid: jax.Array,
                       keep_q: jax.Array, k: int, c: float,
                       block_size: int = DEFAULT_BLOCK) -> QueryResult:
    """Dense phase B: ONE jit region — compacted step 1 + compacted
    selection (gather/matmul/lookup/select all fuse)."""
    r_lo, r_up, est = _gathered_bounds(rt, users, qs, block_ids,
                                       block_size)
    return _finish_impl(r_lo, r_up, est, block_ids, blk_valid, keep_q,
                        rt.m, k, c, users.shape[0], block_size)


@functools.partial(jax.jit, static_argnames=("block_size",))
def _pruned_delta_bounds(rt: RankTable, users: jax.Array, qs: jax.Array,
                         corr: DeltaCorrection, block_ids: jax.Array,
                         block_size: int
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    return _gathered_bounds(rt, users, qs, block_ids, block_size,
                            corr=corr)


def pruned_query_batch_delta(rt: RankTable, users: jax.Array,
                             qs: jax.Array, corr: DeltaCorrection,
                             block_ids: jax.Array, blk_valid: jax.Array,
                             keep_q: jax.Array, k: int, c: float,
                             block_size: int = DEFAULT_BLOCK
                             ) -> QueryResult:
    """Dense phase B over a mutated index. TWO jit regions for the same
    reason as `query.query_batch_delta` (XLA CPU re-fuses the corrected
    bound chain into every selection consumer otherwise)."""
    r_lo, r_up, est = _pruned_delta_bounds(rt, users, qs, corr, block_ids,
                                           block_size)
    return finish_compacted(r_lo, r_up, est, block_ids, blk_valid, keep_q,
                            corr.selection_m(), k, c, users.shape[0],
                            block_size)


@functools.partial(jax.jit, static_argnames=("k", "n", "block_size"))
def delta_finish_compacted(users, qs: jax.Array,
                           corr: DeltaCorrection, r_lo_c: jax.Array,
                           r_up_c: jax.Array, est_c: jax.Array,
                           block_ids: jax.Array, blk_valid: jax.Array,
                           keep_q: jax.Array, k: int, c: float, n: int,
                           block_size: int) -> QueryResult:
    """Delta tail for compacted-bounds backends (the fused kernel path
    and generic inner backends): the shared correction needs the u·q
    scores of the kept rows — one gathered matmul, the same extra cost
    `QueryBackend._delta_query` pays — then correction + compacted
    selection."""
    ridx = row_indices(block_ids, block_size)
    g = jnp.minimum(ridx, n - 1)
    scores, slack = user_scores_batch(take_user_rows(users, g),
                                      qs)                   # (rows, B)
    r_lo, r_up, est = rt_mod.apply_delta_corrections(
        scores, r_lo_c.T, r_up_c.T, est_c.T, corr.take_rows(g),
        slack=slack)
    return _finish_impl(r_lo.T, r_up.T, est.T, block_ids, blk_valid,
                        keep_q, corr.selection_m(), k, c, n, block_size)
