"""Exact reverse k-ranks (Definitions 1 & 2) — the O(nmd) oracle.

This is both (a) the correctness oracle every approximate path is tested
against and (b) the "straightforward algorithm" baseline from §1 of the
paper. Users are processed in fixed-size blocks so the (n, m) score matrix
never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def exact_ranks(users: jax.Array, items: jax.Array, q: jax.Array,
                block: int = 4096) -> jax.Array:
    """r(q, u, P) for every u ∈ U (Definition 1).

    Args:
      users: (n, d) user vectors U.
      items: (m, d) item vectors P.
      q:     (d,) query item vector.
      block: user-block size (controls peak memory: block × m scores).

    Returns:
      (n,) int32 ranks, r = 1 + #{p ∈ P : u·p > u·q}.
    """
    n = users.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    upad = jnp.pad(users, ((0, pad), (0, 0)))

    def body(_, ublk):
        uq = ublk @ q                                   # (block,)
        up = ublk @ items.T                             # (block, m)
        r = 1 + jnp.sum(up > uq[:, None], axis=1)
        return None, r.astype(jnp.int32)

    _, ranks = jax.lax.scan(body, None, upad.reshape(nb, block, -1))
    return ranks.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("k", "block"))
def reverse_k_ranks(users: jax.Array, items: jax.Array, q: jax.Array,
                    k: int, block: int = 4096) -> tuple[jax.Array, jax.Array]:
    """Exact reverse k-ranks query (Definition 2).

    Returns:
      (indices, ranks): the k users with the smallest r(q, ·, P), rank-
      ascending, ties broken by user index (deterministic).
    """
    ranks = exact_ranks(users, items, q, block=block)
    neg_topk, idx = jax.lax.top_k(-ranks, k)
    # top_k is stable w.r.t. index on ties of the key, which gives the
    # deterministic ordering we document.
    return idx.astype(jnp.int32), -neg_topk


def exact_rank_single(u: jax.Array, items: jax.Array, q: jax.Array) -> jax.Array:
    """r(q, u, P) for one user — the literal Definition 1."""
    return 1 + jnp.sum((items @ u) > jnp.dot(u, q)).astype(jnp.int32)
