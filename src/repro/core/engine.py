"""ReverseKRanksEngine — the public, composable API for the paper's system.

Wraps Algorithm 1 (build) + the §4.3 query into one object that owns the
user matrix and rank table, executing on a PLUGGABLE BACKEND selected by
name from the registry in `repro.core.backends`:

    backend="dense"    pure-jnp XLA (default; runs anywhere)
    backend="fused"    Pallas fused step-1 kernels (`repro.kernels`)
    backend="sharded"  mesh-sharded tree-merge (`repro.core.distributed`;
                       pass `mesh=` or it flattens all visible devices —
                       builds AND rebuilds row-sharded end-to-end via
                       `distributed.build_sharded`)
    backend="pruned[:<inner>]"  two-phase block-pruned scan over any of
                       the above (`repro.core.pruning`): per-block
                       summaries certify which user tiles can hold
                       answers, step 1 runs only over those — selected
                       indices bit-identical to the inner full scan

The API is BATCHED-FIRST: `query_batch` takes a (B, d) block of queries
and executes step 1 as one (n, d) × (d, B) MXU matmul plus a single
streamed pass over the (n, τ) rank table serving all B queries — the
dominant HBM stream is read once per batch, a ~B× bandwidth reduction
over per-query execution (see `benchmarks/perf_engine.py --batched`).
`query` is exactly the B = 1 case of `query_batch` (same code path,
leading axis squeezed), so single- and batched-query results cannot
drift apart.

Typical use::

    eng = ReverseKRanksEngine.build(users, items, RankTableConfig(), key)
    res = eng.query(q, k=10, c=2.0)            # QueryResult
    res = eng.query_batch(qs, k=10, c=2.0)     # leading B axis on fields

    eng = ReverseKRanksEngine.build(..., backend="fused")     # Pallas
    eng = ReverseKRanksEngine.build(..., backend="sharded", mesh=mesh)
    eng = ReverseKRanksEngine.build(..., backend="cached:fused")  # + LRU

Wrapped specs like `"cached:<inner>"` compose a wrapper backend (here the
serving cache: within-tick duplicate dedupe + a cross-tick per-query LRU,
see `repro.serve.cache`) around any registered inner backend. For ONLINE
workloads where queries arrive one at a time, `repro.serve.MicroBatcher`
sits on top of this engine and coalesces async submissions into
`query_batch` ticks. Custom backends register with
`repro.core.backends.register_backend` (wrappers with `register_wrapper`)
and become available here by name.

Mutation API (PR 3 — dynamic index maintenance, `repro.index`)
--------------------------------------------------------------
Engines produced by `build(...)` retain their item set and are MUTABLE
while queries keep flowing::

    ids = eng.insert_items(new_vectors)    # absorbed, no rebuild
    eng.delete_items(ids_to_drop)          # tombstoned, no rebuild
    eng.upsert_users(vectors, indices)     # rows re-estimated in place
    eng.upsert_users(vectors)              # append new users
    eng.delete_users(indices)              # masked out of every result
    eng.delta_stats()                      # rebuild-policy accounting
    eng.rebuild()                          # full Algorithm 1 + hot swap

State is EPOCH-VERSIONED: every mutation publishes a new immutable
`IndexSnapshot` behind an atomic pointer (`repro.index.snapshot`), and
each `query_batch` call executes entirely against the snapshot it grabbed
— concurrent mutations or a rebuild hot-swap never tear an in-flight
query or scheduler tick. Inserted/deleted items are fused into queries as
an exact per-user additive correction (`repro.index.delta`; the Eq. (1)
estimator is shifted, not degraded), valid while |delta|/m stays small.
The rebuild policy — delta ratio ρ and the tombstoned-sample error
budget — is enforced by `repro.index.MaintenanceLoop`, which rebuilds on
this engine's configured backend off-thread and hot-swaps the new epoch;
mutations that land mid-rebuild are re-based onto the new base during the
swap, and the serving cache (keyed on snapshot array identity) drops
every stale-epoch entry at the same instant.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rank_table as rt_mod
from repro.core.backends import QueryBackend, available_backends, get_backend
from repro.obs import registry as obs
from repro.obs import trace
from repro.core.types import QueryResult, RankTable, RankTableConfig
from repro.index import delta as delta_mod


from repro.index.maintenance import RebuildRecord
from repro.index.snapshot import IndexSnapshot, SnapshotManager, \
    compose_remaps
from repro.serve import faults


def _cluster_layout(users):
    """(perm, old→new remap) from `pruning.kmeans_layout`, or
    (None, None) when the matrix is too small or the layout is already
    the k-means order (an identity reorder must not publish a remap)."""
    from repro.core import pruning
    perm = pruning.kmeans_layout(users)
    if perm is None or np.array_equal(perm, np.arange(perm.size)):
        return None, None
    remap = np.full(perm.size, -1, np.int64)
    remap[perm] = np.arange(perm.size, dtype=np.int64)
    return perm, remap


@dataclasses.dataclass
class ReverseKRanksEngine:
    users: jax.Array          # (n, d)
    rank_table: RankTable     # thresholds/table: (n, tau)
    config: RankTableConfig
    backend: Union[str, QueryBackend] = "dense"
    mesh: Any = None          # only consumed by the "sharded" backend
    items: Any = None         # base item set; enables the mutation API
    build_key: Any = None     # Algorithm-1 key (re-derives sampling state)
    user_remap: Any = None    # lineage old→new row map the constructor's
    # user matrix ALREADY reflects (build(cluster_reorder=True) permutes
    # rows before constructing); seeds the epoch-0 snapshot

    def __post_init__(self):
        self._backend = get_backend(self.backend, mesh=self.mesh)
        base = None
        if self.items is not None:
            if self.build_key is None:
                raise ValueError(
                    "items= requires build_key= (the Algorithm-1 PRNG key) "
                    "to re-derive the index's sampling state; use "
                    "ReverseKRanksEngine.build(...) which wires both")
            base = delta_mod.BaseIndex.create(
                self.items, np.arange(self.items.shape[0]), self.config,
                self.build_key)
        m_base = base.m_base if base is not None else int(self.rank_table.m)
        snap = IndexSnapshot(
            epoch=0, users=self.users, rank_table=self.rank_table,
            config=self.config, base=base,
            delta=delta_mod.DeltaState.empty(m_base, self.users.shape[0]),
            corr=None, user_remap=self.user_remap,
            stored_users=self.config.storage.pack_users(self.users))
        self._snapshots = SnapshotManager(snap)
        self._lock = threading.RLock()          # serializes mutations
        self._rebuild_lock = threading.Lock()   # one rebuild in flight
        self._next_item_id = m_base
        self._corr_cost: dict = {}              # measured delta-cost cache
        self._persister = None                  # attach_persister wires it

    @classmethod
    def build(cls, users: jax.Array, items: jax.Array, cfg: RankTableConfig,
              key: jax.Array, backend: Union[str, QueryBackend] = "dense",
              mesh: Any = None, cluster_reorder: bool = False
              ) -> "ReverseKRanksEngine":
        """Run Algorithm 1 and return a query-ready, MUTABLE engine.

        The build executes on the requested backend's substrate
        (`QueryBackend.build_index`): "sharded" runs
        `distributed.build_sharded`, keeping the table row-sharded
        end-to-end instead of building on one device and re-sharding.

        `cluster_reorder` (PR 6): k-means-cluster the user matrix and
        physically reorder its rows BEFORE the build so the pruned
        backends' summary tiles are geometrically tight by construction
        (`pruning.kmeans_layout`). The old→new permutation is published
        as the epoch-0 snapshot's `user_remap`, exactly like a
        compaction's; n is unchanged, so backend shape contracts hold.
        """
        bk = get_backend(backend, mesh=mesh)
        remap = None
        if cluster_reorder:
            perm, remap = _cluster_layout(users)
            if perm is not None:
                users = jnp.asarray(users)[jnp.asarray(perm)]
        rt = bk.build_index(users, items, cfg, key)
        # construct from the ORIGINAL (backend, mesh) spec so the engine's
        # introspection fields survive (eng.mesh must not silently become
        # None for a sharded engine built with an explicit mesh);
        # __post_init__ re-resolves the backend, which is cheap — unless
        # the caller passed an instance, which get_backend returns as-is
        return cls(users=users, rank_table=rt, config=cfg,
                   backend=bk if isinstance(backend, QueryBackend)
                   else backend,
                   mesh=None if isinstance(backend, QueryBackend) else mesh,
                   items=items, build_key=key, user_remap=remap)

    @classmethod
    def restore(cls, path, *, backend: Union[str, QueryBackend] = "dense",
                mesh: Any = None) -> "ReverseKRanksEngine":
        """Recover an engine from a persistence directory (PR 9).

        Loads the newest checksum-valid spill (`repro.index.persist`),
        reconstructs its snapshot — everything not stored re-derives
        deterministically from (items, item_ids, config, build_key) —
        then replays the spill's WAL through the NORMAL mutation API, so
        the recovered engine is BITWISE the engine that was running at
        the durable point (same epochs, same rank-table bytes, same
        certified bounds). Raises `repro.index.persist.PersistError` when
        no durable point is trustworthy (rebuild from the master copy
        instead of serving wrong answers).

        Durability is NOT re-armed automatically: call
        `attach_persister(IndexPersister(path))` on the result to spill a
        fresh baseline and resume WAL logging.
        """
        from repro.index import persist as persist_mod
        state = persist_mod.load_latest(path)
        snap = state.snapshot
        eng = cls(users=snap.users, rank_table=snap.rank_table,
                  config=state.config, backend=backend, mesh=mesh)
        # graft the durable lineage over the constructor's fresh epoch-0
        # state: the snapshot chain, the stable-id counter, and the base
        # inputs the mutation API re-derives from
        eng.items = snap.base.items
        eng.build_key = state.build_key
        eng.user_remap = snap.user_remap
        eng._snapshots = SnapshotManager(snap)
        eng._next_item_id = state.next_item_id
        eng.users = snap.users
        eng.rank_table = snap.rank_table
        for rec in state.wal:
            persist_mod.replay_record(eng, rec)
        return eng

    def attach_persister(self, persister) -> None:
        """Arm crash-safety: spill the CURRENT snapshot as the baseline
        durable point, then WAL-log every subsequent mutation; each
        rebuild spills the new epoch and rotates the WAL. Requires the
        base item set (engines from `build(...)`)."""
        with self._lock:
            snap = self._require_base("attach_persister")
            persister.spill(snap, next_item_id=self._next_item_id,
                            build_key=self.build_key)
            self._persister = persister

    def _wal_append(self, op: str, **arrays) -> None:
        """Record one mutation (caller holds the mutation lock, AFTER its
        `_publish` — the publish defines the op's observable effect; the
        WAL merely makes it durable). None-valued arrays are omitted."""
        if self._persister is None:
            return
        self._persister.append(op, {k: v for k, v in arrays.items()
                                    if v is not None})

    @property
    def backend_name(self) -> str:
        return self._backend.name

    @staticmethod
    def backends() -> list[str]:
        """Names accepted by the `backend=` argument."""
        return available_backends()

    # ------------------------------------------------------------ queries
    def current_snapshot(self) -> IndexSnapshot:
        """The live index generation — one atomic pointer read. Callers
        that need several consistent reads (the micro-batching scheduler,
        metrics) pin one snapshot and use `query_batch_at`."""
        return self._snapshots.current()

    def query_batch_at(self, snap: IndexSnapshot, qs: jax.Array, k: int,
                       c: float) -> QueryResult:
        """`query_batch` against a PINNED snapshot: the whole call —
        bounds, delta correction, selection — sees exactly that epoch,
        regardless of concurrent mutations or a rebuild hot-swap."""
        if qs.ndim != 2:
            raise ValueError(
                f"query_batch expects (B, d) queries; got {qs.shape}")
        users = snap.query_users()      # spec-space storage (raw f32 on
        reg = obs.get_default()         # the exact spec — no-op path)
        reg.counter("engine_queries_total",
                    "queries executed (batch-expanded)").inc(qs.shape[0])
        if snap.corr is None:
            # no delta kwarg on the static path: pre-PR-3 custom backends
            # with a (rt, users, qs, *, k, c) signature keep working on
            # never-mutated engines
            return self._backend.query_batch(snap.rank_table, users,
                                             qs, k=k, c=c)
        # delta path: bounds are corrected for the epoch's uncompacted
        # add/delete buffers inside the backend — span it so a dashboard
        # can see the correction share of tick time grow with churn
        reg.counter("engine_delta_queries_total",
                    "queries served through delta corrections"
                    ).inc(qs.shape[0])
        with trace.span("engine.delta_correct", batch=qs.shape[0],
                        epoch=snap.epoch):
            return self._backend.query_batch(snap.rank_table, users, qs,
                                             k=k, c=c, delta=snap.corr)

    def dispatch_batch_at(self, snap: IndexSnapshot, qs, k: int,
                          c: float) -> QueryResult:
        """Non-blocking serving twin of `query_batch_at` (PR 10): a HOST
        (numpy) query block in, DEVICE-HANDLE QueryResult out. Routed
        through the backend's donation-safe `dispatch_device` entry — one
        H2D stages the tick, the computation is dispatched async, and no
        host sync happens on this thread; the scheduler's completion
        stage performs the tick's single D2H. Results are bit-identical
        to `query_batch_at` on the same block."""
        if qs.ndim != 2:
            raise ValueError(
                f"dispatch_batch_at expects (B, d) queries; got {qs.shape}")
        users = snap.query_users()
        reg = obs.get_default()
        reg.counter("engine_queries_total",
                    "queries executed (batch-expanded)").inc(qs.shape[0])
        if snap.corr is None:
            return self._backend.dispatch_device(snap.rank_table, users,
                                                 qs, k=k, c=c)
        reg.counter("engine_delta_queries_total",
                    "queries served through delta corrections"
                    ).inc(qs.shape[0])
        with trace.span("engine.delta_correct", batch=qs.shape[0],
                        epoch=snap.epoch):
            return self._backend.dispatch_device(snap.rank_table, users,
                                                 qs, k=k, c=c,
                                                 delta=snap.corr)

    def query_batch(self, qs: jax.Array, k: int, c: float) -> QueryResult:
        """Batched queries: qs is (B, d); every field gains a leading B
        axis. One table pass serves the whole batch (see module doc)."""
        return self.query_batch_at(self.current_snapshot(), qs, k, c)

    def query(self, q: jax.Array, k: int, c: float) -> QueryResult:
        """One query — the B = 1 case of `query_batch`."""
        if q.ndim != 1:
            raise ValueError(f"query expects a (d,) vector; got {q.shape} "
                             "(use query_batch for (B, d) blocks)")
        res = self.query_batch(q[None, :], k, c)
        return jax.tree_util.tree_map(lambda x: x[0], res)

    # ---------------------------------------------------------- mutations
    def _require_base(self, op: str) -> IndexSnapshot:
        snap = self.current_snapshot()
        if snap.base is None:
            raise ValueError(
                f"{op} requires the engine's base item set; construct with "
                "ReverseKRanksEngine.build(...) (or pass items= and "
                "build_key=)")
        return snap

    _KEEP_REMAP = object()      # _publish sentinel: carry snap.user_remap

    def _publish(self, snap: IndexSnapshot, *, users: jax.Array = None,
                 rank_table: RankTable = None,
                 delta: delta_mod.DeltaState = None,
                 base: delta_mod.BaseIndex = None,
                 epoch: Optional[int] = None,
                 user_remap=_KEEP_REMAP) -> IndexSnapshot:
        """Install the next epoch (caller holds the mutation lock).

        `user_remap` defaults to carrying the previous snapshot's value
        (ordinary mutations keep the lineage's coordinate map visible to
        clients); rebuilds pass the explicit COMPOSED map — lineage ∘
        compaction ∘ reorder (`snapshot.compose_remaps`)."""
        users = snap.users if users is None else users
        rank_table = snap.rank_table if rank_table is None else rank_table
        delta = snap.delta if delta is None else delta
        base = snap.base if base is None else base
        if user_remap is ReverseKRanksEngine._KEEP_REMAP:
            user_remap = snap.user_remap
        m_base = base.m_base if base is not None else int(rank_table.m)
        spec = self.config.storage
        # the spec-space user storage tracks the f32 system of record:
        # repacked only when the user matrix itself changed (O(nd), no
        # table work), carried otherwise
        stored = (snap.stored_users if users is snap.users
                  else spec.pack_users(users))
        if (snap.corr is not None and users is snap.users
                and base is snap.base
                and delta.added_ids is snap.delta.added_ids
                and delta.base_live is snap.delta.base_live):
            # user-mask-only mutation (delete_users): the per-user delta
            # score sets depend only on (users, item delta) — reuse them
            # instead of re-running the O(n·|delta|·d) scoring + sorts
            # under the mutation lock
            corr = snap.corr._replace(
                user_live=jnp.asarray(delta.user_live))
        else:
            corr = delta_mod.build_correction(users, base, delta, m_base,
                                              spec=spec)
        new = IndexSnapshot(
            epoch=snap.epoch + 1 if epoch is None else epoch, users=users,
            rank_table=rank_table, config=snap.config, base=base,
            delta=delta, corr=corr, user_remap=user_remap,
            stored_users=stored)
        self._snapshots.publish(new)
        # refresh the introspection fields; consistent PAIRS always come
        # from current_snapshot(), these are best-effort mirrors
        self.users = users
        self.rank_table = rank_table
        return new

    def insert_items(self, vectors: jax.Array) -> np.ndarray:
        """Insert item vectors; returns their stable ids. Absorbed by the
        delta buffer — no rebuild, queries see them immediately (scored
        exactly per user at query time)."""
        vectors = jnp.atleast_2d(jnp.asarray(vectors))
        if vectors.shape[1] != self.d:
            raise ValueError(f"expected (*, {self.d}) item vectors; got "
                             f"{vectors.shape}")
        with self._lock:
            snap = self._require_base("insert_items")
            ids = np.arange(self._next_item_id,
                            self._next_item_id + vectors.shape[0],
                            dtype=np.int64)
            self._next_item_id += vectors.shape[0]
            self._publish(snap, delta=snap.delta.with_inserted(ids, vectors))
            self._wal_append("insert_items", vectors=vectors, ids=ids)
        return ids

    def delete_items(self, ids: Sequence[int]) -> None:
        """Delete items by stable id (base items are tombstoned; items
        inserted this epoch simply leave the buffer). Raises KeyError for
        unknown or already-deleted ids."""
        with self._lock:
            snap = self._require_base("delete_items")
            self._publish(snap,
                          delta=snap.delta.with_deleted(ids, snap.base))
            self._wal_append("delete_items",
                             ids=np.asarray(list(ids), np.int64))

    def upsert_users(self, vectors: jax.Array,
                     indices: Optional[Sequence[int]] = None) -> np.ndarray:
        """Replace user rows (indices given) or append new users (None).
        The touched threshold/table rows are re-estimated against the
        build's retained sample — the same per-row math as a from-scratch
        rebuild — so upserts cost O(t·ω·s·d), not a rebuild."""
        vectors = jnp.atleast_2d(jnp.asarray(vectors))
        if vectors.shape[1] != self.d:
            raise ValueError(f"expected (*, {self.d}) user vectors; got "
                             f"{vectors.shape}")
        with self._lock:
            snap = self._require_base("upsert_users")
            n0 = snap.users.shape[0]
            if indices is None:
                # fail a shape the backend cannot query BEFORE publishing
                # (e.g. sharded: n must stay divisible by the mesh size)
                self._backend.check_users_shape(n0 + vectors.shape[0])
                idx = np.arange(n0, n0 + vectors.shape[0])
                users_new = jnp.concatenate([snap.users, vectors])
            else:
                idx = np.asarray(list(indices), np.int64)
                if idx.size != vectors.shape[0]:
                    raise ValueError(f"{idx.size} indices for "
                                     f"{vectors.shape[0]} vectors")
                if idx.size and (idx.min() < 0 or idx.max() >= n0):
                    raise IndexError(f"user indices out of range [0, {n0})")
                if np.unique(idx).size != idx.size:
                    # .at[].set with duplicate indices picks an arbitrary
                    # winner INDEPENDENTLY for users and for the table
                    # rows — the snapshot could pair one vector with the
                    # other's recomputed rows
                    raise ValueError("duplicate user indices in upsert")
                users_new = snap.users.at[jnp.asarray(idx)].set(vectors)
            thr_rows, tab_rows = self._user_rows(vectors, snap.base)
            rt = snap.rank_table
            # the ONE storage pack path (shared with the builds): rows are
            # re-estimated in f32 and materialized per spec — per-row
            # quantization parameters make the update strictly local
            packed = self.config.storage.pack_table(thr_rows, tab_rows)
            if indices is None:
                rt_new = rt.append_rows(packed)
            else:
                rt_new = rt.set_rows(jnp.asarray(idx), packed)
            self._publish(
                snap, users=users_new, rank_table=rt_new,
                delta=snap.delta.with_users(touched=tuple(int(i)
                                                          for i in idx),
                                            n_users=users_new.shape[0]))
            self._wal_append("upsert_users", vectors=vectors,
                             indices=None if indices is None else idx)
        return idx

    def delete_users(self, indices: Sequence[int]) -> None:
        """Mask users out of every future result (their rows remain until
        the next rebuild compacts nothing — masking is O(1) per query)."""
        idx = np.asarray(list(indices), np.int64)
        with self._lock:
            snap = self.current_snapshot()
            n = snap.users.shape[0]
            if idx.size and (idx.min() < 0 or idx.max() >= n):
                raise IndexError(f"user indices out of range [0, {n})")
            self._publish(snap, delta=snap.delta.with_users(
                dead=tuple(int(i) for i in idx)))
            self._wal_append("delete_users", indices=idx)

    def _user_rows(self, vectors: jax.Array, base: delta_mod.BaseIndex):
        cfg = self.config
        return rt_mod.recompute_user_rows(
            vectors, base.samples, base.weights, cfg,
            items=base.items if cfg.threshold_mode == "exact" else None,
            max_norm=base.max_norm)

    # ------------------------------------------------- rebuild / lifecycle
    def delta_stats(self) -> delta_mod.DeltaStats:
        """Delta-buffer accounting (drives `MaintenancePolicy`)."""
        snap = self.current_snapshot()
        return snap.delta.stats(snap.base)

    def correction_overhead(self, *, batch: int = 8, k: int = 10,
                            c: float = 2.0, iters: int = 2) -> float:
        """MEASURED per-query delta-correction cost, as the wall-time
        ratio (corrected query / static query) of a small probe batch on
        this engine's backend — the delta-aware half of the rebuild
        policy (`MaintenancePolicy.max_correction_overhead`).

        The probe times the real serving path (the (n, |delta|) count
        pass rides inside it), so the number reflects this host and this
        backend, not a model. Results are cached per bucketed correction
        SHAPE — the delta buffer pads score sets to power-of-two widths,
        so a streaming workload re-measures only O(log |delta|) times per
        epoch lineage. Returns 1.0 on an unmutated index (no probe run).
        """
        snap = self.current_snapshot()
        if snap.corr is None:
            return 1.0
        key = (snap.corr.n_add, snap.corr.n_del, snap.users.shape[0],
               batch, k, float(c))
        hit = self._corr_cost.get(key)
        if hit is not None:
            return hit
        qs = snap.users[:min(batch, snap.users.shape[0])]
        # probe the REAL serving path: spec-space user storage, exactly
        # what query_batch_at dispatches (a raw-f32 probe on a quantized
        # engine would time a program production never runs)
        users = snap.query_users()

        def run(delta) -> None:
            if delta is None:
                r = self._backend.query_batch(snap.rank_table, users,
                                              qs, k=k, c=c)
            else:
                r = self._backend.query_batch(snap.rank_table, users,
                                              qs, k=k, c=c, delta=delta)
            jax.block_until_ready(r.indices)

        times = {}
        for name, delta in (("static", None), ("delta", snap.corr)):
            run(delta)                          # warmup: compile both
            t0 = time.perf_counter()
            for _ in range(iters):
                run(delta)
            times[name] = (time.perf_counter() - t0) / iters
        ratio = times["delta"] / max(times["static"], 1e-9)
        self._corr_cost[key] = ratio
        return ratio

    def live_items(self) -> jax.Array:
        return self._require_base("live_items").live_items()

    def live_item_ids(self) -> np.ndarray:
        return self._require_base("live_item_ids").live_item_ids()

    def rebuild(self, reason: str = "manual",
                compact_dead_above: Optional[float] = None,
                reorder_clusters: bool = False
                ) -> Optional[RebuildRecord]:
        """Full Algorithm 1 over the live item set on this engine's
        backend, then an atomic hot-swap to the new epoch.

        The build runs OFF the mutation lock (serving and mutations
        continue); the swap re-bases any delta that accumulated while
        building — residual inserts/deletes carry over, user rows
        upserted or appended mid-build are re-estimated against the new
        sample — so no mutation is ever lost to a rebuild. Returns None
        if another rebuild is already in flight.

        `compact_dead_above` (PR 4): when the tombstoned-user fraction at
        swap time exceeds this threshold, dead rows are COMPACTED out of
        the users/table arrays instead of surviving as masked dead
        weight; the old→new index remap is surfaced on the published
        snapshot (`IndexSnapshot.user_remap`, −1 for dropped rows) so
        clients can translate the ids they hold. Compaction is skipped —
        never failed — when the shrunken n would violate the backend's
        shape contract (e.g. sharded divisibility). None disables it.

        `reorder_clusters` (PR 6): after any compaction, k-means-cluster
        the (compacted) user matrix and physically reorder rows/table so
        pruned-backend tiles are tight (`pruning.kmeans_layout`); n is
        unchanged, so no shape contract can fail. The published
        `user_remap` is the COMPOSITION lineage-remap ∘ compaction ∘
        reorder — a rebuild that does neither carries the lineage's
        remap forward unchanged (it is never cleared).
        """
        if not self._rebuild_lock.acquire(blocking=False):
            return None
        try:
            if faults.ACTIVE is not None:
                # chaos site: a failing Algorithm-1 build — exercises the
                # maintenance loop's backoff + recovery accounting
                faults.fire("index.rebuild")
            with self._lock:
                snap = self._require_base("rebuild")
            stats = snap.delta.stats(snap.base)
            live_items = snap.live_items()
            live_ids = snap.live_item_ids()
            t0 = time.monotonic()
            rt_new = self._backend.build_index(snap.users, live_items,
                                               self.config, self.build_key)
            base_new = delta_mod.BaseIndex.create(live_items, live_ids,
                                                  self.config,
                                                  self.build_key)
            jax.block_until_ready(rt_new.table)
            build_s = time.monotonic() - t0
            t1 = time.monotonic()
            with self._lock:
                now = self.current_snapshot()
                users_now = now.users
                rt_work = rt_new
                n_built, n_now = snap.users.shape[0], users_now.shape[0]
                # Stale rows = touched users whose VECTOR changed since
                # capture, plus rows appended mid-build. Comparing
                # vectors (not set-differencing touched_users) matters:
                # a user upserted both before capture and again
                # mid-build is in both touched sets, and a difference
                # would silently keep its capture-time row while
                # users_now holds the newer vector.
                cand = sorted(set(now.delta.touched_users))
                existing = [i for i in cand if i < n_built]
                stale = [i for i in cand if i >= n_built]
                if existing:
                    je = jnp.asarray(existing)
                    same = np.asarray(jnp.all(
                        users_now[je] == snap.users[je], axis=1))
                    stale += [i for i, s in zip(existing, same) if not s]
                touched = sorted(set(stale) | set(range(n_built, n_now)))
                if n_now > n_built:     # users appended mid-build
                    # placeholder rows only: every appended index is in
                    # `touched` and re-estimated below
                    grow = (n_now - n_built, rt_work.tau)
                    rt_work = rt_work.append_rows(
                        self.config.storage.pack_table(
                            jnp.zeros(grow, jnp.float32),
                            jnp.ones(grow, jnp.float32)))
                if touched:             # rows mutated mid-build
                    rows_thr, rows_tab = self._user_rows(
                        users_now[jnp.asarray(touched)], base_new)
                    j = jnp.asarray(np.asarray(touched))
                    rt_work = rt_work.set_rows(
                        j, self.config.storage.pack_table(rows_thr,
                                                          rows_tab))
                delta_new = delta_mod.residual_after_rebuild(
                    snap.base, now.delta, live_ids)
                remap = None
                n_dropped = 0
                live = delta_new.user_live
                if (compact_dead_above is not None and live.size
                        and 1.0 - float(live.mean()) > compact_dead_above):
                    keep = np.flatnonzero(live)
                    try:
                        # a shape the backend cannot query (e.g. sharded
                        # divisibility) skips compaction, never fails the
                        # rebuild — dead rows stay masked until a later
                        # rebuild can drop them legally
                        self._backend.check_users_shape(int(keep.size))
                        ok = keep.size > 0
                    except ValueError:
                        ok = False
                    if ok:
                        n_dropped = int(live.size - keep.size)
                        remap = np.full(live.size, -1, np.int64)
                        remap[keep] = np.arange(keep.size)
                        j = jnp.asarray(keep)
                        users_now = users_now[j]
                        rt_work = rt_work.take_rows(j)
                        delta_new = dataclasses.replace(
                            delta_new,
                            user_live=np.ones(keep.size, bool))
                reordered = False
                if reorder_clusters:
                    perm, rmap = _cluster_layout(np.asarray(users_now))
                    if perm is not None:
                        reordered = True
                        j = jnp.asarray(perm)
                        users_now = users_now[j]
                        rt_work = rt_work.take_rows(j)
                        delta_new = dataclasses.replace(
                            delta_new, user_live=np.asarray(
                                delta_new.user_live)[perm])
                        remap = compose_remaps(remap, rmap)
                swapped = self._publish(
                    now, users=users_now, rank_table=rt_work,
                    delta=delta_new, base=base_new,
                    user_remap=compose_remaps(now.user_remap, remap))
                if self._persister is not None:
                    # INSIDE the locked swap: the spill supersedes the
                    # old WAL and rotation opens the new one before any
                    # post-swap mutation can append — no mutation can
                    # fall between the durable points. A spill failure
                    # degrades durability, never the rebuild.
                    try:
                        self._persister.spill(
                            swapped, next_item_id=self._next_item_id,
                            build_key=self.build_key)
                    except OSError:
                        logging.getLogger(__name__).exception(
                            "rebuild spill failed; durability stays at "
                            "the previous spill + WAL")
            # epoch captured from the published snapshot, not self.epoch:
            # a mutation racing in after the lock releases must not be
            # misattributed to this swap
            return RebuildRecord(
                epoch_before=snap.epoch, epoch_after=swapped.epoch,
                reason=reason, build_s=build_s,
                swap_s=time.monotonic() - t1, stats=stats,
                users_compacted=n_dropped, users_reordered=reordered)
        finally:
            self._rebuild_lock.release()

    # ------------------------------------------------------ introspection
    @property
    def epoch(self) -> int:
        return self.current_snapshot().epoch

    @property
    def n(self) -> int:
        return self.current_snapshot().users.shape[0]

    @property
    def d(self) -> int:
        return self.current_snapshot().users.shape[1]

    def memory_bytes(self) -> int:
        """Query-path storage footprint (thresholds + table + per-row
        quantization parameters + the user storage the backends actually
        scan + delta correction), per §4.2's O(n) claim — the delta adds
        O(n·|delta|) until rebuild. User bytes are counted UNIFORMLY
        (spec-space storage when quantized, the raw f32 matrix otherwise)
        so spec footprints are comparable."""
        snap = self.current_snapshot()
        rt = snap.rank_table
        sz = lambda a: 0 if a is None else int(a.size * a.dtype.itemsize)
        total = (sz(rt.thresholds) + sz(rt.table) + sz(rt.thr_scale)
                 + sz(rt.thr_off) + sz(rt.tab_scale) + sz(rt.tab_off)
                 + sz(rt.thr_dev))
        if snap.stored_users is not None:
            su = snap.stored_users
            total += sz(su.rows) + sz(su.scale) + sz(su.row_slack)
        else:
            total += sz(snap.users)
        if snap.corr is not None:
            c = snap.corr
            total += (sz(c.add_scores) + sz(c.del_scores)
                      + int(c.user_live.size) + sz(c.add_scale)
                      + sz(c.add_off) + sz(c.del_scale) + sz(c.del_off))
        return total
