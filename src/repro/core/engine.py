"""ReverseKRanksEngine — the public, composable API for the paper's system.

Wraps Algorithm 1 (build) + the §4.3 query into one object that owns the
user matrix and rank table, executing on a PLUGGABLE BACKEND selected by
name from the registry in `repro.core.backends`:

    backend="dense"    pure-jnp XLA (default; runs anywhere)
    backend="fused"    Pallas fused step-1 kernels (`repro.kernels`)
    backend="sharded"  mesh-sharded tree-merge (`repro.core.distributed`;
                       pass `mesh=` or it flattens all visible devices)

The API is BATCHED-FIRST: `query_batch` takes a (B, d) block of queries
and executes step 1 as one (n, d) × (d, B) MXU matmul plus a single
streamed pass over the (n, τ) rank table serving all B queries — the
dominant HBM stream is read once per batch, a ~B× bandwidth reduction
over per-query execution (see `benchmarks/perf_engine.py --batched`).
`query` is exactly the B = 1 case of `query_batch` (same code path,
leading axis squeezed), so single- and batched-query results cannot
drift apart.

Typical use::

    eng = ReverseKRanksEngine.build(users, items, RankTableConfig(), key)
    res = eng.query(q, k=10, c=2.0)            # QueryResult
    res = eng.query_batch(qs, k=10, c=2.0)     # leading B axis on fields

    eng = ReverseKRanksEngine.build(..., backend="fused")     # Pallas
    eng = ReverseKRanksEngine.build(..., backend="sharded", mesh=mesh)
    eng = ReverseKRanksEngine.build(..., backend="cached:fused")  # + LRU

Wrapped specs like `"cached:<inner>"` compose a wrapper backend (here the
serving cache: within-tick duplicate dedupe + a cross-tick per-query LRU,
see `repro.serve.cache`) around any registered inner backend. For ONLINE
workloads where queries arrive one at a time, `repro.serve.MicroBatcher`
sits on top of this engine and coalesces async submissions into
`query_batch` ticks. Custom backends register with
`repro.core.backends.register_backend` (wrappers with `register_wrapper`)
and become available here by name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax

from repro.core import rank_table as rt_mod
from repro.core.backends import QueryBackend, available_backends, get_backend
from repro.core.types import QueryResult, RankTable, RankTableConfig


@dataclasses.dataclass
class ReverseKRanksEngine:
    users: jax.Array          # (n, d)
    rank_table: RankTable     # thresholds/table: (n, tau)
    config: RankTableConfig
    backend: Union[str, QueryBackend] = "dense"
    mesh: Any = None          # only consumed by the "sharded" backend

    def __post_init__(self):
        self._backend = get_backend(self.backend, mesh=self.mesh)

    @classmethod
    def build(cls, users: jax.Array, items: jax.Array, cfg: RankTableConfig,
              key: jax.Array, backend: Union[str, QueryBackend] = "dense",
              mesh: Any = None) -> "ReverseKRanksEngine":
        """Run Algorithm 1 and return a query-ready engine."""
        rt = rt_mod.build_rank_table(users, items, cfg, key)
        return cls(users=users, rank_table=rt, config=cfg, backend=backend,
                   mesh=mesh)

    @property
    def backend_name(self) -> str:
        return self._backend.name

    @staticmethod
    def backends() -> list[str]:
        """Names accepted by the `backend=` argument."""
        return available_backends()

    def query(self, q: jax.Array, k: int, c: float) -> QueryResult:
        """One query — the B = 1 case of `query_batch`."""
        if q.ndim != 1:
            raise ValueError(f"query expects a (d,) vector; got {q.shape} "
                             "(use query_batch for (B, d) blocks)")
        res = self.query_batch(q[None, :], k, c)
        return jax.tree_util.tree_map(lambda x: x[0], res)

    def query_batch(self, qs: jax.Array, k: int, c: float) -> QueryResult:
        """Batched queries: qs is (B, d); every field gains a leading B
        axis. One table pass serves the whole batch (see module doc)."""
        if qs.ndim != 2:
            raise ValueError(
                f"query_batch expects (B, d) queries; got {qs.shape}")
        return self._backend.query_batch(self.rank_table, self.users, qs,
                                         k=k, c=c)

    @property
    def n(self) -> int:
        return self.users.shape[0]

    @property
    def d(self) -> int:
        return self.users.shape[1]

    def memory_bytes(self) -> int:
        """Index footprint (thresholds + table), per §4.2's O(n) claim."""
        rt = self.rank_table
        return int(rt.thresholds.size * rt.thresholds.dtype.itemsize
                   + rt.table.size * rt.table.dtype.itemsize)
