"""ReverseKRanksEngine — the public, composable API for the paper's system.

Wraps Algorithm 1 (build) + the §4.3 query into one object that owns the
user matrix and rank table, with single-device and mesh-sharded execution
(see `repro.core.distributed` for the multi-pod path and
`repro.kernels` for the fused TPU hot loops).

Typical use::

    eng = ReverseKRanksEngine.build(users, items, RankTableConfig(), key)
    res = eng.query(q, k=10, c=2.0)            # QueryResult
    res = eng.query_batch(qs, k=10, c=2.0)     # vmapped over queries
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import query as query_mod
from repro.core import rank_table as rt_mod
from repro.core.types import QueryResult, RankTable, RankTableConfig


@dataclasses.dataclass
class ReverseKRanksEngine:
    users: jax.Array          # (n, d)
    rank_table: RankTable     # thresholds/table: (n, tau)
    config: RankTableConfig
    use_kernels: bool = False  # route step 1 through the Pallas fused kernel

    @classmethod
    def build(cls, users: jax.Array, items: jax.Array, cfg: RankTableConfig,
              key: jax.Array, use_kernels: bool = False
              ) -> "ReverseKRanksEngine":
        """Run Algorithm 1 and return a query-ready engine."""
        rt = rt_mod.build_rank_table(users, items, cfg, key)
        return cls(users=users, rank_table=rt, config=cfg,
                   use_kernels=use_kernels)

    def query(self, q: jax.Array, k: int, c: float) -> QueryResult:
        if self.use_kernels:
            from repro.kernels import ops as kops
            return kops.query_fused(self.rank_table, self.users, q, k, c)
        return query_mod.query(self.rank_table, self.users, q, k, c)

    def query_batch(self, qs: jax.Array, k: int, c: float) -> QueryResult:
        if self.use_kernels:
            from repro.kernels import ops as kops
            return jax.vmap(
                lambda q: kops.query_fused(self.rank_table, self.users, q,
                                           k, c))(qs)
        return query_mod.query_batch(self.rank_table, self.users, qs, k, c)

    @property
    def n(self) -> int:
        return self.users.shape[0]

    @property
    def d(self) -> int:
        return self.users.shape[1]

    def memory_bytes(self) -> int:
        """Index footprint (thresholds + table), per §4.2's O(n) claim."""
        rt = self.rank_table
        return int(rt.thresholds.size * rt.thresholds.dtype.itemsize
                   + rt.table.size * rt.table.dtype.itemsize)
