"""Core data types for the c-approximate reverse k-ranks engine.

All types are JAX pytrees (NamedTuples of arrays) or static dataclass
configs, so they flow through jit / shard_map / checkpointing unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RankTableConfig:
    """Static configuration for Algorithm 1 (pre-processing).

    Attributes:
      tau:   number of inner-product thresholds per user (table columns).
             Paper default 500 (Table 1 tunes 100/500/1000).
      omega: number of norm-stratified partitions of P (Alg. 1 input).
      s:     number of random samples per partition (Alg. 1 input).
      threshold_mode: how f_min/f_max (threshold range per user) is obtained:
        * "sampled"    — min/max of u·p over the stratified sample, widened
                         by `range_pad` of the sampled range. O(ω·s·d)/user,
                         consistent with the paper's O(d) claim for
                         ω,s = O(1); the default.
        * "norm_bound" — ±‖u‖·max‖p‖ (the paper's footnote-1 "domain value"
                         O(1) variant).
        * "exact"      — true f_min/f_max via a full U·Pᵀ pass, O(nmd).
                         Only for small oracle tests.
      range_pad: fractional widening of the sampled threshold range.
      sample_with_replacement: stratified sampling mode; False matches the
        paper ("s random samples in P_l"), True is used when s > |P_l|.
    """

    tau: int = 500
    omega: int = 10
    s: int = 64
    threshold_mode: str = "sampled"
    range_pad: float = 0.05
    sample_with_replacement: bool = False
    # Storage dtype for thresholds+table (§Perf H4): "bfloat16" halves the
    # dominant HBM stream of the query at a bounded rank-quantization cost
    # (≤ 2^-8 relative — smaller than Eq. 1's sampling noise at s = 64).
    storage_dtype: str = "float32"

    def __post_init__(self):
        if self.tau < 2:
            raise ValueError(f"tau must be >= 2, got {self.tau}")
        if self.omega < 1:
            raise ValueError(f"omega must be >= 1, got {self.omega}")
        if self.s < 1:
            raise ValueError(f"s must be >= 1, got {self.s}")
        if self.threshold_mode not in ("sampled", "norm_bound", "exact"):
            raise ValueError(f"unknown threshold_mode {self.threshold_mode!r}")
        if self.storage_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"unknown storage_dtype {self.storage_dtype!r}")


class RankTable(NamedTuple):
    """The paper's rank table T (§4.1) plus its per-user thresholds.

    thresholds: (n, tau) float32, ascending along axis 1 — t_{u_i, j}.
    table:      (n, tau) float32, non-increasing along axis 1 — estimated
                rank of an item p for u_i when u_i·p = t_{u_i,j}  (Eq. 1).
    m:          () int32 — |P|, needed for the out-of-range upper bound m+1.
    """

    thresholds: jax.Array
    table: jax.Array
    m: jax.Array

    @property
    def n(self) -> int:
        return self.thresholds.shape[0]

    @property
    def tau(self) -> int:
        return self.thresholds.shape[1]


class DeltaCorrection(NamedTuple):
    """Query-time correction for a mutated index (see `repro.index`).

    The rank table is built over a frozen base item set P₀ and user set U₀;
    streaming mutations are absorbed by a delta buffer and FUSED into the
    estimated rank at query time as a bounded additive correction:

        r(q, u, P') = r(q, u, P₀) + #{a ∈ A : u·a > u·q}
                                  − #{p ∈ D : u·p > u·q}

    for P' = (P₀ \\ D) ∪ A. Both correction terms are computed EXACTLY
    from per-user scores against the (small) delta item sets, so the
    Eq. (1) estimator's error is untouched by the shift — the only delta
    degradation is the stale sampling noise of tombstoned sample
    positions, which the maintenance policy budgets (`repro.index.delta`).

    All fields are device arrays (the tuple is a pytree and flows through
    jit / shard_map); the per-row score sets are pre-sorted so the query-
    time count is one vmapped searchsorted — O(B·log|delta|) per user row
    on top of the static path.

    add_scores: (n, n_add) float32, ascending per row — u_i·a for every
                live inserted item a ∈ A.
    del_scores: (n, n_del) float32, ascending per row — u_i·p for every
                tombstoned base item p ∈ D.
    user_live:  (n,) bool — False rows are deleted users; their bounds are
                forced past every admissible selection key.
    m_new:      () int32 — |P'| = |P₀| − |D| + |A|, the live item count
                (replaces `RankTable.m` in the selection).
    """

    add_scores: jax.Array
    del_scores: jax.Array
    user_live: jax.Array
    m_new: jax.Array

    @property
    def n_add(self) -> int:
        return self.add_scores.shape[1]

    @property
    def n_del(self) -> int:
        return self.del_scores.shape[1]

    def selection_m(self) -> jax.Array:
        """The `m_items` to pass into the §4.3 composite selection key on
        the delta path (see `query.lemma1_key`): the class-separation
        offset must dominate the SHIFTED estimate range
        [1 − n_del, m_base + 1 + n_add], whose width is
        m_new + 2·n_del ≥ width for the padded column counts — the plain
        live count m' is not enough once deletions widen the range
        downward. Every backend derives it from this one method, so the
        key stays identical across dense/fused/sharded."""
        return self.m_new + 2 * self.n_del


class QueryResult(NamedTuple):
    """Output of one c-approximate reverse k-ranks query (§4.3).

    indices:   (k,) int32 — selected user indices (U_c), best-first.
    est_rank:  (k,) float32 — interpolated rank estimates for the selection.
    r_lo:      (n,) float32 — per-user lower-bound rank r↓.
    r_up:      (n,) float32 — per-user upper-bound rank r↑.
    R_lo_k:    () float32 — k-th smallest lower bound (R↓_k).
    R_up_k:    () float32 — k-th smallest upper bound (R↑_k).
    guaranteed:() bool    — Lemma-1 case: c·R↓_k ≥ R↑_k (search closed in
                step 2; no interpolation fill needed).
    n_accepted:() int32   — #users with r↑ ≤ c·R↓_k (Lemma 1 (1)).
    n_pruned:  () int32   — #users with r↓ > R↑_k  (Lemma 1 (2)).
    """

    indices: jax.Array
    est_rank: jax.Array
    r_lo: jax.Array
    r_up: jax.Array
    R_lo_k: jax.Array
    R_up_k: jax.Array
    guaranteed: jax.Array
    n_accepted: jax.Array
    n_pruned: jax.Array


def kth_smallest(x: jax.Array, k: int) -> jax.Array:
    """k-th smallest value along the last axis (k is 1-indexed, static).

    Shape-polymorphic: (n,) → scalar, (B, n) → (B,) — the batched query
    path reduces every query's bound vector in one call.

    Implemented with jnp.partition rather than top_k on the negation: an
    order STATISTIC needs no indices, and XLA's CPU backend lowers a
    values-only top_k to a full O(n log n) sort (~100× slower at
    (16, 16k)); partition stays O(n) and returns the identical value.
    """
    return jnp.partition(x, k - 1, axis=-1)[..., k - 1]


def partition_sizes(m: int, omega: int) -> tuple[int, ...]:
    """Sizes of the ω norm-descending partitions of P (Alg. 1 line 3).

    Equal sizes when ω | m; otherwise the first (m mod ω) buckets carry one
    extra item so every item is covered exactly once.
    """
    base = m // omega
    extra = m % omega
    return tuple(base + (1 if l < extra else 0) for l in range(omega))
