"""Core data types for the c-approximate reverse k-ranks engine.

All types are JAX pytrees (NamedTuples of arrays) or static dataclass
configs, so they flow through jit / shard_map / checkpointing unchanged.

Precision-polymorphic storage tier (PR 5)
-----------------------------------------
`StorageSpec` governs how the user matrix, thresholds and rank table are
MATERIALIZED — f32 (exact), bf16, or int8 with per-user scales — and the
whole stack consumes it uniformly (`RankTable` carries optional per-row
affine parameters, `StoredUsers` the quantized user rows).

THE BOUND-WIDENING PROOF OBLIGATION. Every quantized read path must
certify, per user u and query q, an interval that CONTAINS the interval
the exact f32 storage would have produced:

    r↓_spec(u, q) ≤ r↓_f32(u, q)   and   r↑_spec(u, q) ≥ r↑_f32(u, q).

Concretely each error source is bracketed and folded in the certified
direction (r↓ rounds DOWN, r↑ rounds UP):

  * quantized user rows — the score error is bounded per row,
    |s_spec − s_f32| ≤ row_slack · ‖q‖₁ (`StoredUsers.row_slack`), and
    the bucketize compares against s ± slack two-sidedly;
  * quantized thresholds — a stored value brackets its f32 original
    (± half a step for int8 codes; bf16 via the monotone cast), so a
    two-sided bucketize yields idx_lo ≤ idx* ≤ idx_hi and the
    non-increasing table turns idx_hi into a sound r↓, idx_lo into a
    sound r↑;
  * quantized table entries — reads widen by the storage error
    (± (½+pad)·scale for int8, ×(1±EPS_BF16) for bf16);
  * quantized delta-score rows — exact counts become certified count
    RANGES (`rank_table._count_above_range`): r↓ shifts by the smallest
    possible net count, r↑ by the largest.

Given containment, §4.3 remains sound at every spec: R↑_k over widened
r↑ upper-bounds the f32 R↑_k, Lemma-1 pruning (r↓ > R↑_k) never discards
a user the exact engine could return, and the block envelopes of
`core.pruning` apply the same widening per tile — so the c-approximation
contract degrades only by the (bounded, measured) widening, never
unsoundly. The f32 spec bypasses every widening branch and traces the
identical XLA program as the pre-spec code: bit-identical results,
asserted against committed goldens in tests/test_storage.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- storage
# bf16 keeps 8 mantissa bits; a round-to-nearest cast is within half an
# ulp, i.e. ~2^-9 relative. 2^-7 over-covers it (including the /(1-eps)
# reciprocal terms), trading a hair of bound tightness for an airtight
# widening at every magnitude.
EPS_BF16 = 2.0 ** -7

# int8 quantized codes live in [-127, 127]; -128 is reserved as the
# "absent" sentinel (delta-score padding) so a clipped integer compare
# against -128 can never count a real entry.
_I8_MAX = 127.0

# Extra widening of int8 block envelopes / comparisons, in quantization
# steps: covers the f32 rounding of the (x - off) / scale transform
# (|s'| <= ~128, ulp ~1e-5) with a wide margin.
_I8_TRANSFORM_PAD = 1e-4


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    """How the storage tier materializes the user matrix, thresholds and
    rank table (the precision-polymorphic storage spec, PR 5).

    kind:
      * "f32"  — exact float32 storage; the default. PROVABLY a no-op:
                 every query path traces the identical XLA program as the
                 pre-spec code, so selected indices are bit-identical.
      * "bf16" — bfloat16 rows everywhere; bounds are certified by
                 monotone-cast two-sided bucketize + EPS_BF16 widening of
                 the table values (see `repro.core.query`).
      * "int8" — int8 rows with PER-USER scales: symmetric per-row scale
                 for the user matrix, per-row affine (scale, offset) for
                 thresholds/table/delta-score rows; bounds are certified
                 by half-step widening in the quantized domain.

    The paper's contract is a c-approximation — it already tolerates
    bounded rank error — so precision is a tunable resource: the certified
    widening folds quantization error into (r↓, r↑) exactly the way
    `pruning.py` folds f32 rounding slack into block envelopes, and
    Lemma-1 selection stays sound at every spec.
    """

    kind: str = "f32"

    _ALIASES = {"f32": "f32", "float32": "f32",
                "bf16": "bf16", "bfloat16": "bf16",
                "int8": "int8"}

    def __post_init__(self):
        if self.kind not in ("f32", "bf16", "int8"):
            raise ValueError(f"unknown StorageSpec kind {self.kind!r}; "
                             "expected one of ('f32', 'bf16', 'int8')")

    @classmethod
    def parse(cls, spec) -> "StorageSpec":
        """Coerce a StorageSpec | name | legacy dtype name ("bfloat16")."""
        if isinstance(spec, StorageSpec):
            return spec
        kind = cls._ALIASES.get(str(spec))
        if kind is None:
            raise ValueError(f"unknown storage spec {spec!r}; expected "
                             f"one of {sorted(set(cls._ALIASES))}")
        return cls(kind=kind)

    @property
    def is_exact(self) -> bool:
        return self.kind == "f32"

    @property
    def table_dtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16,
                "int8": jnp.int8}[self.kind]

    # -------------------------------------------------- materialization
    # THE one code path that turns f32 build outputs into stored arrays —
    # the three pre-PR-5 ad-hoc `astype(storage_dtype)` casts (dense
    # build, sharded build, engine upsert) all collapse into these.
    def pack_table(self, thresholds: jax.Array, table: jax.Array,
                   m=None) -> "RankTable":
        """Materialize f32 (rows, τ) thresholds/table in spec space.

        Works on full matrices and on row blocks (upsert path): the int8
        affine parameters are strictly per-row, so packed rows can be
        scattered into a packed table field-by-field."""
        m = jnp.asarray(0, jnp.int32) if m is None else m
        thresholds = thresholds.astype(jnp.float32)
        table = table.astype(jnp.float32)
        if self.kind == "f32":
            return RankTable(thresholds=thresholds, table=table, m=m)
        if self.kind == "bf16":
            return RankTable(thresholds=thresholds.astype(jnp.bfloat16),
                             table=table.astype(jnp.bfloat16), m=m)
        thr_q, thr_sc, thr_off = _quant_affine_rows(thresholds)
        tab_q, tab_sc, tab_off = _quant_affine_rows(table)
        # Per-row deviation of the TRUE thresholds from the uniform
        # [−127, 127] code grid: Algorithm 1 builds thresholds with
        # `threshold_grid` (uniform), so dev is ~f32-rounding tiny and
        # the query-time bucketize becomes CLOSED FORM — zero gathers,
        # zero threshold-stream reads (`query._lookup_bounds_int8`).
        # Arbitrary (non-uniform) packed thresholds just get a larger
        # dev: the closed form stays certified, only less tight.
        tau = thresholds.shape[1]
        grid = jnp.linspace(-_I8_MAX, _I8_MAX, tau,
                            dtype=jnp.float32)[None, :]
        thr_dev = jnp.max(jnp.abs((thresholds - thr_off) / thr_sc - grid),
                          axis=1, keepdims=True)
        return RankTable(thresholds=thr_q, table=tab_q, m=m,
                         thr_scale=thr_sc, thr_off=thr_off,
                         tab_scale=tab_sc, tab_off=tab_off,
                         thr_dev=thr_dev)

    def pack_users(self, users: jax.Array) -> Optional["StoredUsers"]:
        """Materialize the (n, d) user matrix in spec space; None for the
        exact spec (the raw f32 array IS the storage — backends receive
        it unchanged, keeping the f32 path a bit-identical no-op).

        `row_slack` is the per-row certified score-error coefficient: for
        any query q, |stored-score − f32-score| ≤ row_slack · ‖q‖₁
        (per-coordinate error ≤ scale/2 for int8, ≤ EPS_BF16·‖row‖∞ for
        bf16)."""
        users = users.astype(jnp.float32)
        if self.kind == "f32":
            return None
        if self.kind == "bf16":
            rows = users.astype(jnp.bfloat16)
            slack = EPS_BF16 * jnp.max(
                jnp.abs(rows.astype(jnp.float32)), axis=1, keepdims=True)
            return StoredUsers(rows=rows, scale=None,
                               row_slack=slack + 1e-12)
        scale = jnp.maximum(jnp.max(jnp.abs(users), axis=1, keepdims=True),
                            1e-12) / _I8_MAX
        rows = jnp.clip(jnp.round(users / scale), -_I8_MAX, _I8_MAX
                        ).astype(jnp.int8)
        return StoredUsers(rows=rows, scale=scale, row_slack=0.5 * scale)

    def pack_scores(self, scores: jax.Array, pad: int
                    ) -> tuple[jax.Array, Optional[jax.Array],
                               Optional[jax.Array]]:
        """Materialize per-row ASCENDING delta score sets in spec space,
        left-padding `pad` absent-sentinel columns (−inf; −128 for int8).

        Returns (rows, scale, offset); scale/offset are None except for
        int8. Quantization is per-row monotone, so sortedness survives
        the pack and the query-time count stays one searchsorted."""
        scores = scores.astype(jnp.float32)
        if self.kind == "f32":
            out = scores
            if pad:
                out = jnp.pad(out, ((0, 0), (pad, 0)),
                              constant_values=-jnp.inf)
            return out, None, None
        if self.kind == "bf16":
            out = scores.astype(jnp.bfloat16)
            if pad:
                out = jnp.pad(out, ((0, 0), (pad, 0)),
                              constant_values=-jnp.inf)
            return out, None, None
        q, sc, off = _quant_affine_rows(scores)
        if pad:
            q = jnp.pad(q, ((0, 0), (pad, 0)), constant_values=-128)
        return q, sc, off


def _quant_affine_rows(x: jax.Array) -> tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """Per-row affine int8 quantization: codes in [-127, 127] with
    x ≈ code·scale + offset, |error| ≤ scale/2 (rounding; the range
    endpoints land exactly on ±127 before rounding, so the clip is a
    no-op on real data and only guards f32 edge rounding)."""
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    off = 0.5 * (lo + hi)
    scale = jnp.maximum(hi - lo, 1e-12) / (2.0 * _I8_MAX)
    q = jnp.clip(jnp.round((x - off) / scale), -_I8_MAX, _I8_MAX
                 ).astype(jnp.int8)
    return q, scale, off


class StoredUsers(NamedTuple):
    """Spec-space user matrix (bf16/int8 specs; f32 passes the raw array).

    rows:      (n, d) bf16 or int8 stored rows.
    scale:     (n, 1) f32 per-user symmetric scale — int8 only.
    row_slack: (n, 1) f32 — certified per-row score-error coefficient:
               |score(stored) − score(f32)| ≤ row_slack · ‖q‖₁.
    """

    rows: jax.Array
    scale: Optional[jax.Array]
    row_slack: Optional[jax.Array]

    @property
    def shape(self):
        return self.rows.shape

    def take_rows(self, idx: jax.Array) -> "StoredUsers":
        return StoredUsers(
            rows=self.rows[idx],
            scale=None if self.scale is None else self.scale[idx],
            row_slack=(None if self.row_slack is None
                       else self.row_slack[idx]))


def stored_rows(users) -> jax.Array:
    """The raw row array of either a plain (n, d) array or StoredUsers."""
    return users.rows if isinstance(users, StoredUsers) else users


def take_user_rows(users, idx: jax.Array):
    """Row-gather either user representation (pruned phase-B compaction)."""
    if isinstance(users, StoredUsers):
        return users.take_rows(idx)
    return users[idx]


@dataclasses.dataclass(frozen=True)
class RankTableConfig:
    """Static configuration for Algorithm 1 (pre-processing).

    Attributes:
      tau:   number of inner-product thresholds per user (table columns).
             Paper default 500 (Table 1 tunes 100/500/1000).
      omega: number of norm-stratified partitions of P (Alg. 1 input).
      s:     number of random samples per partition (Alg. 1 input).
      threshold_mode: how f_min/f_max (threshold range per user) is obtained:
        * "sampled"    — min/max of u·p over the stratified sample, widened
                         by `range_pad` of the sampled range. O(ω·s·d)/user,
                         consistent with the paper's O(d) claim for
                         ω,s = O(1); the default.
        * "norm_bound" — ±‖u‖·max‖p‖ (the paper's footnote-1 "domain value"
                         O(1) variant).
        * "exact"      — true f_min/f_max via a full U·Pᵀ pass, O(nmd).
                         Only for small oracle tests.
      range_pad: fractional widening of the sampled threshold range.
      sample_with_replacement: stratified sampling mode; False matches the
        paper ("s random samples in P_l"), True is used when s > |P_l|.
    """

    tau: int = 500
    omega: int = 10
    s: int = 64
    threshold_mode: str = "sampled"
    range_pad: float = 0.05
    sample_with_replacement: bool = False
    # Storage spec for the user matrix + thresholds + table (§Perf H4 /
    # PR 5): "bfloat16"/"bf16" halves, "int8" quarters the dominant HBM
    # stream of the query; the quantization error is folded into the
    # certified (r↓, r↑) bounds (see `StorageSpec`), so the
    # c-approximation contract holds at every setting.
    storage_dtype: str = "float32"

    def __post_init__(self):
        if self.tau < 2:
            raise ValueError(f"tau must be >= 2, got {self.tau}")
        if self.omega < 1:
            raise ValueError(f"omega must be >= 1, got {self.omega}")
        if self.s < 1:
            raise ValueError(f"s must be >= 1, got {self.s}")
        if self.threshold_mode not in ("sampled", "norm_bound", "exact"):
            raise ValueError(f"unknown threshold_mode {self.threshold_mode!r}")
        StorageSpec.parse(self.storage_dtype)   # raises on unknown specs

    @property
    def storage(self) -> StorageSpec:
        """The parsed storage spec (the single source of truth for how
        users/thresholds/table are materialized)."""
        return StorageSpec.parse(self.storage_dtype)


class RankTable(NamedTuple):
    """The paper's rank table T (§4.1) plus its per-user thresholds.

    thresholds: (n, tau) storage dtype, ascending along axis 1 — t_{u_i,j}
                (f32 exact, bf16, or int8 codes under the per-row affine
                (thr_scale, thr_off)).
    table:      (n, tau) storage dtype, non-increasing along axis 1 —
                estimated rank of an item p for u_i when u_i·p = t_{u_i,j}
                (Eq. 1); int8 codes under (tab_scale, tab_off).
    m:          () int32 — |P|, needed for the out-of-range upper bound m+1.
    thr_scale/thr_off/tab_scale/tab_off: (n, 1) f32 per-row affine
                dequantization parameters; present iff the storage spec is
                int8 (None otherwise — the pytree stays shape-compatible
                with pre-spec tables). They row-shard exactly like the
                rows they describe (`core.distributed`).
    """

    thresholds: jax.Array
    table: jax.Array
    m: jax.Array
    thr_scale: Optional[jax.Array] = None
    thr_off: Optional[jax.Array] = None
    tab_scale: Optional[jax.Array] = None
    tab_off: Optional[jax.Array] = None
    # (n, 1) f32, int8 only: max per-row deviation of the true f32
    # thresholds from the uniform [−127, 127] code grid, in code units —
    # certifies the closed-form bucketize (see pack_table).
    thr_dev: Optional[jax.Array] = None

    @property
    def n(self) -> int:
        return self.thresholds.shape[0]

    @property
    def tau(self) -> int:
        return self.thresholds.shape[1]

    @property
    def spec_kind(self) -> str:
        """The storage kind this table is materialized in — derived from
        the arrays themselves so query code needs no side-channel."""
        if self.thr_scale is not None:
            return "int8"
        if self.thresholds.dtype == jnp.bfloat16:
            return "bf16"
        return "f32"

    _QUANT_FIELDS = ("thr_scale", "thr_off", "tab_scale", "tab_off",
                     "thr_dev")

    def take_rows(self, idx: jax.Array) -> "RankTable":
        """Row-gather every row-aligned field (pruned phase-B compaction,
        upsert row updates) — scale vectors travel with their rows."""
        g = lambda a: None if a is None else a[idx]
        return RankTable(thresholds=self.thresholds[idx],
                         table=self.table[idx], m=self.m,
                         **{f: g(getattr(self, f))
                            for f in self._QUANT_FIELDS})

    def set_rows(self, idx: jax.Array, rows: "RankTable") -> "RankTable":
        """Scatter packed row blocks (from `StorageSpec.pack_table`) into
        this table — the upsert path; per-row quantization parameters make
        the row update local."""
        s = lambda a, b: None if a is None else a.at[idx].set(b)
        return RankTable(
            thresholds=self.thresholds.at[idx].set(
                rows.thresholds.astype(self.thresholds.dtype)),
            table=self.table.at[idx].set(rows.table.astype(self.table.dtype)),
            m=self.m,
            **{f: s(getattr(self, f), getattr(rows, f))
               for f in self._QUANT_FIELDS})

    def append_rows(self, rows: "RankTable") -> "RankTable":
        """Concatenate packed row blocks (user-append upserts)."""
        c = lambda a, b: None if a is None else jnp.concatenate([a, b])
        return RankTable(
            thresholds=jnp.concatenate(
                [self.thresholds, rows.thresholds.astype(
                    self.thresholds.dtype)]),
            table=jnp.concatenate(
                [self.table, rows.table.astype(self.table.dtype)]),
            m=self.m,
            **{f: c(getattr(self, f), getattr(rows, f))
               for f in self._QUANT_FIELDS})


class DeltaCorrection(NamedTuple):
    """Query-time correction for a mutated index (see `repro.index`).

    The rank table is built over a frozen base item set P₀ and user set U₀;
    streaming mutations are absorbed by a delta buffer and FUSED into the
    estimated rank at query time as a bounded additive correction:

        r(q, u, P') = r(q, u, P₀) + #{a ∈ A : u·a > u·q}
                                  − #{p ∈ D : u·p > u·q}

    for P' = (P₀ \\ D) ∪ A. Both correction terms are computed EXACTLY
    from per-user scores against the (small) delta item sets, so the
    Eq. (1) estimator's error is untouched by the shift — the only delta
    degradation is the stale sampling noise of tombstoned sample
    positions, which the maintenance policy budgets (`repro.index.delta`).

    All fields are device arrays (the tuple is a pytree and flows through
    jit / shard_map); the per-row score sets are pre-sorted so the query-
    time count is one vmapped searchsorted — O(B·log|delta|) per user row
    on top of the static path.

    add_scores: (n, n_add) ascending per row — u_i·a for every live
                inserted item a ∈ A, stored in SPEC SPACE (f32 exact,
                bf16, or int8 codes under (add_scale, add_off); left-
                padded with the absent sentinel −inf / −128). Quantized
                sets yield certified COUNT RANGES instead of exact
                counts; `rank_table.apply_delta_corrections` widens
                (r↓, r↑) by them so the bounds stay certified.
    del_scores: (n, n_del) ascending per row — u_i·p for every
                tombstoned base item p ∈ D (same storage).
    user_live:  (n,) bool — False rows are deleted users; their bounds are
                forced past every admissible selection key.
    m_new:      () int32 — |P'| = |P₀| − |D| + |A|, the live item count
                (replaces `RankTable.m` in the selection).
    add_scale/add_off/del_scale/del_off: (n, 1) f32 per-row affine
                dequantization parameters, present iff the spec is int8.
    """

    add_scores: jax.Array
    del_scores: jax.Array
    user_live: jax.Array
    m_new: jax.Array
    add_scale: Optional[jax.Array] = None
    add_off: Optional[jax.Array] = None
    del_scale: Optional[jax.Array] = None
    del_off: Optional[jax.Array] = None

    @property
    def n_add(self) -> int:
        return self.add_scores.shape[1]

    @property
    def n_del(self) -> int:
        return self.del_scores.shape[1]

    def take_rows(self, idx: jax.Array) -> "DeltaCorrection":
        """Row-gather the per-user fields (pruned phase-B compaction,
        sharded per-shard sub-corrections)."""
        g = lambda a: None if a is None else a[idx]
        return DeltaCorrection(
            add_scores=self.add_scores[idx], del_scores=self.del_scores[idx],
            user_live=self.user_live[idx], m_new=self.m_new,
            add_scale=g(self.add_scale), add_off=g(self.add_off),
            del_scale=g(self.del_scale), del_off=g(self.del_off))

    def selection_m(self) -> jax.Array:
        """The `m_items` to pass into the §4.3 composite selection key on
        the delta path (see `query.lemma1_key`): the class-separation
        offset must dominate the SHIFTED estimate range
        [1 − n_del, m_base + 1 + n_add], whose width is
        m_new + 2·n_del ≥ width for the padded column counts — the plain
        live count m' is not enough once deletions widen the range
        downward. Every backend derives it from this one method, so the
        key stays identical across dense/fused/sharded."""
        return self.m_new + 2 * self.n_del


class QueryResult(NamedTuple):
    """Output of one c-approximate reverse k-ranks query (§4.3).

    indices:   (k,) int32 — selected user indices (U_c), best-first.
    est_rank:  (k,) float32 — interpolated rank estimates for the selection.
    r_lo:      (n,) float32 — per-user lower-bound rank r↓.
    r_up:      (n,) float32 — per-user upper-bound rank r↑.
    R_lo_k:    () float32 — k-th smallest lower bound (R↓_k).
    R_up_k:    () float32 — k-th smallest upper bound (R↑_k).
    guaranteed:() bool    — Lemma-1 case: c·R↓_k ≥ R↑_k (search closed in
                step 2; no interpolation fill needed).
    n_accepted:() int32   — #users with r↑ ≤ c·R↓_k (Lemma 1 (1)).
    n_pruned:  () int32   — #users with r↓ > R↑_k  (Lemma 1 (2)).
    """

    indices: jax.Array
    est_rank: jax.Array
    r_lo: jax.Array
    r_up: jax.Array
    R_lo_k: jax.Array
    R_up_k: jax.Array
    guaranteed: jax.Array
    n_accepted: jax.Array
    n_pruned: jax.Array


def kth_smallest(x: jax.Array, k: int) -> jax.Array:
    """k-th smallest value along the last axis (k is 1-indexed, static).

    Shape-polymorphic: (n,) → scalar, (B, n) → (B,) — the batched query
    path reduces every query's bound vector in one call.

    Implemented with jnp.partition rather than top_k on the negation: an
    order STATISTIC needs no indices, and XLA's CPU backend lowers a
    values-only top_k to a full O(n log n) sort (~100× slower at
    (16, 16k)); partition stays O(n) and returns the identical value.
    """
    return jnp.partition(x, k - 1, axis=-1)[..., k - 1]


def partition_sizes(m: int, omega: int) -> tuple[int, ...]:
    """Sizes of the ω norm-descending partitions of P (Alg. 1 line 3).

    Equal sizes when ω | m; otherwise the first (m mod ω) buckets carry one
    extra item so every item is covered exactly once.
    """
    base = m // omega
    extra = m % omega
    return tuple(base + (1 if l < extra else 0) for l in range(omega))
