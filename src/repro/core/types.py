"""Core data types for the c-approximate reverse k-ranks engine.

All types are JAX pytrees (NamedTuples of arrays) or static dataclass
configs, so they flow through jit / shard_map / checkpointing unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RankTableConfig:
    """Static configuration for Algorithm 1 (pre-processing).

    Attributes:
      tau:   number of inner-product thresholds per user (table columns).
             Paper default 500 (Table 1 tunes 100/500/1000).
      omega: number of norm-stratified partitions of P (Alg. 1 input).
      s:     number of random samples per partition (Alg. 1 input).
      threshold_mode: how f_min/f_max (threshold range per user) is obtained:
        * "sampled"    — min/max of u·p over the stratified sample, widened
                         by `range_pad` of the sampled range. O(ω·s·d)/user,
                         consistent with the paper's O(d) claim for
                         ω,s = O(1); the default.
        * "norm_bound" — ±‖u‖·max‖p‖ (the paper's footnote-1 "domain value"
                         O(1) variant).
        * "exact"      — true f_min/f_max via a full U·Pᵀ pass, O(nmd).
                         Only for small oracle tests.
      range_pad: fractional widening of the sampled threshold range.
      sample_with_replacement: stratified sampling mode; False matches the
        paper ("s random samples in P_l"), True is used when s > |P_l|.
    """

    tau: int = 500
    omega: int = 10
    s: int = 64
    threshold_mode: str = "sampled"
    range_pad: float = 0.05
    sample_with_replacement: bool = False
    # Storage dtype for thresholds+table (§Perf H4): "bfloat16" halves the
    # dominant HBM stream of the query at a bounded rank-quantization cost
    # (≤ 2^-8 relative — smaller than Eq. 1's sampling noise at s = 64).
    storage_dtype: str = "float32"

    def __post_init__(self):
        if self.tau < 2:
            raise ValueError(f"tau must be >= 2, got {self.tau}")
        if self.omega < 1:
            raise ValueError(f"omega must be >= 1, got {self.omega}")
        if self.s < 1:
            raise ValueError(f"s must be >= 1, got {self.s}")
        if self.threshold_mode not in ("sampled", "norm_bound", "exact"):
            raise ValueError(f"unknown threshold_mode {self.threshold_mode!r}")
        if self.storage_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"unknown storage_dtype {self.storage_dtype!r}")


class RankTable(NamedTuple):
    """The paper's rank table T (§4.1) plus its per-user thresholds.

    thresholds: (n, tau) float32, ascending along axis 1 — t_{u_i, j}.
    table:      (n, tau) float32, non-increasing along axis 1 — estimated
                rank of an item p for u_i when u_i·p = t_{u_i,j}  (Eq. 1).
    m:          () int32 — |P|, needed for the out-of-range upper bound m+1.
    """

    thresholds: jax.Array
    table: jax.Array
    m: jax.Array

    @property
    def n(self) -> int:
        return self.thresholds.shape[0]

    @property
    def tau(self) -> int:
        return self.thresholds.shape[1]


class QueryResult(NamedTuple):
    """Output of one c-approximate reverse k-ranks query (§4.3).

    indices:   (k,) int32 — selected user indices (U_c), best-first.
    est_rank:  (k,) float32 — interpolated rank estimates for the selection.
    r_lo:      (n,) float32 — per-user lower-bound rank r↓.
    r_up:      (n,) float32 — per-user upper-bound rank r↑.
    R_lo_k:    () float32 — k-th smallest lower bound (R↓_k).
    R_up_k:    () float32 — k-th smallest upper bound (R↑_k).
    guaranteed:() bool    — Lemma-1 case: c·R↓_k ≥ R↑_k (search closed in
                step 2; no interpolation fill needed).
    n_accepted:() int32   — #users with r↑ ≤ c·R↓_k (Lemma 1 (1)).
    n_pruned:  () int32   — #users with r↓ > R↑_k  (Lemma 1 (2)).
    """

    indices: jax.Array
    est_rank: jax.Array
    r_lo: jax.Array
    r_up: jax.Array
    R_lo_k: jax.Array
    R_up_k: jax.Array
    guaranteed: jax.Array
    n_accepted: jax.Array
    n_pruned: jax.Array


def kth_smallest(x: jax.Array, k: int) -> jax.Array:
    """k-th smallest value along the last axis (k is 1-indexed, static).

    Shape-polymorphic: (n,) → scalar, (B, n) → (B,) — the batched query
    path reduces every query's bound vector in one call.

    Implemented with jnp.partition rather than top_k on the negation: an
    order STATISTIC needs no indices, and XLA's CPU backend lowers a
    values-only top_k to a full O(n log n) sort (~100× slower at
    (16, 16k)); partition stays O(n) and returns the identical value.
    """
    return jnp.partition(x, k - 1, axis=-1)[..., k - 1]


def partition_sizes(m: int, omega: int) -> tuple[int, ...]:
    """Sizes of the ω norm-descending partitions of P (Alg. 1 line 3).

    Equal sizes when ω | m; otherwise the first (m mod ω) buckets carry one
    extra item so every item is covered exactly once.
    """
    base = m // omega
    extra = m % omega
    return tuple(base + (1 if l < extra else 0) for l in range(omega))
