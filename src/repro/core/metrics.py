"""Evaluation criteria from §5 of the paper: accuracy and overall ratio.

Both pair the i-th returned user (by true rank) with the i-th exact-answer
user, per Definition 3 ("Let u and u' be the i-th user in U_c and U_rr").
"""
from __future__ import annotations

import numpy as np


def _paired_true_ranks(result_idx: np.ndarray, exact_idx: np.ndarray,
                       true_ranks: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Sort both result sets by true rank and pair position-wise."""
    ours = np.sort(true_ranks[np.asarray(result_idx)])
    exact = np.sort(true_ranks[np.asarray(exact_idx)])
    return ours.astype(np.float64), exact.astype(np.float64)


def accuracy(result_idx: np.ndarray, exact_idx: np.ndarray,
             true_ranks: np.ndarray, c: float) -> float:
    """Accuracy = (1/k) Σ_i  I[ r(q,u_i,P) ≤ c · r(q,u'_i,P) ]   (§5)."""
    ours, exact = _paired_true_ranks(result_idx, exact_idx, true_ranks)
    return float(np.mean(ours <= c * exact))


def overall_ratio(result_idx: np.ndarray, exact_idx: np.ndarray,
                  true_ranks: np.ndarray) -> float:
    """Overall ratio = (1/k) Σ_i  r(q,u_i,P) / r(q,u'_i,P)   (§5). ≥ 1."""
    ours, exact = _paired_true_ranks(result_idx, exact_idx, true_ranks)
    return float(np.mean(ours / np.maximum(exact, 1.0)))
