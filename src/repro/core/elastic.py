"""Compile-once elastic serving: a scan-over-tiles query program (PR 7).

Every other backend's query program is shaped by n, the user count — so
every insert-triggered rebuild, compaction, or tenant growth that changes
n retraces and recompiles a fresh XLA program per backend (a recompile
storm on every hot-swap, exactly what a live promotion-monitoring fleet
cannot tolerate). This module restructures the phase-B scan as a
`lax.fori_loop` over FIXED-SIZE user tiles against CAPACITY-PADDED
operands, so one compiled program serves any n:

  * operands (users / rank table / delta correction) are padded host-side
    (numpy — zero per-(n, cap) XLA pad programs) to a power-of-two tile
    capacity `capacity_for(n, tile)`; growing n re-pads inside the same
    bucket without touching the compiled program, and doubles the bucket
    O(log n) times over a fleet's lifetime;
  * the traced program takes the VALID ROW COUNT as a runtime scalar: a
    fori_loop with a data-dependent trip count ⌈n_valid/tile⌉ runs the
    §4.3 step-1 tile unit (`query.tile_bounds`, or the tile-shaped Pallas
    call `kernels.ops.bound_ranks_tile` for the fused inner) and writes
    each (tile, B) result into a (cap, B) buffer; rows ≥ n_valid are
    masked to a DOMINATED SENTINEL after the loop;
  * §4.3 steps 2-3 run unchanged over the (B, cap) bounds; the sentinel
    is constructed to be invisible to them (proof below), and the two
    Lemma-1 population counters are corrected for the pad rows.

This is the haliax-`Stacked` / torch_xla-`apply_layers` idiom applied to
the user axis: compile one tile's computation, reuse it across all
homogeneous tiles. The compile key of the one program is
(tile, d, B, τ, storage spec, k, capacity bucket) — never n.

Sentinel soundness (bit-identical selection, asserted in
tests/test_elastic.py):

  static path   S = m + 2 (f32). Real bounds and estimates all lie in
  [.., m+1], so for k ≤ n every order statistic R↓_k/R↑_k over the padded
  axis equals the unpadded one. Selection keys: in the guaranteed case
  the sentinel's key is its est = m+2 > any real est; in the
  non-guaranteed case the sentinel is accepted only when c·R↓_k ≥ m+2 —
  but then EVERY real user is accepted too (r↑ ≤ m+1) with key est ≤
  m+1 < m+2; otherwise S > R↑_k always holds (R↑_k ≤ m+1), the sentinel
  is pruned with key 2·big + S, strictly above every real key of any
  class. Pad rows therefore never enter the top-k for k ≤ n, and real
  rows keep their indices and tie-breaks.

  delta path    S = +inf — the one unconditionally dominated value under
  `apply_delta_corrections`' dead-user convention (deleted users are
  forced to +inf; at equal +inf keys top_k breaks ties toward the LOWER
  index, so real dead rows still win over pads). Pad correction rows
  carry user_live=False and absent-sentinel score sets, so the
  correction arithmetic never produces non-finite intermediates.

  The two population counters do see the pads: n_accepted over-counts by
  pad·[S ≤ c·R↓_k] and n_pruned by pad·[S > R↑_k]; both are subtracted
  inside the same program. (With S = +inf the two indicators also
  reproduce the dead-row accounting of the unpadded delta program —
  see tests.)

Usage — a wrapper backend, composed by name like the others::

    eng = ReverseKRanksEngine.build(..., backend="elastic:dense")
    eng = ReverseKRanksEngine.build(..., backend="elastic:fused")

(There is no bare "elastic" spec: the wrapper needs an inner backend to
name the tile unit. "elastic:" defaults the inner to dense.) Stock dense
and fused inners get the elastic program; any other inner — sharded
(collectives are built per n inside shard_map), pruned (host-side keep
lists), or a user subclass — delegates unchanged, documented rather than
silently reinterpreted.

The tile size is the `REPRO_ELASTIC_TILE` env knob (default 256, must be
a multiple of 32 so one tile satisfies every TPU min-tile: f32 (8, 128),
bf16 (16, 128), int8 (32, 128)). On CPU the fused inner runs the Pallas
tile in interpret mode (`REPRO_INTERPRET`, see `kernels.ops`); interpret
kernels trace into the fori_loop body like any jnp code, so the
compile-once property holds in both modes and TPU validation needs no
source edit.

`compiled_program_count()` is the serving-side observability hook: a
monotone count of compiled programs across the query stack's jit entry
points, sampled by the scheduler around every tick
(`TickStats.compiles`) and asserted flat across an n-sweep in tier-1.
"""
from __future__ import annotations

import functools
import os
import sys
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import importlib

from repro.core import backends as BK
from repro.core.types import DeltaCorrection, QueryResult, RankTable, \
    StoredUsers, stored_rows
from repro.kernels import ops as kops
from repro.obs import registry as obs
from repro.obs import trace

# `repro.core.__init__` re-exports the `query` FUNCTION under the package
# attribute `query`, shadowing the submodule for late importers like this
# one — resolve the module through sys.modules instead.
query_mod = importlib.import_module("repro.core.query")

# Traces of the elastic program observed this process — the tentpole's
# acceptance counter. Incremented at TRACE time (the Python body runs
# once per compile, not per call), so an n-sweep that stays inside one
# capacity bucket must leave it unchanged.
_TRACE_EVENTS = 0


def default_tile() -> int:
    """The elastic tile size: `REPRO_ELASTIC_TILE` env (default 256).

    Must be a multiple of 32 (one tile then satisfies the TPU min-tile
    of every storage dtype — f32 (8, 128), bf16 (16, 128), int8
    (32, 128) — so the same knob value validates on hardware with
    REPRO_INTERPRET=0)."""
    raw = os.environ.get("REPRO_ELASTIC_TILE", "").strip()
    tile = int(raw) if raw else 256
    if tile < 32 or tile % 32:
        raise ValueError(
            f"REPRO_ELASTIC_TILE must be a positive multiple of 32 "
            f"(TPU min-tile alignment for f32/bf16/int8); got {tile}")
    return tile


def capacity_for(n: int, tile: int) -> int:
    """Row capacity serving n users: tile · next_pow2(⌈n/tile⌉).

    Power-of-two bucketing bounds the lifetime compile count at O(log n)
    while wasting at most half the capacity; every n in (cap/2, cap]
    shares one padded shape and hence one compiled program."""
    n_tiles = max(1, -(-int(n) // tile))
    return tile * (1 << (n_tiles - 1).bit_length())


# ------------------------------------------------------- host-side padding
def _np_pad_rows(x, cap: int, value):
    """Pad axis 0 to `cap` rows with `value`, in HOST numpy: repadding on
    a hot-swap must compile ZERO XLA programs (a jnp.pad would lower one
    tiny program per (n, cap) pair — the storm in miniature)."""
    if x is None or x.shape[0] == cap:
        return x
    a = np.asarray(jax.device_get(x))
    out = np.full((cap,) + a.shape[1:], value, dtype=a.dtype)
    out[: a.shape[0]] = a
    return jnp.asarray(out)


def _pad_users(users, cap: int):
    """Capacity-pad either user representation. Pad rows are all-zero
    with identity scale and zero slack (the quantized kernels' junk-row
    soundness values, cf. `ops._pad_quant_operands`): their scores are
    exactly 0 and every lookup on them is finite."""
    if isinstance(users, StoredUsers):
        return StoredUsers(
            rows=_np_pad_rows(users.rows, cap, 0),
            scale=_np_pad_rows(users.scale, cap, 1.0),
            row_slack=_np_pad_rows(users.row_slack, cap, 0.0))
    return _np_pad_rows(users, cap, 0.0)


def _pad_table(rt: RankTable, cap: int) -> RankTable:
    """Capacity-pad every row-aligned rank-table field. Values follow the
    kernel-padding conventions: thresholds 0 (constant row — trivially
    ascending), table 1.0 (int8: code 0 under identity affine → 0.0),
    scales 1.0, offsets/dev 0.0. Pad-row lookups are finite junk,
    overwritten by the sentinel mask."""
    pad_vals = {"thr_scale": 1.0, "thr_off": 0.0, "tab_scale": 1.0,
                "tab_off": 0.0, "thr_dev": 0.0}
    tab_pad = 0 if rt.table.dtype == jnp.int8 else 1.0
    return RankTable(
        thresholds=_np_pad_rows(rt.thresholds, cap, 0),
        table=_np_pad_rows(rt.table, cap, tab_pad), m=rt.m,
        **{f: _np_pad_rows(getattr(rt, f), cap, pad_vals[f])
           for f in RankTable._QUANT_FIELDS})


def _pad_corr(corr: DeltaCorrection, cap: int) -> DeltaCorrection:
    """Capacity-pad the delta correction. Pad rows are DEAD USERS
    (user_live=False → `apply_delta_corrections` forces their bounds to
    the +inf sentinel) with absent-sentinel score sets (−inf; −128 for
    int8 codes), so the count/shift arithmetic sees zero delta items and
    stays finite on them."""
    absent = lambda a: -128 if a.dtype == jnp.int8 else -np.inf
    return DeltaCorrection(
        add_scores=_np_pad_rows(corr.add_scores, cap,
                                absent(corr.add_scores)),
        del_scores=_np_pad_rows(corr.del_scores, cap,
                                absent(corr.del_scores)),
        user_live=_np_pad_rows(corr.user_live, cap, False),
        m_new=corr.m_new,
        add_scale=_np_pad_rows(corr.add_scale, cap, 1.0),
        add_off=_np_pad_rows(corr.add_off, cap, 0.0),
        del_scale=_np_pad_rows(corr.del_scale, cap, 1.0),
        del_off=_np_pad_rows(corr.del_off, cap, 0.0))


# ------------------------------------------------------------ tile slicing
def _dyn_rows(a, start, size: int):
    return (None if a is None
            else jax.lax.dynamic_slice_in_dim(a, start, size, axis=0))


def _slice_users(users, start, size: int):
    if isinstance(users, StoredUsers):
        return StoredUsers(rows=_dyn_rows(users.rows, start, size),
                           scale=_dyn_rows(users.scale, start, size),
                           row_slack=_dyn_rows(users.row_slack, start, size))
    return _dyn_rows(users, start, size)


def _slice_table(rt: RankTable, start, size: int) -> RankTable:
    return RankTable(
        thresholds=_dyn_rows(rt.thresholds, start, size),
        table=_dyn_rows(rt.table, start, size), m=rt.m,
        **{f: _dyn_rows(getattr(rt, f), start, size)
           for f in RankTable._QUANT_FIELDS})


def _slice_corr(corr: DeltaCorrection, start, size: int) -> DeltaCorrection:
    return DeltaCorrection(
        add_scores=_dyn_rows(corr.add_scores, start, size),
        del_scores=_dyn_rows(corr.del_scores, start, size),
        user_live=_dyn_rows(corr.user_live, start, size),
        m_new=corr.m_new,
        add_scale=_dyn_rows(corr.add_scale, start, size),
        add_off=_dyn_rows(corr.add_off, start, size),
        del_scale=_dyn_rows(corr.del_scale, start, size),
        del_off=_dyn_rows(corr.del_off, start, size))


# ------------------------------------------------------- the ONE program
_STATIC_ARGS = ("tile", "use_kernel", "m_kernel", "k")


def _elastic_query_impl(rt: RankTable, users, qs: jax.Array,
                        n_valid: jax.Array,
                        corr: Optional[DeltaCorrection], c: jax.Array, *,
                        tile: int, use_kernel: bool, m_kernel: int, k: int
                        ) -> QueryResult:
    """The compile-once program: fori_loop over tiles → sentinel mask →
    shared §4.3 selection → pad-count correction. ONE jit region — unlike
    the delta path's deliberate two-region split (`query_batch_delta`),
    the fori_loop materializes its (cap, B) carry as a while-op output
    XLA cannot re-fuse into the selection's consumers, so the region
    break buys nothing here.

    Operands are capacity-padded; `n_valid` is the runtime valid-row
    count, the ONLY place n enters — never a shape. `m_kernel` is the
    static item count the Pallas tile call needs (the kernel wrappers
    take m statically, exactly like the existing fused path); the dense
    tile unit reads the traced `rt.m` instead, so pass −1 there and item
    churn cannot retrace it.
    """
    global _TRACE_EVENTS
    _TRACE_EVENTS += 1                  # trace-time: once per compile
    cap = stored_rows(users).shape[0]
    B = qs.shape[0]
    is_delta = corr is not None
    sentinel = (jnp.float32(jnp.inf) if is_delta
                else (rt.m + 2).astype(jnp.float32))
    init = tuple(jnp.full((cap, B), sentinel, jnp.float32)
                 for _ in range(3))
    n_tiles = (n_valid + tile - 1) // tile      # data-dependent trip count

    def body(t, bufs):
        start = t * tile
        users_t = _slice_users(users, start, tile)
        rt_t = _slice_table(rt, start, tile)
        corr_t = _slice_corr(corr, start, tile) if is_delta else None
        if use_kernel:
            r_lo, r_up, est = kops.bound_ranks_tile(users_t, qs, rt_t,
                                                    m=m_kernel,
                                                    block_n=tile)
            if is_delta:
                from repro.core import rank_table as rt_mod
                scores, slack = query_mod.user_scores_batch(users_t, qs)
                r_lo, r_up, est = rt_mod.apply_delta_corrections(
                    scores, r_lo, r_up, est, corr_t, slack=slack)
        else:
            r_lo, r_up, est = query_mod.tile_bounds(rt_t, users_t, qs,
                                                    corr_t)
        return tuple(
            jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(jnp.float32), start, axis=0)
            for buf, val in zip(bufs, (r_lo, r_up, est)))

    r_lo, r_up, est = jax.lax.fori_loop(0, n_tiles, body, init)
    live = jnp.arange(cap, dtype=jnp.int32)[:, None] < n_valid
    r_lo = jnp.where(live, r_lo, sentinel)
    r_up = jnp.where(live, r_up, sentinel)
    est = jnp.where(live, est, sentinel)
    m_items = corr.selection_m() if is_delta else rt.m
    res = query_mod.select_topk(r_lo.T, r_up.T, est.T, k=k, c=c,
                                m_items=m_items)
    # the two Lemma-1 population counters are the only fields that SEE
    # the pad rows; subtract exactly the pads' contribution (module doc)
    pad = (cap - n_valid).astype(jnp.int32)
    over_acc = pad * (sentinel <= c * res.R_lo_k).astype(jnp.int32)
    over_prn = pad * (sentinel > res.R_up_k).astype(jnp.int32)
    return res._replace(n_accepted=res.n_accepted - over_acc,
                        n_pruned=res.n_pruned - over_prn)


_elastic_query = jax.jit(_elastic_query_impl, static_argnames=_STATIC_ARGS)


def _serve_donate_args() -> tuple:
    """Buffer donation for the SERVING entry: the scheduler's per-tick
    query block is staged into a fresh device buffer each tick and never
    read after dispatch, so on accelerators XLA may reuse its memory for
    outputs. On CPU donation is a no-op that warns per call — alias the
    plain entry instead (same jit object: zero extra compiles)."""
    try:
        if jax.default_backend() in ("gpu", "cuda", "rocm", "tpu"):
            return ("qs",)
    except Exception:  # pragma: no cover - backend probe must never fail
        pass
    return ()


_SERVE_DONATE = _serve_donate_args()
_elastic_query_serve = (
    jax.jit(_elastic_query_impl, static_argnames=_STATIC_ARGS,
            donate_argnames=_SERVE_DONATE)
    if _SERVE_DONATE else _elastic_query)


# -------------------------------------------------------- observability
def elastic_trace_count() -> int:
    """Traces of the elastic program so far (monotone; one per
    (tile, B, k, spec, capacity-bucket) combination ever served)."""
    return _TRACE_EVENTS


# Modules whose jit entry points constitute the query stack; only
# already-imported ones are counted (sys.modules — counting must never
# import pieces of the stack the process isn't using).
_COUNTED_MODULES = ("repro.core.query", "repro.core.rank_table",
                    "repro.core.pruning", "repro.kernels.ops",
                    "repro.core.elastic")


# Memoized scan of the counted modules' jit entry points. The scheduler
# brackets EVERY tick with compiled_program_count(); rebuilding the
# callable list by walking vars() of five modules per call was measurable
# at small tick sizes. The key detects both late imports (a counted
# module appearing in sys.modules) and late jit definitions (a module
# growing attributes); jit objects themselves are stable across calls.
_JIT_SCAN_KEY: Optional[tuple] = None
_JIT_SCAN: tuple = ()


def _jit_entries() -> tuple:
    global _JIT_SCAN_KEY, _JIT_SCAN
    key = tuple((name, id(mod), len(vars(mod)))
                for name in _COUNTED_MODULES
                if (mod := sys.modules.get(name)) is not None)
    if key == _JIT_SCAN_KEY:
        return _JIT_SCAN
    seen: set = set()
    entries = []
    for name in _COUNTED_MODULES:
        mod = sys.modules.get(name)
        if mod is None:
            continue
        for obj in vars(mod).values():
            size_fn = getattr(obj, "_cache_size", None)
            if callable(size_fn) and id(obj) not in seen:
                seen.add(id(obj))
                entries.append(size_fn)
    _JIT_SCAN = tuple(entries)
    _JIT_SCAN_KEY = key
    return _JIT_SCAN


def compiled_program_count() -> int:
    """Total compiled-program count across the query stack's jit caches.

    Sums `_cache_size()` over every jit-wrapped callable in the counted
    modules (deduped by identity — re-exports must not double-count; the
    module scan itself is memoized, see `_jit_entries`). Monotone in
    practice (jit caches only grow), so a DELTA across a serving interval
    is "programs compiled during it": the scheduler samples it around
    each tick (`TickStats.compiles`) and the tier-1 n-sweep asserts the
    delta is zero after the elastic warm-up. Also exported as the
    callback gauge `query_compiled_programs` (read at scrape time)."""
    total = 0
    for size_fn in _jit_entries():
        try:
            total += int(size_fn())
        except Exception:
            pass
    return total


# scrape-time callback gauge: dashboards watch the derivative — a nonzero
# slope in steady state is the recompile-storm signature
obs.get_default().gauge(
    "query_compiled_programs",
    "compiled XLA programs across the query stack's jit caches"
).set_function(compiled_program_count)


# ------------------------------------------------------------ the backend
class ElasticBackend(BK.QueryBackend):
    """Wrapper backend: compile-once elastic serving over a stock dense
    or fused inner; any other inner delegates unchanged (module doc).

    The padded-operand cache is keyed on ARRAY IDENTITY per index
    generation (same contract as `PrunedBackend._summaries` /
    `serve.cache`): snapshot generations are immutable, so identity
    equality is epoch equality, and the cached value holds strong
    references to the keyed arrays so an id() can never be recycled
    while its entry lives. A hot-swap that changes any operand repads
    host-side (numpy) and re-dispatches the SAME compiled program.
    """

    _PAD_CACHE = 4              # index generations kept padded

    def __init__(self, inner="dense", *, mesh=None,
                 tile: Optional[int] = None):
        super().__init__(mesh=mesh)
        self.inner = BK.get_backend(inner or "dense", mesh=mesh)
        self.name = f"elastic:{self.inner.name}"
        self.tile = int(tile) if tile else default_tile()
        if self.tile < 32 or self.tile % 32:
            raise ValueError(f"elastic tile must be a positive multiple "
                             f"of 32; got {self.tile}")
        if (type(self.inner) is BK.DenseBackend
                and BK._stock_pipeline(self.inner, BK.DenseBackend)):
            self._mode = "dense"
        elif (type(self.inner) is BK.FusedBackend
                and BK._stock_pipeline(self.inner, BK.FusedBackend)):
            self._mode = "fused"
        else:
            # sharded (per-n shard_map programs), pruned (host-side keep
            # lists), or subclassed hooks: delegate rather than silently
            # reinterpret — their elasticization is tracked on the ROADMAP
            self._mode = None
        self._padded: "OrderedDict[tuple, tuple]" = OrderedDict()

    # ----------------------------------------------------------- plumbing
    def bound_ranks(self, rt, users, qs):
        """Full (B, n) bounds are a debugging surface (cf. pruned/cached
        wrappers); the elastic program applies to the end-to-end query."""
        return self.inner.bound_ranks(rt, users, qs)

    def build_index(self, users, items, cfg, key):
        return self.inner.build_index(users, items, cfg, key)

    def check_users_shape(self, n):
        return self.inner.check_users_shape(n)

    def degrade(self, level):
        """Ladder levels act on the wrapped execution backend."""
        super().degrade(level)
        self.inner.degrade(level)

    def _padded_operands(self, rt, users, corr):
        n = users.shape[0]
        cap = capacity_for(n, self.tile)
        key = (id(stored_rows(users)), id(rt.thresholds), id(rt.table),
               cap)
        if corr is not None:
            key += (id(corr.add_scores), id(corr.del_scores),
                    id(corr.user_live))
        hit = self._padded.get(key)
        if hit is not None:
            self._padded.move_to_end(key)
            return hit[1]
        with trace.span("elastic.repad", n=n, cap=cap):
            value = (_pad_table(rt, cap), _pad_users(users, cap),
                     None if corr is None else _pad_corr(corr, cap))
        obs.get_default().counter(
            "elastic_repads_total",
            "host-side capacity repads (one per new index generation)"
        ).inc()
        # pin the keyed arrays: their id()s must not be recycled while
        # this entry can be returned for them
        self._padded[key] = ((users, rt, corr), value)
        while len(self._padded) > self._PAD_CACHE:
            self._padded.popitem(last=False)
        return value

    # -------------------------------------------------------------- query
    def _query_via(self, program, rt, users, qs, *, k, c, delta):
        """Shared dispatch body for `query_batch` (plain jit entry) and
        `dispatch_device` (donating serve entry): padded operands → the
        compile-once program → eager slice epilogue."""
        n = users.shape[0]
        rt_p, users_p, corr_p = self._padded_operands(rt, users, delta)
        m_kernel = int(rt.m) if self._mode == "fused" else -1
        with trace.span("elastic.dispatch", n=n, batch=qs.shape[0], k=k):
            res = program(
                rt_p, users_p, qs, jnp.asarray(n, jnp.int32), corr_p,
                jnp.float32(c), tile=self.tile,
                use_kernel=self._mode == "fused", m_kernel=m_kernel,
                k=int(k))
        if res.r_lo.shape[1] == n:
            return res
        # Restore the documented (B, n) shape of the two per-user fields.
        # Deliberately OUTSIDE the jit: an eager op-by-op slice is a
        # trivial epilogue (XLA caches it per shape in microseconds), not
        # a retrace of the query program — folding it in would key the
        # one compiled program on n and undo the whole point.
        return res._replace(r_lo=res.r_lo[:, :n], r_up=res.r_up[:, :n])

    def query_batch(self, rt, users, qs, *, k, c, delta=None):
        n = users.shape[0]
        if self._mode is None or k > n:
            # k > n: the shared selection (partition at k−1) needs k ≤ n
            # of REAL rows for the sentinel proof; hand the degenerate
            # case to the inner backend for identical error behavior
            if delta is None:
                return self.inner.query_batch(rt, users, qs, k=k, c=c)
            return self.inner.query_batch(rt, users, qs, k=k, c=c,
                                          delta=delta)
        return self._query_via(_elastic_query, rt, users, qs,
                               k=k, c=c, delta=delta)

    def dispatch_device(self, rt, users, qs, *, k, c, delta=None):
        """Serving dispatch (PR 10): one H2D for the tick's host query
        block, then the DONATING jit entry — the block's device buffer is
        tick-private (freshly staged here, never reused by the caller),
        so on accelerators XLA reclaims it for outputs. Values are
        bit-identical to `query_batch`: same compiled computation, only
        buffer residency differs (on CPU it IS the same jit entry)."""
        qs = jnp.asarray(qs)            # the tick's single H2D
        n = users.shape[0]
        if self._mode is None or k > n:
            if delta is None:
                return self.inner.dispatch_device(rt, users, qs, k=k, c=c)
            return self.inner.dispatch_device(rt, users, qs, k=k, c=c,
                                              delta=delta)
        return self._query_via(_elastic_query_serve, rt, users, qs,
                               k=k, c=c, delta=delta)


@BK.register_wrapper("elastic")
def _make_elastic(inner: str, *, mesh=None) -> ElasticBackend:
    """Registry hook: `get_backend("elastic:<inner>")` lands here."""
    return ElasticBackend(inner, mesh=mesh)
