"""c-approximate reverse k-ranks query processing — §4.3 of the paper.

Three steps, all shape-stable (no data-dependent branches, so the whole
query jits into one XLA program and the Lemma-1 cases become masks):

  1. u·q for every user (the only O(nd) stage) + rank-table lookup →
     per-user bound ranks (r↓, r↑) and an interpolated estimate;
  2. R↓_k / R↑_k via top-k, Lemma-1 accept/prune masks;
  3. a single composite-key top-k realizes the paper's insertion order:
     in the guaranteed case (c·R↓_k ≥ R↑_k) users are ranked purely by the
     interpolated estimate; otherwise Lemma-1-accepted users come first,
     undetermined users (U_temp) fill by estimate, pruned users are pushed
     past every admissible key.

Total O(nd) — matching the paper's complexity claim; steps 2-3 are O(n).

BATCHED-FIRST (PR 1): the primitive unit of work is a (B, d) query block.
Step 1 for a batch is one (n, d) × (d, B) MXU matmul plus a SINGLE pass
over the (n, τ) thresholds/table serving all B queries — the n·(d + 2τ)
byte stream is read once per batch instead of once per query, a ~B×
reduction in HBM traffic for the memory-bound online phase. `query` is
literally the B = 1 case of `query_batch`; `select_topk` and
`lemma1_select` are shape-polymorphic over a leading batch axis so the
dense, fused-Pallas, and sharded backends (see `repro.core.backends`)
share one selection semantics.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import DeltaCorrection, EPS_BF16, QueryResult, \
    RankTable, StoredUsers, _I8_TRANSFORM_PAD, kth_smallest

# §Perf H4b (REFUTED): a gather-based bisection was hypothesized to touch
# only ~log2(τ)·n elements instead of streaming the full (n, τ) rows.
# XLA's cost model (and TPU HBM reality — gathers are line-quantized)
# charges each gather round at full-operand bytes, making bisect ~3×
# WORSE than the vectorized searchsorted. Kept as an option for the
# record; the winning lever is τ itself (see EXPERIMENTS.md §Perf H4).
LOOKUP = "searchsorted"


def _bucketize(thresholds: jax.Array, uq: jax.Array) -> jax.Array:
    """idx = #{j : t_j ≤ uq} per (row, query), ascending per-row thresholds.

    thresholds (n, τ); uq (n, B) — one score column per batched query.
    Returns (n, B) int in [0, τ].
    """
    n, tau = thresholds.shape
    if LOOKUP == "searchsorted":
        return jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
            thresholds, uq.astype(thresholds.dtype))
    rows = jnp.arange(n)[:, None]
    uq_c = uq.astype(thresholds.dtype)
    lo = jnp.zeros(uq.shape, jnp.int32)
    hi = jnp.full(uq.shape, tau, jnp.int32)
    for _ in range(int(math.ceil(math.log2(max(tau, 2)))) + 1):
        mid = (lo + hi) // 2
        v = thresholds[rows, jnp.clip(mid, 0, tau - 1)]
        go_right = (v <= uq_c) & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


# Row-block size for the tiled dequantizing matmul: XLA CPU lowers a
# convert feeding a dot into a NAIVE (non-GEMM) loop, and a standalone
# full-matrix convert is a DRAM-streaming write of the 4× f32 copy —
# both measured several times slower than f32 GEMM at (256k, 64). A
# sequential lax.map over row blocks keeps each converted tile
# cache-resident between its convert and its oneDNN GEMM: 24 ms vs 83 ms
# fused / 73 ms barrier at (262144, 64) × (64, 16).
_DEQUANT_MM_BLOCK = 1024


def _dequant_matmul(rows: jax.Array, scale: Optional[jax.Array],
                    qs: jax.Array) -> jax.Array:
    """(rows·qs.T)·scale with rows in a storage dtype — f32 accumulate,
    tiled so the dequantized copy never round-trips through DRAM."""
    n = rows.shape[0]
    qt = qs.T.astype(jnp.float32)

    def block(args):
        rb, sb = args
        out = rb.astype(jnp.float32) @ qt
        return out if sb is None else out * sb

    nb = n // _DEQUANT_MM_BLOCK
    if nb < 2:
        return block((rows, scale))
    head = nb * _DEQUANT_MM_BLOCK
    rb = rows[:head].reshape(nb, _DEQUANT_MM_BLOCK, rows.shape[1])
    sb = (None if scale is None
          else scale[:head].reshape(nb, _DEQUANT_MM_BLOCK, 1))
    out = jax.lax.map(block, (rb, sb)).reshape(head, -1)
    if head < n:
        out = jnp.concatenate([out, block((rows[head:],
                                           None if scale is None
                                           else scale[head:]))])
    return out


def user_scores_batch(users, qs: jax.Array
                      ) -> tuple[jax.Array, Optional[jax.Array]]:
    """Step-1 scores for either user representation.

    `users` is a raw (n, d) array (f32 spec — the expression is exactly
    the pre-spec `(users @ qs.T).astype(f32)`, so the f32 path stays
    bit-identical) or a `StoredUsers` (bf16/int8 rows dequantized with
    f32 accumulation, tiled — see `_dequant_matmul`). Returns
    (scores, slack), each (n, B); slack is the certified
    |stored-score − f32-score| bound (None when exact) that the
    dequant-aware lookup folds into the (r↓, r↑) widening.
    """
    if not isinstance(users, StoredUsers):
        return (users @ qs.T).astype(jnp.float32), None
    scores = _dequant_matmul(users.rows, users.scale, qs)   # (n, B)
    slack = users.row_slack * jnp.sum(jnp.abs(qs), axis=1)[None, :]
    return scores, slack


def _searchsorted_rows(rows: jax.Array, vals: jax.Array, side: str
                       ) -> jax.Array:
    """Vmapped per-row searchsorted: rows (n, τ) ascending, vals (n, B)."""
    return jax.vmap(functools.partial(jnp.searchsorted, side=side))(rows,
                                                                    vals)


def _est_from_grid(uq: jax.Array, idx: jax.Array, thr_up: jax.Array,
                   thr_lo: jax.Array, thr_edge_lo: jax.Array,
                   thr_edge_hi: jax.Array, r_lo: jax.Array,
                   r_up: jax.Array, tau: int, m_plus_1: jax.Array
                   ) -> jax.Array:
    """The §4.3-step-3 interpolated estimate + margin-decayed out-of-range
    refinement + sub-unit tie-break, on caller-supplied DEQUANTIZED f32
    grid values (shared by the bf16 and int8 lookup paths; the f32 path
    keeps its original inline body for bit-identity).

    thr_up/thr_lo are the thresholds bracketing `idx`; thr_edge_lo/hi the
    per-row grid endpoints, (n, 1). The estimate interpolates between the
    CERTIFIED (widened) bounds, so clip keeps it admissible.
    """
    span = jnp.maximum(thr_lo - thr_up, 1e-12)
    frac = jnp.clip((uq - thr_up) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau)
    est_in = r_up + (r_lo - r_up) * frac
    rng = jnp.maximum(thr_edge_hi - thr_edge_lo, 1e-12)
    m_above = jnp.maximum(uq - thr_edge_hi, 0.0) / rng
    m_below = jnp.maximum(thr_edge_lo - uq, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau * m_above)
    est_below = m_plus_1 - (m_plus_1 - r_lo) * jnp.exp(-tau * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau, est_above, est_below))
    est = jnp.clip(est, r_lo, r_up)
    return est - 0.5 * m_above / (1.0 + m_above)


def _lookup_bounds_bf16(rt: RankTable, uq: jax.Array,
                        slack: Optional[jax.Array]
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Certified lookup on a bf16-stored table.

    Bucketize via the MONOTONE CAST, two-sided: with t̃ = bf16(t) and a
    score interval [s−δ, s+δ] around the true f32 score,
      t ≤ s+δ ⟹ t̃ ≤ bf16(s+δ)   so  idx_hi = #{t̃ ≤ bf16(s+δ)} ≥ idx*;
      t̃ < bf16(s−δ) ⟹ t < s−δ   so  idx_lo = #{t̃ < bf16(s−δ)} ≤ idx*.
    Table reads widen by EPS_BF16 in the certified direction:
    r↑ = T̃[idx_lo−1]·(1+ε) ≥ T[idx*−1] (T non-increasing, idx_lo ≤ idx*)
    and r↓ = T̃[idx_hi]·(1−ε) ≤ T[idx*] — quantization error is folded
    into the bounds, never into the selection semantics.
    """
    n, tau = rt.thresholds.shape
    thr, tab = rt.thresholds, rt.table
    s_hi = uq if slack is None else uq + slack
    s_lo = uq if slack is None else uq - slack
    idx_hi = _searchsorted_rows(thr, s_hi.astype(thr.dtype), "right")
    idx_lo = _searchsorted_rows(thr, s_lo.astype(thr.dtype), "left")
    m_plus_1 = (rt.m + 1).astype(jnp.float32)
    up_col = jnp.clip(idx_lo - 1, 0, tau - 1)
    lo_col = jnp.clip(idx_hi, 0, tau - 1)
    t_up = jnp.take_along_axis(tab, up_col, axis=1).astype(jnp.float32)
    t_lo = jnp.take_along_axis(tab, lo_col, axis=1).astype(jnp.float32)
    r_up = jnp.where(idx_lo == 0, m_plus_1, t_up * (1.0 + EPS_BF16))
    r_lo = jnp.where(idx_hi == tau, 1.0, t_lo * (1.0 - EPS_BF16))
    thr32 = lambda c: jnp.take_along_axis(thr, c, axis=1).astype(jnp.float32)
    est = _est_from_grid(
        uq, idx_hi, thr32(jnp.clip(idx_hi - 1, 0, tau - 1)), thr32(lo_col),
        thr[:, :1].astype(jnp.float32),
        thr[:, tau - 1:tau].astype(jnp.float32), r_lo, r_up, tau, m_plus_1)
    return r_lo, r_up, est


def _lookup_bounds_int8(rt: RankTable, uq: jax.Array,
                        slack: Optional[jax.Array]
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Certified lookup on an int8-stored table — CLOSED-FORM bucketize.

    Algorithm 1 builds each row's thresholds as a UNIFORM grid
    (`threshold_grid`), so in the row's code units the true thresholds
    sit within `thr_dev` (measured at pack time, ~f32-rounding tiny) of
    the exact affine grid G_j = −127 + j·Δ, Δ = 254/(τ−1). The bucketize
    therefore needs NO search and NO threshold-stream read at all:

        idx_hi = #{j : G_j − dev' ≤ s' + δ'} = ⌊(s'+δ'+127+dev')/Δ⌋ + 1
        idx_lo = #{j : G_j + dev' ≤ s' − δ'} = ⌊(s'−δ'−127−dev')/Δ⌋ + 1

    (clipped to [0, τ]), with s' = (s−off)/sc, δ' the user-quantization
    score slack in code units, and dev' = thr_dev + pad covering the f32
    rounding of the transform and the division. Since thr_dev bounds the
    TRUE-threshold deviation, idx_lo ≤ idx* ≤ idx_hi is certified even
    for a non-uniform packed table (dev is then just large). Table reads
    dequantize and widen by (½+pad)·scale in the certified direction —
    r↓ rounds down, r↑ rounds up. HBM traffic of the whole lookup: the
    int8 TABLE gathers plus five (n, 1) vectors — the thresholds array
    is never touched on the query path.
    """
    n, tau = rt.thresholds.shape
    tab_q = rt.table
    sc_t, off_t = rt.thr_scale, rt.thr_off                  # (n, 1)
    sc_b, off_b = rt.tab_scale, rt.tab_off
    s_n = (uq - off_t) / sc_t                               # (n, B) in codes
    d_n = 0.0 if slack is None else slack / sc_t
    dev = rt.thr_dev + 20.0 * _I8_TRANSFORM_PAD             # (n, 1)
    delta = 254.0 / (tau - 1)
    # #{j : −127 + jΔ ≤ v} = ⌊(v + 127)/Δ⌋ + 1, v = s' ± (δ' + dev);
    # the float-side clip guards the int32 cast against overflow when a
    # degenerate row scale blows s' up
    count = lambda v: jnp.clip(
        jnp.floor((v + 127.0) / delta), -1.0, float(tau)
    ).astype(jnp.int32) + 1
    idx_hi = jnp.clip(count(s_n + d_n + dev), 0, tau)
    idx_lo = jnp.clip(count(s_n - d_n - dev), 0, tau)
    m_plus_1 = (rt.m + 1).astype(jnp.float32)
    up_col = jnp.clip(idx_lo - 1, 0, tau - 1)
    lo_col = jnp.clip(idx_hi, 0, tau - 1)
    deq_tab = lambda c: (jnp.take_along_axis(tab_q, c, axis=1).astype(
        jnp.float32) * sc_b + off_b)
    widen = (0.5 + _I8_TRANSFORM_PAD) * sc_b                # (n, 1)
    r_up = jnp.where(idx_lo == 0, m_plus_1, deq_tab(up_col) + widen)
    r_lo = jnp.where(idx_hi == tau, 1.0, deq_tab(lo_col) - widen)
    # est thresholds in closed form too (G_c·sc + off) — zero gathers
    grid_at = lambda c: ((c.astype(jnp.float32) * delta - 127.0) * sc_t
                         + off_t)
    est = _est_from_grid(
        uq, idx_hi, grid_at(jnp.clip(idx_hi - 1, 0, tau - 1)),
        grid_at(lo_col),
        -127.0 * sc_t + off_t, 127.0 * sc_t + off_t,
        r_lo, r_up, tau, m_plus_1)
    return r_lo, r_up, est


def lookup_bounds_batch(rt: RankTable, uq: jax.Array,
                        slack: Optional[jax.Array] = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-table lookup (§4.3 step 1) for a (n, B) score block — THE one
    dequant-aware bound path: every backend (dense, fused-generic,
    sharded per shard, pruned per gathered block) lands here, dispatched
    on the table's storage spec (`RankTable.spec_kind`, static at trace).

    uq[i, b] = u_i · q_b; each threshold/table ROW is streamed once and
    bucketizes all B queries — the bandwidth amortization the batched
    engine is built around.

    With ascending thresholds t_1..t_τ and non-increasing table T_1..T_τ:
      t_j ≤ u·q ≤ t_{j+1}  ⇒  T_{j+1} ≤ r(q,u,P) ≤ T_j.
    Out-of-range: u·q < t_1 ⇒ (r↓, r↑) = (T_1, m+1);
                  u·q ≥ t_τ ⇒ (r↓, r↑) = (1, T_τ).

    `slack` (quantized user matrices) is the certified per-(row, query)
    score-error bound; quantized specs fold it plus their own storage
    error into the returned bounds — r↓ rounds DOWN, r↑ rounds UP — so
    the f32-spec bounds (and hence the table's true bracketing) are
    certifiably contained in the returned interval, and Lemma-1 selection
    over them stays sound (the bound-widening proof obligation; see
    `types.StorageSpec`).

    Returns (r_lo, r_up, est), each (n, B) — bounds plus the §4.3-step-3
    linear interpolation of the rank at u·q's position between its two
    thresholds.
    """
    kind = rt.spec_kind
    if kind == "int8":
        return _lookup_bounds_int8(rt, uq, slack)
    if kind == "bf16":
        return _lookup_bounds_bf16(rt, uq, slack)
    if slack is not None:
        raise ValueError("score slack requires a quantized rank table "
                         "(an exact f32 table cannot widen its bounds)")
    n, tau = rt.thresholds.shape
    # _bucketize compares in the table's storage dtype: promotion to f32
    # would materialize a full-size HBM copy of a bf16 table, erasing the
    # §Perf-H4 bandwidth win (refuted-hypothesis lesson).
    idx = _bucketize(rt.thresholds, uq)                     # (n, B) in [0, τ]
    m_plus_1 = (rt.m + 1).astype(jnp.float32)
    up_col = jnp.clip(idx - 1, 0, tau - 1)
    lo_col = jnp.clip(idx, 0, tau - 1)
    t_up = jnp.take_along_axis(rt.table, up_col, axis=1).astype(jnp.float32)
    t_lo = jnp.take_along_axis(rt.table, lo_col, axis=1).astype(jnp.float32)
    r_up = jnp.where(idx == 0, m_plus_1, t_up)               # T_j (j = idx)
    r_lo = jnp.where(idx == tau, 1.0, t_lo)                  # T_{j+1}

    # Linear interpolation between the bracketing thresholds (step 3).
    lo_thr = jnp.take_along_axis(rt.thresholds, up_col, axis=1).astype(
        jnp.float32)
    hi_thr = jnp.take_along_axis(rt.thresholds, lo_col, axis=1).astype(
        jnp.float32)
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((uq - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau)
    est_in = r_up + (r_lo - r_up) * frac
    # Out-of-range scores (beyond-paper refinement): the paper's midpoint
    # collapses every above-range user to the same estimate, making the
    # final top-k an arbitrary tie-break (hurts popular-item queries where
    # many users exceed t_τ). Decay the estimate with the score's margin
    # beyond the range instead — monotone, consistent at the boundary
    # (margin 0 ⇒ the bound), and still within [r↓, r↑].
    t_lo_edge = rt.thresholds[:, :1].astype(jnp.float32)     # (n, 1)
    t_hi_edge = rt.thresholds[:, tau - 1:tau].astype(jnp.float32)
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(uq - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - uq, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau * m_above)
    est_below = m_plus_1 - (m_plus_1 - r_lo) * jnp.exp(-tau * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau, est_above, est_below))
    est = jnp.clip(est, r_lo, r_up)
    # Sub-unit tie-break: when the top table entry is already rank 1, every
    # above-range user collapses to est = 1; order them by how far their
    # score clears the threshold range (larger margin ⇒ fewer items can
    # still beat q for that user). Stays within (est-0.5, est], so it never
    # reorders users whose estimates differ by ≥ 1 rank.
    return r_lo, r_up, est - 0.5 * m_above / (1.0 + m_above)


def lookup_bounds(rt: RankTable, uq: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-query rank-table lookup: the B = 1 column of
    `lookup_bounds_batch`. Returns (r_lo, r_up, est), each (n,)."""
    r_lo, r_up, est = lookup_bounds_batch(rt, uq[:, None])
    return r_lo[:, 0], r_up[:, 0], est[:, 0]


def tile_bounds(rt_tile: RankTable, users_tile, qs: jax.Array,
                corr_tile: Optional[DeltaCorrection] = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """§4.3 step 1 (scores → dequant-aware lookup → optional delta
    correction) for ONE fixed-size user tile — the dense unit of work of
    the compile-once elastic scan (`repro.core.elastic`).

    Exactly `user_scores_batch` ∘ `lookup_bounds_batch`
    [∘ `apply_delta_corrections`] on a (tile, ·) row slice. Every
    operation in that composition is ROW-LOCAL (the matmul row, the
    per-row bucketize, the per-row correction counts touch only their own
    user's data), which is the property that makes tiling bit-identical:
    computing rows 0..n in ⌈n/tile⌉ fixed slices produces the same f32
    words as one (n, ·) call. (The one n-sensitive branch in the stack,
    `_dequant_matmul`'s blocked remainder split, takes its direct branch
    for any tile < 2·`_DEQUANT_MM_BLOCK` — asserted in
    tests/test_elastic.py.)

    Returns (r↓, r↑, est), each USER-major (tile, B) — the orientation
    the scan accumulates in.
    """
    scores, slack = user_scores_batch(users_tile, qs)
    r_lo, r_up, est = lookup_bounds_batch(rt_tile, scores, slack)
    if corr_tile is not None:
        from repro.core import rank_table as rt_mod
        r_lo, r_up, est = rt_mod.apply_delta_corrections(
            scores, r_lo, r_up, est, corr_tile, slack=slack)
    return r_lo, r_up, est


@jax.jit
def bound_ranks_batch(rt: RankTable, users, qs: jax.Array
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dense-backend step 1 for a (B, d) query block.

    One (n, d) × (d, B) MXU matmul + one streamed pass over the table.
    `users` is a raw (n, d) array or a `StoredUsers` (quantized specs).
    Returns (r_lo, r_up, est), each (B, n) — the `QueryBackend.bound_ranks`
    orientation (query-major, user axis last, ready for per-query top-k).
    """
    scores, slack = user_scores_batch(users, qs)            # (n, B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores, slack)
    return r_lo.T, r_up.T, est.T


def lemma1_key(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *,
               R_lo_k: jax.Array, R_up_k: jax.Array, c: float,
               m_items: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The §4.3 composite selection key (smaller = better), plus the
    guaranteed/accepted/pruned masks it is built from.

    THE single definition of the selection ordering: `lemma1_select`
    (dense/fused global selection) and the sharded per-shard candidate
    pick (`distributed.make_batch_query_fn`) both call it, so the local
    top-k and the global merge cannot drift apart.

    Class separation: `big = m_items + 2` strictly dominates any static
    est ∈ [1, m+1]. On the DELTA path the unclipped shifted estimate
    spans [1 − n_del, m_base + 1 + n_add] instead, so delta callers pass
    the WIDENED `DeltaCorrection.selection_m` (≥ that range's width) as
    `m_items` — with a bare m'+2 offset and ≥ 2 deletions, a U_temp user
    at the top of the est range could out-key a pruned user at the
    bottom, inverting the class order.
    """
    guaranteed = c * R_lo_k >= R_up_k
    accepted = r_up <= (c * R_lo_k)[..., None]              # Lemma 1 (1)
    pruned = r_lo > R_up_k[..., None]                       # Lemma 1 (2)
    prio = jnp.where(accepted, 0.0, jnp.where(pruned, 2.0, 1.0))
    big = (m_items + 2).astype(jnp.float32)
    key_val = jnp.where(guaranteed[..., None], est, prio * big + est)
    return key_val, guaranteed, accepted, pruned


def lemma1_select(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *,
                  R_lo_k: jax.Array, R_up_k: jax.Array, k: int, c: float,
                  m_items: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """§4.3 step 3 as one composite-key top-k, given the step-2 statistics.

    Shape-polymorphic over leading batch axes: the candidate axis is LAST
    (r_lo/r_up/est are (..., n); R_lo_k/R_up_k are (...,)). Shared by the
    in-memory backends (candidates = all n users) and the distributed
    tree-merge (candidates = the gathered (B, k·P) per-shard winners).

    Returns (selected indices into the candidate axis, guaranteed mask,
    accepted mask, pruned mask).
    """
    key_val, guaranteed, accepted, pruned = lemma1_key(
        r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, c=c,
        m_items=m_items)
    _, indices = jax.lax.top_k(-key_val, k)
    return indices.astype(jnp.int32), guaranteed, accepted, pruned


def select_topk(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *, k: int,
                c: float, m_items: jax.Array) -> QueryResult:
    """Steps 2-3 of §4.3 given per-user bounds — shared by the dense path
    (`query`/`query_batch`) and the Pallas fused path
    (`kernels.ops.query_fused*`).

    Shape-polymorphic: pass (n,) arrays for one query or (B, n) arrays for
    a batch; every QueryResult field gains the same leading axes.
    """
    R_lo_k = kth_smallest(r_lo, k)                          # step 2: O(n)
    R_up_k = kth_smallest(r_up, k)
    indices, guaranteed, accepted, pruned = lemma1_select(
        r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, k=k, c=c,
        m_items=m_items)
    return QueryResult(
        indices=indices,
        est_rank=jnp.take_along_axis(est, indices, axis=-1),
        r_lo=r_lo, r_up=r_up,
        R_lo_k=R_lo_k, R_up_k=R_up_k,
        guaranteed=guaranteed,
        n_accepted=jnp.sum(accepted, axis=-1).astype(jnp.int32),
        n_pruned=jnp.sum(pruned, axis=-1).astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def query_batch(rt: RankTable, users, qs: jax.Array, k: int,
                c: float) -> QueryResult:
    """Batched c-approximate reverse k-ranks queries (Definition 3, §4.3).

    qs is (B, d); every QueryResult field gains a leading B axis. Step 1
    is ONE matmul + ONE pass over the rank table for the whole batch (not
    B re-reads — see the module docstring).
    """
    scores, slack = user_scores_batch(users, qs)            # step 1: O(nd·B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores, slack)
    return select_topk(r_lo.T, r_up.T, est.T, k=k, c=c, m_items=rt.m)


@jax.jit
def _delta_bounds_batch(rt: RankTable, users, qs: jax.Array,
                        corr: DeltaCorrection
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Step 1 + delta correction for a (B, d) block → corrected
    (r↓, r↑, est), each (B, n)."""
    from repro.core import rank_table as rt_mod
    scores, slack = user_scores_batch(users, qs)            # (n, B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores, slack)
    r_lo, r_up, est = rt_mod.apply_delta_corrections(scores, r_lo, r_up,
                                                     est, corr, slack=slack)
    return r_lo.T, r_up.T, est.T


@functools.partial(jax.jit, static_argnames=("k",))
def _select_topk_jit(r_lo, r_up, est, m_items, k: int, c: float
                     ) -> QueryResult:
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=m_items)


def query_batch_delta(rt: RankTable, users: jax.Array, qs: jax.Array,
                      corr: DeltaCorrection, k: int, c: float) -> QueryResult:
    """`query_batch` over a mutated index: the same one-pass batched step 1
    plus the delta-buffer correction (`rank_table.apply_delta_corrections`)
    between the table lookup and the selection. The correction reuses the
    step-1 score matrix, so the only extra work is the O(n·B·log|delta|)
    counting pass; selection uses the delta-widened class offset
    `corr.selection_m()` (see `lemma1_key`).

    TWO jit regions, deliberately (unlike the static one-region
    `query_batch`): selection fans the corrected bounds out to ~6
    consumers (two order statistics, the composite key, the accept/prune
    sums), and XLA CPU re-fuses the whole O(n·(τ + |delta|)) bound/count
    producer chain into each of them — measured 1.8× end-to-end
    (optimization_barrier does not stop it). The region break materializes
    the corrected (B, n) bounds ONCE; the second dispatch costs µs and
    holds the delta path at ≤ 1.3× the static query (perf_engine
    --updates acceptance)."""
    r_lo, r_up, est = _delta_bounds_batch(rt, users, qs, corr)
    return _select_topk_jit(r_lo, r_up, est, corr.selection_m(), k, c)


@functools.partial(jax.jit, static_argnames=("k",))
def query(rt: RankTable, users: jax.Array, q: jax.Array, k: int,
          c: float) -> QueryResult:
    """One c-approximate reverse k-ranks query: the B = 1 case of
    `query_batch` (same code path, leading axis squeezed)."""
    res = query_batch(rt, users, q[None, :], k, c)
    return jax.tree_util.tree_map(lambda x: x[0], res)
