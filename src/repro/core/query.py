"""c-approximate reverse k-ranks query processing — §4.3 of the paper.

Three steps, all shape-stable (no data-dependent branches, so the whole
query jits into one XLA program and the Lemma-1 cases become masks):

  1. u·q for every user (the only O(nd) stage) + rank-table lookup →
     per-user bound ranks (r↓, r↑) and an interpolated estimate;
  2. R↓_k / R↑_k via top-k, Lemma-1 accept/prune masks;
  3. a single composite-key top-k realizes the paper's insertion order:
     in the guaranteed case (c·R↓_k ≥ R↑_k) users are ranked purely by the
     interpolated estimate; otherwise Lemma-1-accepted users come first,
     undetermined users (U_temp) fill by estimate, pruned users are pushed
     past every admissible key.

Total O(nd) — matching the paper's complexity claim; steps 2-3 are O(n).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.types import QueryResult, RankTable, kth_smallest

# §Perf H4b (REFUTED): a gather-based bisection was hypothesized to touch
# only ~log2(τ)·n elements instead of streaming the full (n, τ) rows.
# XLA's cost model (and TPU HBM reality — gathers are line-quantized)
# charges each gather round at full-operand bytes, making bisect ~3×
# WORSE than the vectorized searchsorted. Kept as an option for the
# record; the winning lever is τ itself (see EXPERIMENTS.md §Perf H4).
LOOKUP = "searchsorted"


def _bucketize(thresholds: jax.Array, uq: jax.Array) -> jax.Array:
    """idx = #{j : t_j ≤ uq} per row, for ascending per-row thresholds."""
    n, tau = thresholds.shape
    if LOOKUP == "searchsorted":
        return jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
            thresholds, uq.astype(thresholds.dtype))
    rows = jnp.arange(n)
    uq_c = uq.astype(thresholds.dtype)
    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.full((n,), tau, jnp.int32)
    for _ in range(int(math.ceil(math.log2(max(tau, 2)))) + 1):
        mid = (lo + hi) // 2
        v = thresholds[rows, jnp.clip(mid, 0, tau - 1)]
        go_right = (v <= uq_c) & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def lookup_bounds(rt: RankTable, uq: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-table lookup (§4.3 step 1) for scores uq = u·q, all users.

    With ascending thresholds t_1..t_τ and non-increasing table T_1..T_τ:
      t_j ≤ u·q ≤ t_{j+1}  ⇒  T_{j+1} ≤ r(q,u,P) ≤ T_j.
    Out-of-range: u·q < t_1 ⇒ (r↓, r↑) = (T_1, m+1);
                  u·q ≥ t_τ ⇒ (r↓, r↑) = (1, T_τ).

    Returns (r_lo, r_up, est) — bounds plus the §4.3-step-3 linear
    interpolation of the rank at u·q's position between its two thresholds.
    """
    n, tau = rt.thresholds.shape
    # _bucketize compares in the table's storage dtype: promotion to f32
    # would materialize a full-size HBM copy of a bf16 table, erasing the
    # §Perf-H4 bandwidth win (refuted-hypothesis lesson).
    idx = _bucketize(rt.thresholds, uq)                     # (n,) in [0, τ]
    rows = jnp.arange(n)
    m_plus_1 = (rt.m + 1).astype(jnp.float32)
    t_up = rt.table[rows, jnp.clip(idx - 1, 0, tau - 1)].astype(jnp.float32)
    t_lo = rt.table[rows, jnp.clip(idx, 0, tau - 1)].astype(jnp.float32)
    r_up = jnp.where(idx == 0, m_plus_1, t_up)               # T_j (j = idx)
    r_lo = jnp.where(idx == tau, 1.0, t_lo)                  # T_{j+1}

    # Linear interpolation between the bracketing thresholds (step 3).
    lo_thr = rt.thresholds[rows, jnp.clip(idx - 1, 0, tau - 1)].astype(
        jnp.float32)
    hi_thr = rt.thresholds[rows, jnp.clip(idx, 0, tau - 1)].astype(
        jnp.float32)
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((uq - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau)
    est_in = r_up + (r_lo - r_up) * frac
    # Out-of-range scores (beyond-paper refinement): the paper's midpoint
    # collapses every above-range user to the same estimate, making the
    # final top-k an arbitrary tie-break (hurts popular-item queries where
    # many users exceed t_τ). Decay the estimate with the score's margin
    # beyond the range instead — monotone, consistent at the boundary
    # (margin 0 ⇒ the bound), and still within [r↓, r↑].
    t_lo_edge = rt.thresholds[:, 0].astype(jnp.float32)
    t_hi_edge = rt.thresholds[:, tau - 1].astype(jnp.float32)
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(uq - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - uq, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau * m_above)
    est_below = m_plus_1 - (m_plus_1 - r_lo) * jnp.exp(-tau * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau, est_above, est_below))
    est = jnp.clip(est, r_lo, r_up)
    # Sub-unit tie-break: when the top table entry is already rank 1, every
    # above-range user collapses to est = 1; order them by how far their
    # score clears the threshold range (larger margin ⇒ fewer items can
    # still beat q for that user). Stays within (est-0.5, est], so it never
    # reorders users whose estimates differ by ≥ 1 rank.
    return r_lo, r_up, est - 0.5 * m_above / (1.0 + m_above)


def select_topk(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *, k: int,
                c: float, m_items: jax.Array) -> QueryResult:
    """Steps 2-3 of §4.3 given per-user bounds — shared by the pure-jnp
    path (`query`) and the Pallas fused path (`kernels.ops.query_fused`)."""
    R_lo_k = kth_smallest(r_lo, k)                          # step 2: O(n)
    R_up_k = kth_smallest(r_up, k)
    guaranteed = c * R_lo_k >= R_up_k
    accepted = r_up <= c * R_lo_k                           # Lemma 1 (1)
    pruned = r_lo > R_up_k                                  # Lemma 1 (2)

    # step 3 as one top-k over a composite key. Priorities only apply in the
    # non-guaranteed case; `m + 2` strictly dominates any est ∈ [1, m+1].
    prio = jnp.where(accepted, 0.0, jnp.where(pruned, 2.0, 1.0))
    big = (m_items + 2).astype(jnp.float32)
    key_val = jnp.where(guaranteed, est, prio * big + est)
    _, indices = jax.lax.top_k(-key_val, k)

    return QueryResult(
        indices=indices.astype(jnp.int32),
        est_rank=est[indices],
        r_lo=r_lo, r_up=r_up,
        R_lo_k=R_lo_k, R_up_k=R_up_k,
        guaranteed=guaranteed,
        n_accepted=jnp.sum(accepted).astype(jnp.int32),
        n_pruned=jnp.sum(pruned).astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def query(rt: RankTable, users: jax.Array, q: jax.Array, k: int,
          c: float) -> QueryResult:
    """One c-approximate reverse k-ranks query (Definition 3, §4.3)."""
    uq = (users @ q).astype(jnp.float32)                    # step 1: O(nd)
    r_lo, r_up, est = lookup_bounds(rt, uq)
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=rt.m)


@functools.partial(jax.jit, static_argnames=("k",))
def query_batch(rt: RankTable, users: jax.Array, qs: jax.Array, k: int,
                c: float) -> QueryResult:
    """Vectorized queries: qs is (b, d); every field gains a leading b axis."""
    return jax.vmap(lambda q: query(rt, users, q, k, c))(qs)
