"""c-approximate reverse k-ranks query processing — §4.3 of the paper.

Three steps, all shape-stable (no data-dependent branches, so the whole
query jits into one XLA program and the Lemma-1 cases become masks):

  1. u·q for every user (the only O(nd) stage) + rank-table lookup →
     per-user bound ranks (r↓, r↑) and an interpolated estimate;
  2. R↓_k / R↑_k via top-k, Lemma-1 accept/prune masks;
  3. a single composite-key top-k realizes the paper's insertion order:
     in the guaranteed case (c·R↓_k ≥ R↑_k) users are ranked purely by the
     interpolated estimate; otherwise Lemma-1-accepted users come first,
     undetermined users (U_temp) fill by estimate, pruned users are pushed
     past every admissible key.

Total O(nd) — matching the paper's complexity claim; steps 2-3 are O(n).

BATCHED-FIRST (PR 1): the primitive unit of work is a (B, d) query block.
Step 1 for a batch is one (n, d) × (d, B) MXU matmul plus a SINGLE pass
over the (n, τ) thresholds/table serving all B queries — the n·(d + 2τ)
byte stream is read once per batch instead of once per query, a ~B×
reduction in HBM traffic for the memory-bound online phase. `query` is
literally the B = 1 case of `query_batch`; `select_topk` and
`lemma1_select` are shape-polymorphic over a leading batch axis so the
dense, fused-Pallas, and sharded backends (see `repro.core.backends`)
share one selection semantics.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.types import DeltaCorrection, QueryResult, RankTable, \
    kth_smallest

# §Perf H4b (REFUTED): a gather-based bisection was hypothesized to touch
# only ~log2(τ)·n elements instead of streaming the full (n, τ) rows.
# XLA's cost model (and TPU HBM reality — gathers are line-quantized)
# charges each gather round at full-operand bytes, making bisect ~3×
# WORSE than the vectorized searchsorted. Kept as an option for the
# record; the winning lever is τ itself (see EXPERIMENTS.md §Perf H4).
LOOKUP = "searchsorted"


def _bucketize(thresholds: jax.Array, uq: jax.Array) -> jax.Array:
    """idx = #{j : t_j ≤ uq} per (row, query), ascending per-row thresholds.

    thresholds (n, τ); uq (n, B) — one score column per batched query.
    Returns (n, B) int in [0, τ].
    """
    n, tau = thresholds.shape
    if LOOKUP == "searchsorted":
        return jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
            thresholds, uq.astype(thresholds.dtype))
    rows = jnp.arange(n)[:, None]
    uq_c = uq.astype(thresholds.dtype)
    lo = jnp.zeros(uq.shape, jnp.int32)
    hi = jnp.full(uq.shape, tau, jnp.int32)
    for _ in range(int(math.ceil(math.log2(max(tau, 2)))) + 1):
        mid = (lo + hi) // 2
        v = thresholds[rows, jnp.clip(mid, 0, tau - 1)]
        go_right = (v <= uq_c) & (mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def lookup_bounds_batch(rt: RankTable, uq: jax.Array
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Rank-table lookup (§4.3 step 1) for a (n, B) score block.

    uq[i, b] = u_i · q_b; each threshold/table ROW is streamed once and
    bucketizes all B queries — the bandwidth amortization the batched
    engine is built around.

    With ascending thresholds t_1..t_τ and non-increasing table T_1..T_τ:
      t_j ≤ u·q ≤ t_{j+1}  ⇒  T_{j+1} ≤ r(q,u,P) ≤ T_j.
    Out-of-range: u·q < t_1 ⇒ (r↓, r↑) = (T_1, m+1);
                  u·q ≥ t_τ ⇒ (r↓, r↑) = (1, T_τ).

    Returns (r_lo, r_up, est), each (n, B) — bounds plus the §4.3-step-3
    linear interpolation of the rank at u·q's position between its two
    thresholds.
    """
    n, tau = rt.thresholds.shape
    # _bucketize compares in the table's storage dtype: promotion to f32
    # would materialize a full-size HBM copy of a bf16 table, erasing the
    # §Perf-H4 bandwidth win (refuted-hypothesis lesson).
    idx = _bucketize(rt.thresholds, uq)                     # (n, B) in [0, τ]
    m_plus_1 = (rt.m + 1).astype(jnp.float32)
    up_col = jnp.clip(idx - 1, 0, tau - 1)
    lo_col = jnp.clip(idx, 0, tau - 1)
    t_up = jnp.take_along_axis(rt.table, up_col, axis=1).astype(jnp.float32)
    t_lo = jnp.take_along_axis(rt.table, lo_col, axis=1).astype(jnp.float32)
    r_up = jnp.where(idx == 0, m_plus_1, t_up)               # T_j (j = idx)
    r_lo = jnp.where(idx == tau, 1.0, t_lo)                  # T_{j+1}

    # Linear interpolation between the bracketing thresholds (step 3).
    lo_thr = jnp.take_along_axis(rt.thresholds, up_col, axis=1).astype(
        jnp.float32)
    hi_thr = jnp.take_along_axis(rt.thresholds, lo_col, axis=1).astype(
        jnp.float32)
    span = jnp.maximum(hi_thr - lo_thr, 1e-12)
    frac = jnp.clip((uq - lo_thr) / span, 0.0, 1.0)
    interior = (idx > 0) & (idx < tau)
    est_in = r_up + (r_lo - r_up) * frac
    # Out-of-range scores (beyond-paper refinement): the paper's midpoint
    # collapses every above-range user to the same estimate, making the
    # final top-k an arbitrary tie-break (hurts popular-item queries where
    # many users exceed t_τ). Decay the estimate with the score's margin
    # beyond the range instead — monotone, consistent at the boundary
    # (margin 0 ⇒ the bound), and still within [r↓, r↑].
    t_lo_edge = rt.thresholds[:, :1].astype(jnp.float32)     # (n, 1)
    t_hi_edge = rt.thresholds[:, tau - 1:tau].astype(jnp.float32)
    rng = jnp.maximum(t_hi_edge - t_lo_edge, 1e-12)
    m_above = jnp.maximum(uq - t_hi_edge, 0.0) / rng
    m_below = jnp.maximum(t_lo_edge - uq, 0.0) / rng
    est_above = 1.0 + (r_up - 1.0) / (1.0 + tau * m_above)
    est_below = m_plus_1 - (m_plus_1 - r_lo) * jnp.exp(-tau * m_below)
    est = jnp.where(interior, est_in,
                    jnp.where(idx == tau, est_above, est_below))
    est = jnp.clip(est, r_lo, r_up)
    # Sub-unit tie-break: when the top table entry is already rank 1, every
    # above-range user collapses to est = 1; order them by how far their
    # score clears the threshold range (larger margin ⇒ fewer items can
    # still beat q for that user). Stays within (est-0.5, est], so it never
    # reorders users whose estimates differ by ≥ 1 rank.
    return r_lo, r_up, est - 0.5 * m_above / (1.0 + m_above)


def lookup_bounds(rt: RankTable, uq: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-query rank-table lookup: the B = 1 column of
    `lookup_bounds_batch`. Returns (r_lo, r_up, est), each (n,)."""
    r_lo, r_up, est = lookup_bounds_batch(rt, uq[:, None])
    return r_lo[:, 0], r_up[:, 0], est[:, 0]


@jax.jit
def bound_ranks_batch(rt: RankTable, users: jax.Array, qs: jax.Array
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dense-backend step 1 for a (B, d) query block.

    One (n, d) × (d, B) MXU matmul + one streamed pass over the table.
    Returns (r_lo, r_up, est), each (B, n) — the `QueryBackend.bound_ranks`
    orientation (query-major, user axis last, ready for per-query top-k).
    """
    scores = (users @ qs.T).astype(jnp.float32)             # (n, B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores)
    return r_lo.T, r_up.T, est.T


def lemma1_key(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *,
               R_lo_k: jax.Array, R_up_k: jax.Array, c: float,
               m_items: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """The §4.3 composite selection key (smaller = better), plus the
    guaranteed/accepted/pruned masks it is built from.

    THE single definition of the selection ordering: `lemma1_select`
    (dense/fused global selection) and the sharded per-shard candidate
    pick (`distributed.make_batch_query_fn`) both call it, so the local
    top-k and the global merge cannot drift apart.

    Class separation: `big = m_items + 2` strictly dominates any static
    est ∈ [1, m+1]. On the DELTA path the unclipped shifted estimate
    spans [1 − n_del, m_base + 1 + n_add] instead, so delta callers pass
    the WIDENED `DeltaCorrection.selection_m` (≥ that range's width) as
    `m_items` — with a bare m'+2 offset and ≥ 2 deletions, a U_temp user
    at the top of the est range could out-key a pruned user at the
    bottom, inverting the class order.
    """
    guaranteed = c * R_lo_k >= R_up_k
    accepted = r_up <= (c * R_lo_k)[..., None]              # Lemma 1 (1)
    pruned = r_lo > R_up_k[..., None]                       # Lemma 1 (2)
    prio = jnp.where(accepted, 0.0, jnp.where(pruned, 2.0, 1.0))
    big = (m_items + 2).astype(jnp.float32)
    key_val = jnp.where(guaranteed[..., None], est, prio * big + est)
    return key_val, guaranteed, accepted, pruned


def lemma1_select(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *,
                  R_lo_k: jax.Array, R_up_k: jax.Array, k: int, c: float,
                  m_items: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """§4.3 step 3 as one composite-key top-k, given the step-2 statistics.

    Shape-polymorphic over leading batch axes: the candidate axis is LAST
    (r_lo/r_up/est are (..., n); R_lo_k/R_up_k are (...,)). Shared by the
    in-memory backends (candidates = all n users) and the distributed
    tree-merge (candidates = the gathered (B, k·P) per-shard winners).

    Returns (selected indices into the candidate axis, guaranteed mask,
    accepted mask, pruned mask).
    """
    key_val, guaranteed, accepted, pruned = lemma1_key(
        r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, c=c,
        m_items=m_items)
    _, indices = jax.lax.top_k(-key_val, k)
    return indices.astype(jnp.int32), guaranteed, accepted, pruned


def select_topk(r_lo: jax.Array, r_up: jax.Array, est: jax.Array, *, k: int,
                c: float, m_items: jax.Array) -> QueryResult:
    """Steps 2-3 of §4.3 given per-user bounds — shared by the dense path
    (`query`/`query_batch`) and the Pallas fused path
    (`kernels.ops.query_fused*`).

    Shape-polymorphic: pass (n,) arrays for one query or (B, n) arrays for
    a batch; every QueryResult field gains the same leading axes.
    """
    R_lo_k = kth_smallest(r_lo, k)                          # step 2: O(n)
    R_up_k = kth_smallest(r_up, k)
    indices, guaranteed, accepted, pruned = lemma1_select(
        r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, k=k, c=c,
        m_items=m_items)
    return QueryResult(
        indices=indices,
        est_rank=jnp.take_along_axis(est, indices, axis=-1),
        r_lo=r_lo, r_up=r_up,
        R_lo_k=R_lo_k, R_up_k=R_up_k,
        guaranteed=guaranteed,
        n_accepted=jnp.sum(accepted, axis=-1).astype(jnp.int32),
        n_pruned=jnp.sum(pruned, axis=-1).astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("k",))
def query_batch(rt: RankTable, users: jax.Array, qs: jax.Array, k: int,
                c: float) -> QueryResult:
    """Batched c-approximate reverse k-ranks queries (Definition 3, §4.3).

    qs is (B, d); every QueryResult field gains a leading B axis. Step 1
    is ONE matmul + ONE pass over the rank table for the whole batch (not
    B re-reads — see the module docstring).
    """
    scores = (users @ qs.T).astype(jnp.float32)             # step 1: O(nd·B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores)
    return select_topk(r_lo.T, r_up.T, est.T, k=k, c=c, m_items=rt.m)


@jax.jit
def _delta_bounds_batch(rt: RankTable, users: jax.Array, qs: jax.Array,
                        corr: DeltaCorrection
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Step 1 + delta correction for a (B, d) block → corrected
    (r↓, r↑, est), each (B, n)."""
    from repro.core import rank_table as rt_mod
    scores = (users @ qs.T).astype(jnp.float32)             # (n, B)
    r_lo, r_up, est = lookup_bounds_batch(rt, scores)
    r_lo, r_up, est = rt_mod.apply_delta_corrections(scores, r_lo, r_up,
                                                     est, corr)
    return r_lo.T, r_up.T, est.T


@functools.partial(jax.jit, static_argnames=("k",))
def _select_topk_jit(r_lo, r_up, est, m_items, k: int, c: float
                     ) -> QueryResult:
    return select_topk(r_lo, r_up, est, k=k, c=c, m_items=m_items)


def query_batch_delta(rt: RankTable, users: jax.Array, qs: jax.Array,
                      corr: DeltaCorrection, k: int, c: float) -> QueryResult:
    """`query_batch` over a mutated index: the same one-pass batched step 1
    plus the delta-buffer correction (`rank_table.apply_delta_corrections`)
    between the table lookup and the selection. The correction reuses the
    step-1 score matrix, so the only extra work is the O(n·B·log|delta|)
    counting pass; selection uses the delta-widened class offset
    `corr.selection_m()` (see `lemma1_key`).

    TWO jit regions, deliberately (unlike the static one-region
    `query_batch`): selection fans the corrected bounds out to ~6
    consumers (two order statistics, the composite key, the accept/prune
    sums), and XLA CPU re-fuses the whole O(n·(τ + |delta|)) bound/count
    producer chain into each of them — measured 1.8× end-to-end
    (optimization_barrier does not stop it). The region break materializes
    the corrected (B, n) bounds ONCE; the second dispatch costs µs and
    holds the delta path at ≤ 1.3× the static query (perf_engine
    --updates acceptance)."""
    r_lo, r_up, est = _delta_bounds_batch(rt, users, qs, corr)
    return _select_topk_jit(r_lo, r_up, est, corr.selection_m(), k, c)


@functools.partial(jax.jit, static_argnames=("k",))
def query(rt: RankTable, users: jax.Array, q: jax.Array, k: int,
          c: float) -> QueryResult:
    """One c-approximate reverse k-ranks query: the B = 1 case of
    `query_batch` (same code path, leading axis squeezed)."""
    res = query_batch(rt, users, q[None, :], k, c)
    return jax.tree_util.tree_map(lambda x: x[0], res)
