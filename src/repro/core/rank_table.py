"""Rank-table pre-processing — Algorithm 1 of the paper, vectorized for TPU.

The paper's per-user, per-sample, per-threshold triple loop (Alg. 1 lines
8-19, with a data-dependent `break`) is re-expressed as three dense stages
that map onto the MXU/VPU:

  1. norm pass + descending sort of P, ω equal partitions, s samples each
     (lines 1-6) — O(md + m log m), shared across all users;
  2. per-user threshold grids from f_min/f_max (lines 9-11) — O(n·τ);
  3. score matrix  U @ Samplesᵀ  (n, ω·s) on the MXU, then a per-row
     sort + weighted suffix-sum + vectorized searchsorted that evaluates
     Eq. (1) for all τ thresholds at once — O(n·(ωs·log ωs + τ·log ωs))
     instead of the paper's O(n·ωs·τ) scalar compares.

The estimator is exactly Eq. (1): unbiased stratified cardinality
estimation with per-partition weights |P_l| / s.

`build_rank_table` is the public entry; `kernels/table_build.py` provides a
Pallas fusion of stage 3 for the TPU hot path (same semantics, tested
against this implementation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import RankTable, RankTableConfig, partition_sizes


def stratified_sample_indices(key: jax.Array, m: int, cfg: RankTableConfig
                              ) -> tuple[jax.Array, jax.Array]:
    """Sample s item positions per norm-partition (Alg. 1 lines 4-6).

    Positions index into the *norm-descending sorted* item order.

    Returns:
      positions: (ω·s,) int32 positions in [0, m).
      weights:   (ω·s,) float32 — the Eq. (1) stratum weights |P_l| / s.
    """
    sizes = partition_sizes(m, cfg.omega)
    keys = jax.random.split(key, cfg.omega)
    pos_parts, w_parts = [], []
    start = 0
    for l, size in enumerate(sizes):
        replace = cfg.sample_with_replacement or cfg.s > size
        local = jax.random.choice(keys[l], size, (cfg.s,), replace=replace)
        pos_parts.append(start + local)
        w_parts.append(jnp.full((cfg.s,), size / cfg.s, dtype=jnp.float32))
        start += size
    return (jnp.concatenate(pos_parts).astype(jnp.int32),
            jnp.concatenate(w_parts))


def threshold_grid(smin: jax.Array, smax: jax.Array, tau: int) -> jax.Array:
    """Per-user uniform thresholds t_{u,j} (Alg. 1 lines 9-11).

    t_{u,j} = f_min + (j-1) · (f_max - f_min) / (τ-1),  j ∈ [1, τ].
    """
    frac = jnp.arange(tau, dtype=jnp.float32) / (tau - 1)
    return smin[:, None] + frac[None, :] * (smax - smin)[:, None]


def estimate_table_rows(scores: jax.Array, weights: jax.Array,
                        thresholds: jax.Array) -> jax.Array:
    """Eq. (1) for a block of users and all τ thresholds.

    Args:
      scores:     (n, ω·s) — u_i · p for the stratified samples.
      weights:    (ω·s,)   — stratum weights |P_l| / s.
      thresholds: (n, τ)   — ascending per-user thresholds.

    Returns:
      (n, τ) float32 table rows:  T̂_{i,j} = 1 + Σ_l (|P_l|/s)·#{p ∈ P_l^s :
      u_i·p > t_{i,j}}  — non-increasing along j.
    """
    order = jnp.argsort(scores, axis=1)
    scores_sorted = jnp.take_along_axis(scores, order, axis=1)
    w_sorted = weights[order]                               # (n, ω·s)
    # suffix[i, j] = Σ_{r >= j} w_sorted[i, r];  suffix[:, ωs] = 0.
    suffix = jnp.concatenate(
        [jnp.cumsum(w_sorted[:, ::-1], axis=1)[:, ::-1],
         jnp.zeros_like(w_sorted[:, :1])], axis=1)
    # side='right': idx = #{scores <= t}, so samples at positions >= idx are
    # strictly greater than t — exactly the indicator u·p > t of Eq. (1).
    idx = jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
        scores_sorted, thresholds)                          # (n, τ)
    return 1.0 + jnp.take_along_axis(suffix, idx, axis=1)


def _threshold_range(users: jax.Array, items_sorted: jax.Array,
                     sample_scores: jax.Array, cfg: RankTableConfig
                     ) -> tuple[jax.Array, jax.Array]:
    """f_min / f_max per user, per cfg.threshold_mode (§4.2 step 2 + fn. 1)."""
    if cfg.threshold_mode == "exact":
        full = users @ items_sorted.T                       # O(nmd): tests only
        return full.min(axis=1), full.max(axis=1)
    if cfg.threshold_mode == "norm_bound":
        bound = jnp.linalg.norm(users, axis=1) * jnp.linalg.norm(
            items_sorted[0])                                # max ‖p‖ is row 0
        return -bound, bound
    smin = sample_scores.min(axis=1)
    smax = sample_scores.max(axis=1)
    pad = cfg.range_pad * jnp.maximum(smax - smin, 1e-6)
    return smin - pad, smax + pad


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_rank_table_sorted(users: jax.Array, items_sorted: jax.Array,
                            cfg: RankTableConfig, key: jax.Array) -> RankTable:
    """Algorithm 1 given P already sorted in descending norm order."""
    m = items_sorted.shape[0]
    positions, weights = stratified_sample_indices(key, m, cfg)
    samples = items_sorted[positions]                       # (ω·s, d)
    scores = (users @ samples.T).astype(jnp.float32)        # (n, ω·s) — MXU
    smin, smax = _threshold_range(users, items_sorted, scores, cfg)
    thresholds = threshold_grid(smin, smax, cfg.tau)
    table = estimate_table_rows(scores, weights, thresholds)
    st = jnp.dtype(cfg.storage_dtype)
    return RankTable(thresholds=thresholds.astype(st),
                     table=table.astype(st),
                     m=jnp.asarray(m, jnp.int32))


def sort_items_by_norm(items: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Alg. 1 lines 1-2: descending-norm ordering of P.

    Returns (items_sorted, order) with ‖items_sorted[i]‖ ≥ ‖items_sorted[i+1]‖.
    """
    norms = jnp.linalg.norm(items.astype(jnp.float32), axis=1)
    order = jnp.argsort(-norms)
    return items[order], order


def build_rank_table(users: jax.Array, items: jax.Array,
                     cfg: RankTableConfig, key: jax.Array) -> RankTable:
    """Full Algorithm 1: sort by norm, partition, sample, estimate.

    O((n+m)d + m log m) total work; the only O(n·) stage is the (n, ω·s)
    sample-score matmul plus the per-row τ-threshold evaluation.
    """
    items_sorted, _ = sort_items_by_norm(items)
    return build_rank_table_sorted(users, items_sorted, cfg, key)
