"""Rank-table pre-processing — Algorithm 1 of the paper, vectorized for TPU.

The paper's per-user, per-sample, per-threshold triple loop (Alg. 1 lines
8-19, with a data-dependent `break`) is re-expressed as three dense stages
that map onto the MXU/VPU:

  1. norm pass + descending sort of P, ω equal partitions, s samples each
     (lines 1-6) — O(md + m log m), shared across all users;
  2. per-user threshold grids from f_min/f_max (lines 9-11) — O(n·τ);
  3. score matrix  U @ Samplesᵀ  (n, ω·s) on the MXU, then a per-row
     sort + weighted suffix-sum + vectorized searchsorted that evaluates
     Eq. (1) for all τ thresholds at once — O(n·(ωs·log ωs + τ·log ωs))
     instead of the paper's O(n·ωs·τ) scalar compares.

The estimator is exactly Eq. (1): unbiased stratified cardinality
estimation with per-partition weights |P_l| / s.

`build_rank_table` is the public entry; `kernels/table_build.py` provides a
Pallas fusion of stage 3 for the TPU hot path (same semantics, tested
against this implementation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from typing import NamedTuple, Optional

from repro.core.types import DeltaCorrection, RankTable, RankTableConfig, \
    partition_sizes


def stratified_sample_indices(key: jax.Array, m: int, cfg: RankTableConfig
                              ) -> tuple[jax.Array, jax.Array]:
    """Sample s item positions per norm-partition (Alg. 1 lines 4-6).

    Positions index into the *norm-descending sorted* item order.

    Returns:
      positions: (ω·s,) int32 positions in [0, m).
      weights:   (ω·s,) float32 — the Eq. (1) stratum weights |P_l| / s.
    """
    sizes = partition_sizes(m, cfg.omega)
    keys = jax.random.split(key, cfg.omega)
    pos_parts, w_parts = [], []
    start = 0
    for l, size in enumerate(sizes):
        replace = cfg.sample_with_replacement or cfg.s > size
        local = jax.random.choice(keys[l], size, (cfg.s,), replace=replace)
        pos_parts.append(start + local)
        w_parts.append(jnp.full((cfg.s,), size / cfg.s, dtype=jnp.float32))
        start += size
    return (jnp.concatenate(pos_parts).astype(jnp.int32),
            jnp.concatenate(w_parts))


def threshold_grid(smin: jax.Array, smax: jax.Array, tau: int) -> jax.Array:
    """Per-user uniform thresholds t_{u,j} (Alg. 1 lines 9-11).

    t_{u,j} = f_min + (j-1) · (f_max - f_min) / (τ-1),  j ∈ [1, τ].
    """
    frac = jnp.arange(tau, dtype=jnp.float32) / (tau - 1)
    return smin[:, None] + frac[None, :] * (smax - smin)[:, None]


def estimate_table_rows(scores: jax.Array, weights: jax.Array,
                        thresholds: jax.Array) -> jax.Array:
    """Eq. (1) for a block of users and all τ thresholds.

    Args:
      scores:     (n, ω·s) — u_i · p for the stratified samples.
      weights:    (ω·s,)   — stratum weights |P_l| / s.
      thresholds: (n, τ)   — ascending per-user thresholds.

    Returns:
      (n, τ) float32 table rows:  T̂_{i,j} = 1 + Σ_l (|P_l|/s)·#{p ∈ P_l^s :
      u_i·p > t_{i,j}}  — non-increasing along j.
    """
    order = jnp.argsort(scores, axis=1)
    scores_sorted = jnp.take_along_axis(scores, order, axis=1)
    w_sorted = weights[order]                               # (n, ω·s)
    # suffix[i, j] = Σ_{r >= j} w_sorted[i, r];  suffix[:, ωs] = 0.
    suffix = jnp.concatenate(
        [jnp.cumsum(w_sorted[:, ::-1], axis=1)[:, ::-1],
         jnp.zeros_like(w_sorted[:, :1])], axis=1)
    # side='right': idx = #{scores <= t}, so samples at positions >= idx are
    # strictly greater than t — exactly the indicator u·p > t of Eq. (1).
    idx = jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
        scores_sorted, thresholds)                          # (n, τ)
    return 1.0 + jnp.take_along_axis(suffix, idx, axis=1)


def _threshold_range(users: jax.Array, items_sorted: jax.Array,
                     sample_scores: jax.Array, cfg: RankTableConfig
                     ) -> tuple[jax.Array, jax.Array]:
    """f_min / f_max per user, per cfg.threshold_mode (§4.2 step 2 + fn. 1)."""
    if cfg.threshold_mode == "exact":
        full = users @ items_sorted.T                       # O(nmd): tests only
        return full.min(axis=1), full.max(axis=1)
    if cfg.threshold_mode == "norm_bound":
        bound = jnp.linalg.norm(users, axis=1) * jnp.linalg.norm(
            items_sorted[0])                                # max ‖p‖ is row 0
        return -bound, bound
    smin = sample_scores.min(axis=1)
    smax = sample_scores.max(axis=1)
    pad = cfg.range_pad * jnp.maximum(smax - smin, 1e-6)
    return smin - pad, smax + pad


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_rank_table_sorted(users: jax.Array, items_sorted: jax.Array,
                            cfg: RankTableConfig, key: jax.Array) -> RankTable:
    """Algorithm 1 given P already sorted in descending norm order."""
    m = items_sorted.shape[0]
    positions, weights = stratified_sample_indices(key, m, cfg)
    samples = items_sorted[positions]                       # (ω·s, d)
    scores = (users @ samples.T).astype(jnp.float32)        # (n, ω·s) — MXU
    smin, smax = _threshold_range(users, items_sorted, scores, cfg)
    thresholds = threshold_grid(smin, smax, cfg.tau)
    table = estimate_table_rows(scores, weights, thresholds)
    # Algorithm 1 always estimates in f32; the storage SPEC decides how
    # the result is materialized (f32/bf16/int8-with-per-row-scales) —
    # the one pack path shared with the sharded build and the upsert.
    return cfg.storage.pack_table(thresholds, table,
                                  m=jnp.asarray(m, jnp.int32))


def sort_items_by_norm(items: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Alg. 1 lines 1-2: descending-norm ordering of P.

    Returns (items_sorted, order) with ‖items_sorted[i]‖ ≥ ‖items_sorted[i+1]‖.
    """
    norms = jnp.linalg.norm(items.astype(jnp.float32), axis=1)
    order = jnp.argsort(-norms)
    return items[order], order


def build_rank_table(users: jax.Array, items: jax.Array,
                     cfg: RankTableConfig, key: jax.Array) -> RankTable:
    """Full Algorithm 1: sort by norm, partition, sample, estimate.

    O((n+m)d + m log m) total work; the only O(n·) stage is the (n, ω·s)
    sample-score matmul plus the per-row τ-threshold evaluation.
    """
    items_sorted, _ = sort_items_by_norm(items)
    return build_rank_table_sorted(users, items_sorted, cfg, key)


# ------------------------------------------------- dynamic-index support
class SamplingArtifacts(NamedTuple):
    """The build's sampling state, retained so a live index can be mutated
    without a rebuild (see `repro.index`): per-user table rows can be
    re-estimated for upserted users against the SAME stratified sample
    (bit-consistent with the rest of the table), and item deletions can be
    tombstoned against the sampled positions for error accounting.

    Deterministic in (items, cfg, key): re-deriving with the build key
    reproduces exactly what `build_rank_table` sampled, for both the dense
    and the sharded build path (they share `stratified_sample_indices` and
    the norm-descending order).

    samples:   (ω·s, d) sampled item vectors.
    weights:   (ω·s,) Eq. (1) stratum weights |P_l| / s.
    order:     (m,) norm-descending permutation of the item set.
    positions: (ω·s,) sampled positions, indexing into the SORTED order.
    max_norm:  () float32 — max ‖p‖, for threshold_mode="norm_bound".
    """

    samples: jax.Array
    weights: jax.Array
    order: jax.Array
    positions: jax.Array
    max_norm: jax.Array


def sampling_artifacts(items: jax.Array, cfg: RankTableConfig,
                       key: jax.Array) -> SamplingArtifacts:
    """Re-derive the sampling state `build_rank_table(…, key)` used."""
    items_sorted, order = sort_items_by_norm(items)
    positions, weights = stratified_sample_indices(key, items.shape[0], cfg)
    samples = items_sorted[positions]
    max_norm = jnp.linalg.norm(items_sorted[0].astype(jnp.float32))
    return SamplingArtifacts(samples=samples, weights=weights, order=order,
                             positions=positions, max_norm=max_norm)


@functools.partial(jax.jit, static_argnames=("cfg",))
def recompute_user_rows(user_rows: jax.Array, samples: jax.Array,
                        weights: jax.Array, cfg: RankTableConfig,
                        items: Optional[jax.Array] = None,
                        max_norm: Optional[jax.Array] = None
                        ) -> tuple[jax.Array, jax.Array]:
    """Stages 2-3 of Algorithm 1 for a block of (possibly new) user rows.

    Runs the SAME per-row math as `build_rank_table_sorted` against the
    retained sample set, so an upserted user's threshold/table rows are
    computed exactly as a from-scratch rebuild would compute them — no
    other row is touched. O(t·(ω·s)·(d + log ω·s)) for t rows.

    `items` is required for threshold_mode="exact" (min/max over the full
    score row is order-invariant, so any item order works); `max_norm` for
    threshold_mode="norm_bound". Returns float32 (thresholds, table) rows;
    the caller casts to the table's storage dtype.
    """
    scores = (user_rows @ samples.T).astype(jnp.float32)    # (t, ω·s)
    if cfg.threshold_mode == "exact":
        full = user_rows @ items.T
        smin, smax = full.min(axis=1), full.max(axis=1)
    elif cfg.threshold_mode == "norm_bound":
        bound = jnp.linalg.norm(user_rows.astype(jnp.float32),
                                axis=1) * max_norm
        smin, smax = -bound, bound
    else:
        smin = scores.min(axis=1)
        smax = scores.max(axis=1)
        pad = cfg.range_pad * jnp.maximum(smax - smin, 1e-6)
        smin, smax = smin - pad, smax + pad
    thresholds = threshold_grid(smin, smax, cfg.tau)
    table = estimate_table_rows(scores, weights, thresholds)
    return thresholds, table


def _count_above(sorted_scores: jax.Array, scores: jax.Array) -> jax.Array:
    """#{x ∈ row : x > v} per (row, query) given ascending per-row sets.

    sorted_scores (n, t); scores (n, B) → (n, B) float32 counts.

    method="scan_unrolled": the rolled scan re-reads loop state every
    round and a direct (n, t, B) compare-reduce materializes the whole
    broadcast — measured 2× and 28× slower respectively at (8k, 100, 16)
    on CPU XLA. The unrolled binary search keeps the delta count at ~20%
    of a τ = 500 static query (see perf_engine --updates).
    """
    if sorted_scores.shape[1] == 0:
        return jnp.zeros(scores.shape, jnp.float32)
    idx = jax.vmap(functools.partial(jnp.searchsorted, side="right",
                                     method="scan_unrolled"))(
        sorted_scores, scores)                  # #{x <= v}: not counted
    return (sorted_scores.shape[1] - idx).astype(jnp.float32)


def _count_above_range(sorted_q: jax.Array, scale, off, scores: jax.Array,
                       slack) -> tuple[jax.Array, jax.Array]:
    """Certified (count_lo, count_hi) brackets of #{x_true > s_true} per
    (row, query), for SPEC-SPACE stored score sets (quantized delta rows).

    x_true is the f32 score the stored entry quantized; s_true is the f32
    query score bracketed by `scores ± slack`. count_lo counts entries
    CERTAINLY above, count_hi those POSSIBLY above — the delta shift then
    widens r↓ by count_lo terms and r↑ by count_hi terms, keeping the
    corrected bounds certified (see `apply_delta_corrections`).

    int8 rows are left-padded with the reserved −128 sentinel: a compare
    value clipped to [−128, 127] always lands the sentinel in the
    not-above set, so padding can never inflate either count. bf16 rows
    pad with −inf and use the monotone-cast compare.
    """
    width = sorted_q.shape[1]
    if width == 0:
        z = jnp.zeros(scores.shape, jnp.float32)
        return z, z
    s_lo = scores if slack is None else scores - slack
    s_hi = scores if slack is None else scores + slack
    ss = lambda vals, side: jax.vmap(functools.partial(
        jnp.searchsorted, side=side, method="scan_unrolled"))(sorted_q, vals)
    if scale is None:                           # bf16 storage
        st = sorted_q.dtype
        # possibly above: x_true > s_true ⟹ x̃ = cast(x_true) ≥ cast(s−δ)
        hi = width - ss(s_lo.astype(st), "left")
        # certainly above: x̃ > cast(s+δ) ⟹ x_true > s+δ ≥ s_true
        lo = width - ss(s_hi.astype(st), "right")
    else:                                       # int8 per-row affine codes
        from repro.core.types import _I8_TRANSFORM_PAD
        half = 0.5 + _I8_TRANSFORM_PAD
        code = lambda v: jnp.clip(jnp.floor((v - off) / scale),
                                  -128.0, 127.0).astype(jnp.int8)
        # possibly above: x̃·sc+off+sc/2 > s−δ ⟺ x̃ > (s−δ−off)/sc − ½
        hi = width - ss(code(s_lo - half * scale), "right")
        # certainly above: x̃·sc+off−sc/2 > s+δ ⟺ x̃ > (s+δ−off)/sc + ½
        lo = width - ss(code(s_hi + half * scale), "right")
    return lo.astype(jnp.float32), hi.astype(jnp.float32)


def apply_delta_corrections(scores: jax.Array, r_lo: jax.Array,
                            r_up: jax.Array, est: jax.Array,
                            corr: DeltaCorrection,
                            slack: Optional[jax.Array] = None
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fuse a delta buffer into table-estimated ranks (user-major).

    This is the ONE delta-aware estimation path: every backend (dense,
    fused, sharded — the latter per shard_map row block) routes its step-1
    bounds through it, so the backends cannot drift on mutated indexes.

    All inputs are user-major: scores/r_lo/r_up/est are (n_rows, B); corr
    rows align with the same user rows (the sharded caller passes its row
    shard of the correction arrays).

    The exact additive shift  #{a ∈ A : u·a > u·q} − #{p ∈ D : u·p > u·q}
    moves base-set bounds to merged-set bounds: if r↓ ≤ r(q,u,P₀) ≤ r↑
    then r↓+Δ ≤ r(q,u,P') ≤ r↑+Δ (clipped to the legal [1, m'+1] range).
    The ESTIMATE is shifted but deliberately NOT clipped: clamping would
    collapse every deletion-corrected top-ranked user onto exactly 1.0,
    and tied estimates are where the dense composite-key top-k and the
    sharded per-shard est-merge legitimately break ties differently —
    unclipped, the ordering stays strictly monotone and all backends
    select identically (an estimate marginally below 1 is ordinary
    estimator noise; the clipped bounds still bracket the true rank).
    Deleted users are forced to +inf, which is the ONLY sentinel that
    dominates unconditionally: r↑ = inf fails the Lemma-1 accept test
    for every finite c·R↓_k (a finite sentinel like m'+2 can be
    "accepted" when c·R↓_k exceeds it, jumping dead users ahead of live
    U_temp users), r↓ = inf is always pruned, and est = inf sorts after
    every live estimate — including insertion-shifted estimates above
    m'+1, which a finite sentinel does not dominate — identically on
    every backend.

    SPEC SPACE (PR 5): quantized engines store the delta score sets in
    the storage spec and the user scores carry a certified `slack`. The
    exact count is then replaced by a certified count RANGE
    (`_count_above_range`): r↓ shifts by the smallest possible net count,
    r↑ by the largest, est by the midpoint — the corrected bounds still
    bracket every shift the exact f32 engine could have applied. The f32
    spec takes the pre-spec exact branch verbatim (bit-identity).
    """
    quantized = (corr.add_scale is not None or corr.del_scale is not None
                 or corr.add_scores.dtype != jnp.float32
                 or corr.del_scores.dtype != jnp.float32
                 or slack is not None)
    if not quantized:
        shift_lo = shift_hi = shift_mid = (
            _count_above(corr.add_scores, scores)
            - _count_above(corr.del_scores, scores))
    else:
        add_lo, add_hi = _count_above_range(
            corr.add_scores, corr.add_scale, corr.add_off, scores, slack)
        del_lo, del_hi = _count_above_range(
            corr.del_scores, corr.del_scale, corr.del_off, scores, slack)
        shift_lo = add_lo - del_hi
        shift_hi = add_hi - del_lo
        shift_mid = 0.5 * (shift_lo + shift_hi)
    m_new = corr.m_new.astype(jnp.float32)
    r_lo = jnp.clip(r_lo + shift_lo, 1.0, m_new + 1.0)
    r_up = jnp.clip(r_up + shift_hi, 1.0, m_new + 1.0)
    est = est + shift_mid
    dead = ~corr.user_live[:, None]
    return (jnp.where(dead, jnp.inf, r_lo),
            jnp.where(dead, jnp.inf, r_up),
            jnp.where(dead, jnp.inf, est))
