"""QSRP baseline (Bian et al., ICDE'24), extended to c-approximate queries.

The paper's comparison target. Faithful to its description in §1/§3/§5:

  * OFFLINE — computes the inner products of ALL user-item pairs
    (Ω(nmd); the cost the paper criticizes) and summarizes each user's
    sorted inner-product list at `levels` rank-quantile positions. With
    `levels = 2τ` the summary matches the rank table's memory footprint
    (thresholds + table = 2 floats/column), the "fair comparison" setup
    of §5.
  * ONLINE — quantile lookup gives *exact* rank bounds of width ≤ m/levels;
    Lemma-1 filtering prunes; every surviving (undetermined) user is
    resolved with an exact O(md) linear scan of P. Hence accuracy is always
    1 (§5.3) and worst-case online time is O(nmd).

The refinement stage has a data-dependent candidate count, so the online
path is host-driven (candidates padded to power-of-two buckets to bound
recompilation); the heavy inner loops are jitted.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import kth_smallest


class QSRPIndex(NamedTuple):
    """Per-user rank-quantile summary of the full inner-product matrix.

    quantile_scores: (n, levels) float32 — u_i's inner products at rank
      positions `ranks_at` of the descending-sorted list of {u_i·p}.
    ranks_at: (levels,) int32 — the rank positions (1-indexed, ascending).
    m: () int32.
    """

    quantile_scores: jax.Array
    ranks_at: jax.Array
    m: jax.Array


@functools.partial(jax.jit, static_argnames=("levels",))
def _summarize_block(ublk: jax.Array, items: jax.Array, levels: int
                     ) -> jax.Array:
    ips = ublk @ items.T                                   # (blk, m)
    m = items.shape[0]
    sorted_desc = -jnp.sort(-ips, axis=1)                  # descending
    pos = jnp.round(jnp.arange(levels) * (m - 1) / (levels - 1)).astype(
        jnp.int32)
    return sorted_desc[:, pos].astype(jnp.float32)


def build_qsrp_index(users: jax.Array, items: jax.Array, levels: int = 1000,
                     block: int = 1024) -> QSRPIndex:
    """The Ω(nmd) pre-processing pass (all-pairs inner products)."""
    n, m = users.shape[0], items.shape[0]
    out = []
    for s in range(0, n, block):
        out.append(np.asarray(_summarize_block(users[s:s + block], items,
                                               levels)))
    pos = np.round(np.arange(levels) * (m - 1) / (levels - 1)).astype(np.int32)
    return QSRPIndex(
        quantile_scores=jnp.asarray(np.concatenate(out, axis=0)),
        ranks_at=jnp.asarray(pos + 1, dtype=jnp.int32),    # 1-indexed ranks
        m=jnp.asarray(m, jnp.int32),
    )


@jax.jit
def _bounds_from_summary(idx: QSRPIndex, uq: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """Exact rank bounds from the quantile summary.

    quantile_scores rows are DESCENDING (rank position ascending). If
    scores[j] > u·q ≥ scores[j+1], then rank ∈ (ranks_at[j], ranks_at[j+1]]
    — bounds are exact because the summary stores true order statistics.
    """
    desc = idx.quantile_scores                              # (n, levels)
    asc = desc[:, ::-1]
    # #quantiles with score > uq  (strict, matching Definition 1):
    gt = jax.vmap(functools.partial(jnp.searchsorted, side="left"))(
        asc, uq)
    levels = desc.shape[1]
    j = levels - gt                                         # in [0, levels]
    r_lo = jnp.where(j == 0, 1.0,
                     idx.ranks_at[jnp.clip(j - 1, 0, levels - 1)].astype(
                         jnp.float32))
    r_up = jnp.where(j == levels, (idx.m + 1).astype(jnp.float32),
                     idx.ranks_at[jnp.clip(j, 0, levels - 1)].astype(
                         jnp.float32))
    return r_lo, r_up


@functools.partial(jax.jit, static_argnames=("block",))
def _exact_ranks_for(users_sel: jax.Array, items: jax.Array, q: jax.Array,
                     block: int = 1024) -> jax.Array:
    uq = users_sel @ q
    nsel = users_sel.shape[0]
    nb = -(-nsel // block)
    pad = nb * block - nsel
    upad = jnp.pad(users_sel, ((0, pad), (0, 0)))
    uqpad = jnp.pad(uq, (0, pad))

    def body(_, xs):
        ublk, uqblk = xs
        r = 1 + jnp.sum((ublk @ items.T) > uqblk[:, None], axis=1)
        return None, r.astype(jnp.float32)

    _, r = jax.lax.scan(body, None,
                        (upad.reshape(nb, block, -1), uqpad.reshape(nb, block)))
    return r.reshape(-1)[:nsel]


def qsrp_query(idx: QSRPIndex, users: jax.Array, items: jax.Array,
               q: jax.Array, k: int, c: float
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """c-approximate reverse k-ranks with QSRP semantics (accuracy 1).

    Returns (indices, ranks, n_refined): the selected users, their EXACT
    ranks, and how many users needed the O(md) refinement scan.
    """
    uq = jnp.asarray(users @ q, jnp.float32)
    r_lo, r_up = _bounds_from_summary(idx, uq)
    R_lo_k = kth_smallest(r_lo, k)
    R_up_k = kth_smallest(r_up, k)

    accepted = np.asarray(r_up <= c * R_lo_k)
    pruned = np.asarray(r_lo > R_up_k)
    r_up_np = np.asarray(r_up)

    accepted_idx = np.where(accepted)[0]
    if len(accepted_idx) >= k:
        # Lemma 1 (1): every accepted user is admissible — no refinement.
        # Order by the (exact) upper bound; any k of them satisfy Def. 3.
        order = accepted_idx[np.lexsort(
            (accepted_idx, r_up_np[accepted_idx]))][:k]
        ranks = np.asarray(_exact_ranks_for(users[order], items, q))
        return order.astype(np.int32), ranks, 0

    # Not enough guaranteed users: refine every undetermined candidate with
    # an exact O(md) scan — the O(nmd)-worst-case tail the paper criticizes.
    cand = np.where(~pruned)[0]
    keys = np.full(users.shape[0], np.inf, dtype=np.float64)
    if len(cand):
        # Padding to power-of-two buckets bounds recompilation of the
        # jitted scan; an empty candidate set skips the launch entirely
        # (everyone pruned ⇒ nothing to refine, no dummy 32-row bucket).
        bucket = 1 << max(int(np.ceil(np.log2(len(cand)))), 5)
        cand_pad = np.pad(cand, (0, bucket - len(cand)),
                          constant_values=cand[0])
        exact = np.asarray(_exact_ranks_for(users[cand_pad], items, q))
        keys[cand] = exact[:len(cand)]
    order = np.lexsort((np.arange(len(keys)), keys))[:k]
    return order.astype(np.int32), keys[order], int(len(cand))
