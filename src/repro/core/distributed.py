"""Multi-pod sharded reverse k-ranks: the engine at 512-chip scale.

Layout (see DESIGN.md §3):
  * users + rank-table rows are ROW-SHARDED over a flat 1-D view of the
    mesh ("shard" = pod×data×model flattened) — n/512 users per chip;
  * items are sharded the same way for the build's norm pass and for exact
    refinement; stratified samples are small and replicated;
  * a query vector is replicated; step 1 (u·q + table lookup) is fully
    local; the global top-k runs as a TREE MERGE: per-shard top-k
    (k values) → gather of k·P candidates (not n) → re-top-k.

Collective budget per query: one gather of O(k·P) floats plus the final
selection — O(k·P) bytes on the wire instead of O(n); per-chip compute is
O(nd/P + kP). The build's only collective is the O(m)-scalar norm gather
for the global sort (item vectors never gather).

Functions take the production mesh; internally the engine re-views its
devices as a 1-D "shard" mesh, which is the natural layout for an index
that has no tensor dimension to model-parallelize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import rank_table as rt_mod
from repro.core.query import lookup_bounds
from repro.core.types import QueryResult, RankTable, RankTableConfig

AXIS = "shard"


def flat_mesh(mesh_or_devices) -> Mesh:
    """1-D engine view of a (possibly multi-axis) mesh's devices."""
    import numpy as np
    if isinstance(mesh_or_devices, Mesh):
        devs = mesh_or_devices.devices.reshape(-1)
    else:
        devs = np.asarray(mesh_or_devices).reshape(-1)
    return Mesh(devs, (AXIS,))


def user_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(AXIS, None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ------------------------------------------------------------------- build
def build_sharded(users: jax.Array, items: jax.Array, cfg: RankTableConfig,
                  key: jax.Array, mesh: Mesh) -> RankTable:
    """Algorithm 1 on a flat mesh.

    Norm pass is item-sharded (O(md/P) per chip); the global norm-sort
    runs on the m gathered SCALARS; the per-user table build is
    embarrassingly row-parallel (zero collectives).
    """
    m = items.shape[0]

    norms_local = jax.shard_map(
        lambda it: jnp.linalg.norm(it.astype(jnp.float32), axis=1),
        mesh=mesh, in_specs=P(AXIS, None), out_specs=P(AXIS))
    norms = norms_local(items)
    order = jnp.argsort(-norms)                    # m scalars: cheap, global

    positions, weights = rt_mod.stratified_sample_indices(key, m, cfg)
    samples = items[order[positions]]              # (ω·s, d) — replicated
    max_norm = norms[order[0]]

    def local_build(u_shard, smp, w, mx):
        scores = (u_shard @ smp.T).astype(jnp.float32)
        if cfg.threshold_mode == "norm_bound":
            bound = jnp.linalg.norm(u_shard.astype(jnp.float32),
                                    axis=1) * mx
            smin, smax = -bound, bound
        else:
            smin = scores.min(axis=1)
            smax = scores.max(axis=1)
            pad = cfg.range_pad * jnp.maximum(smax - smin, 1e-6)
            smin, smax = smin - pad, smax + pad
        thr = rt_mod.threshold_grid(smin, smax, cfg.tau)
        table = rt_mod.estimate_table_rows(scores, w, thr)
        st = jnp.dtype(cfg.storage_dtype)
        return thr.astype(st), table.astype(st)

    thr, table = jax.shard_map(
        local_build, mesh=mesh,
        in_specs=(P(AXIS, None), P(None, None), P(None), P()),
        out_specs=(P(AXIS, None), P(AXIS, None)))(
            users, samples, weights, max_norm)
    return RankTable(thresholds=thr, table=table,
                     m=jnp.asarray(m, jnp.int32))


# ------------------------------------------------------------------- query
def make_query_fn(mesh: Mesh, k: int, n: int, c: float):
    """Builds the jit'd sharded query: (rank_table, users, q) → QueryResult.

    Stage 1 (shard_map): local u·q + table lookup + per-shard top-k; the
    out_specs stack each shard's k candidates into a global (k·P) set —
    the tree-merge gather.
    Stage 2 (plain jit): O(k·P) global selection with the §4.3 Lemma-1
    masks; GSPMD replicates it after an all-gather of k·P floats.
    """
    nshards = mesh.devices.size
    shard_n = n // nshards

    def local_part(thr, tab, m_items, u_shard, q):
        uq = (u_shard @ q).astype(jnp.float32)
        r_lo, r_up, est = lookup_bounds(RankTable(thr, tab, m_items), uq)
        neg_lo, _ = jax.lax.top_k(-r_lo, k)        # k smallest lower bounds
        neg_up, _ = jax.lax.top_k(-r_up, k)
        neg_est, cand = jax.lax.top_k(-est, k)     # k best candidates
        shard_id = jax.lax.axis_index(AXIS)
        gidx = cand.astype(jnp.int32) + shard_id * shard_n
        payload = jnp.stack(
            [-neg_est, r_lo[cand], r_up[cand]], axis=1)        # (k, 3)
        return -neg_lo, -neg_up, payload, gidx

    sharded = jax.shard_map(
        local_part, mesh=mesh,
        in_specs=(P(AXIS, None), P(AXIS, None), P(), P(AXIS, None), P()),
        out_specs=(P(AXIS), P(AXIS), P(AXIS, None), P(AXIS)))

    @jax.jit
    def query_fn(rt: RankTable, users: jax.Array, q: jax.Array
                 ) -> QueryResult:
        all_lo, all_up, payload, gidx = sharded(
            rt.thresholds, rt.table, rt.m, users, q)           # (k·P, …)
        est, r_lo, r_up = payload[:, 0], payload[:, 1], payload[:, 2]
        neg, _ = jax.lax.top_k(-all_lo, k)
        R_lo_k = -neg[k - 1]
        neg, _ = jax.lax.top_k(-all_up, k)
        R_up_k = -neg[k - 1]
        guaranteed = c * R_lo_k >= R_up_k
        accepted = r_up <= c * R_lo_k
        pruned = r_lo > R_up_k
        prio = jnp.where(accepted, 0.0, jnp.where(pruned, 2.0, 1.0))
        big = (rt.m + 2).astype(jnp.float32)
        key_val = jnp.where(guaranteed, est, prio * big + est)
        _, sel = jax.lax.top_k(-key_val, k)
        return QueryResult(
            indices=gidx[sel].astype(jnp.int32),
            est_rank=est[sel],
            r_lo=r_lo, r_up=r_up,              # candidate-set bounds (k·P)
            R_lo_k=R_lo_k, R_up_k=R_up_k,
            guaranteed=guaranteed,
            n_accepted=jnp.sum(accepted).astype(jnp.int32),
            n_pruned=jnp.sum(pruned).astype(jnp.int32),
        )

    return query_fn


def make_batch_query_fn(mesh: Mesh, k: int, n: int, c: float, q_batch: int):
    """§Perf H6 — batched sharded queries: (rank_table, users, Q (b, d)) →
    QueryResult with leading batch axis.

    The paper (and `make_query_fn`) process queries one at a time: every
    query re-streams the user matrix and table rows (memory-bound matvec).
    Batching b queries turns step 1 into one U_shard @ Qᵀ MATMUL — the
    n·(d+2τ) byte stream is read ONCE for all b queries, so the per-query
    memory term drops ~b× while compute (still tiny) grows b×. This is the
    arithmetic-intensity lever the roofline demands for the engine.
    """
    nshards = mesh.devices.size
    shard_n = n // nshards

    def local_part(thr, tab, m_items, u_shard, qs):
        scores = (u_shard @ qs.T).astype(jnp.float32)       # (n_loc, b) MXU
        rt_local = RankTable(thr, tab, m_items)

        def per_query(uq):
            r_lo, r_up, est = lookup_bounds(rt_local, uq)
            neg_lo, _ = jax.lax.top_k(-r_lo, k)
            neg_up, _ = jax.lax.top_k(-r_up, k)
            neg_est, cand = jax.lax.top_k(-est, k)
            payload = jnp.stack([-neg_est, r_lo[cand], r_up[cand]], axis=1)
            return -neg_lo, -neg_up, payload, cand.astype(jnp.int32)

        lo, up, payload, cand = jax.vmap(per_query)(scores.T)   # (b, k, …)
        shard_id = jax.lax.axis_index(AXIS)
        gidx = cand + shard_id * shard_n
        return lo, up, payload, gidx

    sharded = jax.shard_map(
        local_part, mesh=mesh,
        in_specs=(P(AXIS, None), P(AXIS, None), P(), P(AXIS, None),
                  P(None, None)),
        out_specs=(P(None, AXIS), P(None, AXIS), P(None, AXIS, None),
                   P(None, AXIS)))

    @jax.jit
    def batch_query_fn(rt: RankTable, users: jax.Array, qs: jax.Array
                       ) -> QueryResult:
        all_lo, all_up, payload, gidx = sharded(
            rt.thresholds, rt.table, rt.m, users, qs)       # (b, k·P, …)

        def select(lo_b, up_b, payload_b, gidx_b):
            est, r_lo, r_up = (payload_b[:, 0], payload_b[:, 1],
                               payload_b[:, 2])
            neg, _ = jax.lax.top_k(-lo_b, k)
            R_lo_k = -neg[k - 1]
            neg, _ = jax.lax.top_k(-up_b, k)
            R_up_k = -neg[k - 1]
            guaranteed = c * R_lo_k >= R_up_k
            accepted = r_up <= c * R_lo_k
            pruned = r_lo > R_up_k
            prio = jnp.where(accepted, 0.0, jnp.where(pruned, 2.0, 1.0))
            big = (rt.m + 2).astype(jnp.float32)
            key_val = jnp.where(guaranteed, est, prio * big + est)
            _, sel = jax.lax.top_k(-key_val, k)
            return QueryResult(
                indices=gidx_b[sel], est_rank=est[sel],
                r_lo=r_lo, r_up=r_up, R_lo_k=R_lo_k, R_up_k=R_up_k,
                guaranteed=guaranteed,
                n_accepted=jnp.sum(accepted).astype(jnp.int32),
                n_pruned=jnp.sum(pruned).astype(jnp.int32))

        return jax.vmap(select)(all_lo, all_up, payload, gidx)

    return batch_query_fn


# -------------------------------------------------------------- refinement
def ring_exact_ranks(users: jax.Array, items: jax.Array, q: jax.Array,
                     mesh: Mesh) -> jax.Array:
    """Exact Definition-1 ranks with BOTH users and items sharded: item
    shards rotate around a ring (collective_permute) while every user
    shard accumulates counts — compute/comm overlap with items never
    materializing unsharded. Used for boundary-user refinement and as the
    at-scale exact baseline."""
    nshards = mesh.devices.size
    perm = [(i, (i + 1) % nshards) for i in range(nshards)]

    def local(u_shard, it_shard, qv):
        uq = (u_shard @ qv).astype(jnp.float32)

        def body(_, carry):
            counts, blk = carry
            scores = (u_shard @ blk.T).astype(jnp.float32)
            counts = counts + jnp.sum(scores > uq[:, None], axis=1)
            blk = jax.lax.ppermute(blk, AXIS, perm)
            return counts, blk

        counts, _ = jax.lax.fori_loop(
            0, nshards, body, (jnp.zeros_like(uq), it_shard))
        return 1.0 + counts

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(AXIS, None), P(AXIS, None), P()),
        out_specs=P(AXIS))(users, items, q)
