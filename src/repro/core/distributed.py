"""Multi-pod sharded reverse k-ranks: the engine at 512-chip scale.

Layout (see DESIGN.md §3):
  * users + rank-table rows are ROW-SHARDED over a flat 1-D view of the
    mesh ("shard" = pod×data×model flattened) — n/512 users per chip;
  * items are sharded the same way for the build's norm pass and for exact
    refinement; stratified samples are small and replicated;
  * a query vector is replicated; step 1 (u·q + table lookup) is fully
    local; the global top-k runs as a TREE MERGE: per-shard top-k
    (k values) → gather of k·P candidates (not n) → re-top-k.

Collective budget per BATCH of B queries: one gather of O(B·k·P) floats
plus the final selection — O(B·k·P) bytes on the wire instead of O(B·n),
and the collective count is independent of B (single-query execution is
just B = 1). Per-chip compute is O(B·nd/P + BkP). The build's only
collective is the O(m)-scalar norm gather for the global sort (item
vectors never gather).

Functions take the production mesh; internally the engine re-views its
devices as a 1-D "shard" mesh, which is the natural layout for an index
that has no tensor dimension to model-parallelize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import rank_table as rt_mod
from repro.core.query import lemma1_key, lemma1_select, \
    lookup_bounds_batch, user_scores_batch
from repro.core.types import DeltaCorrection, QueryResult, RankTable, \
    RankTableConfig, StoredUsers, kth_smallest, take_user_rows

AXIS = "shard"

# jax.shard_map graduated from jax.experimental after 0.4.x; support both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                        # pragma: no cover - version dep
    from jax.experimental.shard_map import shard_map as _shard_map


def flat_mesh(mesh_or_devices) -> Mesh:
    """1-D engine view of a (possibly multi-axis) mesh's devices."""
    import numpy as np
    if isinstance(mesh_or_devices, Mesh):
        devs = mesh_or_devices.devices.reshape(-1)
    else:
        devs = np.asarray(mesh_or_devices).reshape(-1)
    return Mesh(devs, (AXIS,))


def user_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(AXIS, None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ------------------------------------------------- storage-spec sharding
# The storage tier is row-aligned by construction: every optional field
# (int8 affine scale/offset vectors, per-user score-slack coefficients)
# is (n, 1) and shards EXACTLY like the rows it describes. These helpers
# build the pytree in_specs for shard_map from the actual argument
# structure, so one query fn serves every StorageSpec.

def _rt_specs(rt: RankTable) -> RankTable:
    s = lambda a: None if a is None else P(AXIS, None)
    return RankTable(thresholds=P(AXIS, None), table=P(AXIS, None), m=P(),
                     **{f: s(getattr(rt, f))
                        for f in RankTable._QUANT_FIELDS})


def _user_specs(users):
    if not isinstance(users, StoredUsers):
        return P(AXIS, None)
    s = lambda a: None if a is None else P(AXIS, None)
    return StoredUsers(rows=P(AXIS, None), scale=s(users.scale),
                       row_slack=s(users.row_slack))


def _corr_specs(corr: DeltaCorrection) -> DeltaCorrection:
    s = lambda a: None if a is None else P(AXIS, None)
    return DeltaCorrection(
        add_scores=P(AXIS, None), del_scores=P(AXIS, None),
        user_live=P(AXIS), m_new=P(),
        add_scale=s(corr.add_scale), add_off=s(corr.add_off),
        del_scale=s(corr.del_scale), del_off=s(corr.del_off))


# ------------------------------------------------------------------- build
def build_sharded(users: jax.Array, items: jax.Array, cfg: RankTableConfig,
                  key: jax.Array, mesh: Mesh) -> RankTable:
    """Algorithm 1 on a flat mesh.

    Norm pass is item-sharded (O(md/P) per chip); the global norm-sort
    runs on the m gathered SCALARS; the per-user table build is
    embarrassingly row-parallel (zero collectives).

    threshold_mode="exact" is refused rather than silently degraded: the
    exact f_min/f_max needs every user row to see the FULL item set,
    which this row-parallel build never materializes (it is an O(nmd)
    oracle mode for small tests — build it dense).
    """
    if cfg.threshold_mode == "exact":
        raise ValueError(
            'build_sharded does not support threshold_mode="exact" (each '
            "user shard only sees its item shard); use the dense "
            "build_rank_table for the exact-threshold oracle mode")
    m = items.shape[0]

    norms_local = _shard_map(
        lambda it: jnp.linalg.norm(it.astype(jnp.float32), axis=1),
        mesh=mesh, in_specs=P(AXIS, None), out_specs=P(AXIS))
    norms = norms_local(items)
    order = jnp.argsort(-norms)                    # m scalars: cheap, global

    positions, weights = rt_mod.stratified_sample_indices(key, m, cfg)
    samples = items[order[positions]]              # (ω·s, d) — replicated
    max_norm = norms[order[0]]

    def local_build(u_shard, smp, w, mx):
        scores = (u_shard @ smp.T).astype(jnp.float32)
        if cfg.threshold_mode == "norm_bound":
            bound = jnp.linalg.norm(u_shard.astype(jnp.float32),
                                    axis=1) * mx
            smin, smax = -bound, bound
        else:
            smin = scores.min(axis=1)
            smax = scores.max(axis=1)
            pad = cfg.range_pad * jnp.maximum(smax - smin, 1e-6)
            smin, smax = smin - pad, smax + pad
        thr = rt_mod.threshold_grid(smin, smax, cfg.tau)
        table = rt_mod.estimate_table_rows(scores, w, thr)
        # the SAME pack path as the dense build — per-row quantization
        # parameters are shard-local, so packing commutes with sharding
        packed = cfg.storage.pack_table(thr, table)
        return tuple(f for f in
                     ((packed.thresholds, packed.table)
                      + tuple(getattr(packed, q)
                              for q in RankTable._QUANT_FIELDS))
                     if f is not None)

    n_out = 2 + len(RankTable._QUANT_FIELDS) \
        if cfg.storage.kind == "int8" else 2
    out = _shard_map(
        local_build, mesh=mesh,
        in_specs=(P(AXIS, None), P(None, None), P(None), P()),
        out_specs=tuple([P(AXIS, None)] * n_out))(
            users, samples, weights, max_norm)
    extra = dict(zip(RankTable._QUANT_FIELDS, out[2:]))
    return RankTable(thresholds=out[0], table=out[1],
                     m=jnp.asarray(m, jnp.int32), **extra)


# ------------------------------------------------------------------- query
def make_batch_query_fn(mesh: Mesh, k: int, n: int, c: float, *,
                        with_delta: bool = False):
    """Builds the jit'd batched sharded query:
    (rank_table, users, Q (B, d) [, delta]) → QueryResult, leading B axis.

    Stage 1 (shard_map): step 1 is ONE local U_shard @ Qᵀ MXU matmul plus
    a single streamed pass over the local threshold/table rows serving all
    B queries (`lookup_bounds_batch`) — the n·(d+2τ)/P byte stream per
    chip is read once per BATCH, not once per query. The per-shard
    k-smallest r↓/r↑ are then all-gathered ((B, k) scalars per shard —
    the kth of the union of per-shard k-smallest IS the global kth), so
    every shard computes the EXACT global R↓_k/R↑_k and selects its k
    candidates by the true §4.3 composite key (accepted ≺ U_temp ≺
    pruned, est within class). Ranking candidates by est alone would
    drop a Lemma-1-accepted user whose estimate is merely mediocre —
    dense and sharded would then legitimately disagree in the
    non-guaranteed regime (caught by tests/test_index.py parity).
    Stage 2: the out_specs stack every shard's candidates into a global
    (B, k·P) set in ONE gather (the tree merge) — not B per-query gathers;
    O(B·k·P) bytes on the wire instead of O(B·n). Global selection reuses
    the shared `lemma1_select` composite key (same R↓_k/R↑_k, same key),
    so the merge preserves the shards' exact ordering.

    With `with_delta=True` the returned fn takes a `DeltaCorrection` whose
    per-user score sets are ROW-SHARDED like the users/table, and the
    shared `apply_delta_corrections` runs inside the shard_map BEFORE the
    per-shard top-k (correcting after candidate selection would pick the
    wrong candidates) — so the mutated-index path keeps the O(B·k·P) wire
    budget: delta score rows never leave their shard.
    """
    nshards = mesh.devices.size
    shard_n = n // nshards

    def local_part(rt_loc, u_shard, qs, *delta):
        scores, slack = user_scores_batch(u_shard, qs)      # (n_loc, B) MXU
        r_lo, r_up, est = lookup_bounds_batch(rt_loc, scores,
                                              slack)        # (n_loc, B)
        if with_delta:
            corr, = delta
            r_lo, r_up, est = rt_mod.apply_delta_corrections(
                scores, r_lo, r_up, est, corr, slack=slack)
            m_eff = corr.selection_m()
        else:
            m_eff = rt_loc.m
        r_lo, r_up, est = r_lo.T, r_up.T, est.T             # (B, n_loc)
        neg_lo, _ = jax.lax.top_k(-r_lo, k)    # k smallest lower bounds / q
        neg_up, _ = jax.lax.top_k(-r_up, k)
        # exact global step-2 statistics: (P, B, k) of per-shard
        # k-smallest → the global kth smallest (order statistic of the
        # union) — O(B·k·P) scalars on the wire, independent of n
        gl = jnp.moveaxis(jax.lax.all_gather(-neg_lo, AXIS), 0, 1)
        gu = jnp.moveaxis(jax.lax.all_gather(-neg_up, AXIS), 0, 1)
        R_lo_k = kth_smallest(gl.reshape(gl.shape[0], -1), k)      # (B,)
        R_up_k = kth_smallest(gu.reshape(gu.shape[0], -1), k)
        # the SHARED composite key (query.lemma1_key) → the local top-k
        # ARE the global top-k's shard members; the merge re-derives the
        # identical key, so local and global ordering cannot drift
        key_val, _, _, _ = lemma1_key(r_lo, r_up, est, R_lo_k=R_lo_k,
                                      R_up_k=R_up_k, c=c, m_items=m_eff)
        _, cand = jax.lax.top_k(-key_val, k)                # k best / query
        shard_id = jax.lax.axis_index(AXIS)
        gidx = cand.astype(jnp.int32) + shard_id * shard_n
        payload = jnp.stack(
            [jnp.take_along_axis(est, cand, axis=-1),
             jnp.take_along_axis(r_lo, cand, axis=-1),
             jnp.take_along_axis(r_up, cand, axis=-1)], axis=-1)  # (B, k, 3)
        return -neg_lo, -neg_up, payload, gidx

    @jax.jit
    def batch_query_fn(rt: RankTable, users, qs: jax.Array,
                       corr: DeltaCorrection = None) -> QueryResult:
        # in_specs are built from the ARGUMENT structure at trace time:
        # int8 scale/offset vectors and quantized-user scale/slack rows
        # shard alongside the rows they describe; the f32 structure
        # lowers to exactly the pre-spec program (bit-identity)
        delta = (corr,) if with_delta else ()
        delta_specs = (_corr_specs(corr),) if with_delta else ()
        sharded = _shard_map(
            local_part, mesh=mesh,
            in_specs=(_rt_specs(rt), _user_specs(users),
                      P(None, None)) + delta_specs,
            out_specs=(P(None, AXIS), P(None, AXIS), P(None, AXIS, None),
                       P(None, AXIS)))
        all_lo, all_up, payload, gidx = sharded(
            rt, users, qs, *delta)                          # (B, k·P, …)
        est = payload[..., 0]
        r_lo = payload[..., 1]
        r_up = payload[..., 2]
        R_lo_k = kth_smallest(all_lo, k)                    # (B,)
        R_up_k = kth_smallest(all_up, k)
        sel, guaranteed, accepted, pruned = lemma1_select(
            r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, k=k, c=c,
            m_items=corr.selection_m() if with_delta else rt.m)
        return QueryResult(
            indices=jnp.take_along_axis(gidx, sel, axis=-1).astype(
                jnp.int32),
            est_rank=jnp.take_along_axis(est, sel, axis=-1),
            r_lo=r_lo, r_up=r_up,          # candidate-set bounds (B, k·P)
            R_lo_k=R_lo_k, R_up_k=R_up_k,
            guaranteed=guaranteed,
            n_accepted=jnp.sum(accepted, axis=-1).astype(jnp.int32),
            n_pruned=jnp.sum(pruned, axis=-1).astype(jnp.int32),
        )

    return batch_query_fn


def make_pruned_batch_query_fn(mesh: Mesh, k: int, n: int, c: float, *,
                               block_size: int, with_delta: bool = False):
    """Block-pruned twin of `make_batch_query_fn` (PR 4): each shard
    gathers only its SURVIVING user tiles before the per-shard top-k, so
    the local n·(d+2τ)/P stream shrinks to the kept fraction while the
    tree-merge wire budget stays O(B·k·P).

    The returned fn takes, after (rank_table, users, Q):
      ids   (P, W) int32 — per-shard LOCAL block ids to execute; the
            caller pads every shard to the same width W (SPMD needs
            uniform shapes) by repeating kept ids;
      valid (P, W) bool — False marks the repeated padding columns (and
            whole shards with nothing kept), whose rows are forced to
            +inf so duplicates can never become duplicate candidates;
      keep  (B, nb) bool, replicated — the PER-QUERY phase-A keep mask
            over GLOBAL block ids; rows executed only because another
            query (or the padding) needed them read as +inf for queries
            that pruned them, exactly like the single-process sentinel
            materialization.

    Correctness matches the single-process argument (`core.pruning`):
    every user that can influence R↓_k/R↑_k or the top-k lives in a kept
    tile of its own shard, +inf dominates every admissible key, and the
    per-shard k-smallest of {kept exact values ∪ +inf} reproduces the
    exact global order statistics through the unchanged all-gather
    merge. Requires n % (P · block_size) == 0 (tiles must not straddle
    shards — `PrunedBackend` falls back to the full scan otherwise).

    Reorder contract (PR 6): a build/rebuild-time cluster reorder is a
    GLOBAL row permutation applied to users/table BEFORE sharding, so
    each shard's local tiles are contiguous rows of the already-permuted
    matrix — shard-local block ids, the divisibility contract and the
    tree-merge are all unchanged (n is invariant under a permutation).
    The permuted snapshot answers in its own row coordinates, identical
    to every other backend on that snapshot; translation to pre-remap
    client ids happens once, host-side, via `IndexSnapshot.user_remap` —
    never inside the shard_map.
    """
    nshards = mesh.devices.size
    shard_n = n // nshards
    nb_loc = shard_n // block_size

    def local_part(rt_loc, u_shard, qs, ids, valid, keep, *delta):
        ids_loc = ids[0]                                    # (W,)
        valid_loc = valid[0]
        ridx = (ids_loc[:, None] * block_size
                + jnp.arange(block_size, dtype=jnp.int32)[None, :]
                ).reshape(-1)                               # (W·bs,) local
        scores, slack = user_scores_batch(
            take_user_rows(u_shard, ridx), qs)              # (W·bs, B)
        r_lo, r_up, est = lookup_bounds_batch(rt_loc.take_rows(ridx),
                                              scores, slack)
        if with_delta:
            corr, = delta
            r_lo, r_up, est = rt_mod.apply_delta_corrections(
                scores, r_lo, r_up, est, corr.take_rows(ridx), slack=slack)
            m_eff = corr.selection_m()
        else:
            m_eff = rt_loc.m
        shard_id = jax.lax.axis_index(AXIS)
        gblk = shard_id * nb_loc + ids_loc                  # global ids (W,)
        keep_rows = keep[:, gblk] & valid_loc[None, :]      # (B, W)
        alive = jnp.repeat(keep_rows, block_size, axis=1)   # (B, W·bs)
        inf = jnp.inf
        r_lo = jnp.where(alive, r_lo.T, inf)                # (B, W·bs)
        r_up = jnp.where(alive, r_up.T, inf)
        est = jnp.where(alive, est.T, inf)
        neg_lo, _ = jax.lax.top_k(-r_lo, k)
        neg_up, _ = jax.lax.top_k(-r_up, k)
        gl = jnp.moveaxis(jax.lax.all_gather(-neg_lo, AXIS), 0, 1)
        gu = jnp.moveaxis(jax.lax.all_gather(-neg_up, AXIS), 0, 1)
        R_lo_k = kth_smallest(gl.reshape(gl.shape[0], -1), k)      # (B,)
        R_up_k = kth_smallest(gu.reshape(gu.shape[0], -1), k)
        key_val, _, _, _ = lemma1_key(r_lo, r_up, est, R_lo_k=R_lo_k,
                                      R_up_k=R_up_k, c=c, m_items=m_eff)
        _, cand = jax.lax.top_k(-key_val, k)                # (B, k)
        gidx = (jnp.take(ridx, cand) + shard_id * shard_n).astype(jnp.int32)
        payload = jnp.stack(
            [jnp.take_along_axis(est, cand, axis=-1),
             jnp.take_along_axis(r_lo, cand, axis=-1),
             jnp.take_along_axis(r_up, cand, axis=-1)], axis=-1)  # (B, k, 3)
        return -neg_lo, -neg_up, payload, gidx

    @jax.jit
    def batch_query_fn(rt: RankTable, users, qs: jax.Array,
                       ids: jax.Array, valid: jax.Array, keep: jax.Array,
                       corr: DeltaCorrection = None) -> QueryResult:
        delta = (corr,) if with_delta else ()
        delta_specs = (_corr_specs(corr),) if with_delta else ()
        sharded = _shard_map(
            local_part, mesh=mesh,
            in_specs=(_rt_specs(rt), _user_specs(users),
                      P(None, None), P(AXIS, None), P(AXIS, None),
                      P(None, None)) + delta_specs,
            out_specs=(P(None, AXIS), P(None, AXIS), P(None, AXIS, None),
                       P(None, AXIS)))
        all_lo, all_up, payload, gidx = sharded(
            rt, users, qs, ids, valid, keep, *delta)        # (B, k·P, …)
        est = payload[..., 0]
        r_lo = payload[..., 1]
        r_up = payload[..., 2]
        R_lo_k = kth_smallest(all_lo, k)                    # (B,)
        R_up_k = kth_smallest(all_up, k)
        sel, guaranteed, accepted, pruned = lemma1_select(
            r_lo, r_up, est, R_lo_k=R_lo_k, R_up_k=R_up_k, k=k, c=c,
            m_items=corr.selection_m() if with_delta else rt.m)
        return QueryResult(
            indices=jnp.take_along_axis(gidx, sel, axis=-1).astype(
                jnp.int32),
            est_rank=jnp.take_along_axis(est, sel, axis=-1),
            r_lo=r_lo, r_up=r_up,          # candidate-set bounds (B, k·P)
            R_lo_k=R_lo_k, R_up_k=R_up_k,
            guaranteed=guaranteed,
            n_accepted=jnp.sum(accepted, axis=-1).astype(jnp.int32),
            n_pruned=jnp.sum(pruned, axis=-1).astype(jnp.int32),
        )

    return batch_query_fn


def make_query_fn(mesh: Mesh, k: int, n: int, c: float):
    """Single-query sharded execution: the B = 1 case of
    `make_batch_query_fn` (same shard_map, same merge; leading axis
    squeezed). Kept as the dry-run/roofline entry point."""
    batched = make_batch_query_fn(mesh, k=k, n=n, c=c)

    @jax.jit
    def query_fn(rt: RankTable, users: jax.Array, q: jax.Array
                 ) -> QueryResult:
        res = batched(rt, users, q[None, :])
        return jax.tree_util.tree_map(lambda x: x[0], res)

    return query_fn


# -------------------------------------------------------------- refinement
def ring_exact_ranks(users: jax.Array, items: jax.Array, q: jax.Array,
                     mesh: Mesh) -> jax.Array:
    """Exact Definition-1 ranks with BOTH users and items sharded: item
    shards rotate around a ring (collective_permute) while every user
    shard accumulates counts — compute/comm overlap with items never
    materializing unsharded. Used for boundary-user refinement and as the
    at-scale exact baseline."""
    nshards = mesh.devices.size
    perm = [(i, (i + 1) % nshards) for i in range(nshards)]

    def local(u_shard, it_shard, qv):
        uq = (u_shard @ qv).astype(jnp.float32)

        def body(_, carry):
            counts, blk = carry
            scores = (u_shard @ blk.T).astype(jnp.float32)
            counts = counts + jnp.sum(scores > uq[:, None], axis=1)
            blk = jax.lax.ppermute(blk, AXIS, perm)
            return counts, blk

        counts, _ = jax.lax.fori_loop(
            0, nshards, body, (jnp.zeros_like(uq), it_shard))
        return 1.0 + counts

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(AXIS, None), P(AXIS, None), P()),
        out_specs=P(AXIS))(users, items, q)
