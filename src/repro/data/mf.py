"""Matrix factorization in JAX — the paper's embedding-production step.

§5 of the paper builds user/item vectors with LIBMF (d = 200) from rating
triples; this module reproduces that substrate so the full pipeline
(ratings → embeddings → rank table → queries) runs end-to-end in-framework.

Mini-batch SGD with bias terms and L2, jit-compiled; deterministic given
the seed. At container scale this trains small replicas; the full-scale
shapes flow through the dry-run path instead.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MFConfig:
    d: int = 200
    lr: float = 0.05
    l2: float = 1e-4
    epochs: int = 10
    batch: int = 8192
    seed: int = 0


def init_mf(key, n: int, m: int, cfg: MFConfig) -> dict:
    ku, kv = jax.random.split(key)
    s = cfg.d ** -0.5
    return {
        "u": jax.random.normal(ku, (n, cfg.d), jnp.float32) * s,
        "v": jax.random.normal(kv, (m, cfg.d), jnp.float32) * s,
        "bu": jnp.zeros((n,), jnp.float32),
        "bv": jnp.zeros((m,), jnp.float32),
    }


@functools.partial(jax.jit, static_argnames=("cfg",))
def mf_epoch(state: dict, ii, jj, rr, perm, cfg: MFConfig):
    """One epoch of mini-batch SGD over permuted rating triples."""
    nb = ii.shape[0] // cfg.batch

    def loss_fn(s, i, j, r):
        pred = jnp.einsum("kd,kd->k", s["u"][i], s["v"][j]) \
            + s["bu"][i] + s["bv"][j]
        err = pred - r
        reg = cfg.l2 * (jnp.sum(s["u"][i] ** 2) + jnp.sum(s["v"][j] ** 2))
        return jnp.mean(err * err) + reg / i.shape[0]

    batches = jnp.arange(nb)

    def scan_step(s, b):
        idx = jax.lax.dynamic_slice_in_dim(perm, b * cfg.batch, cfg.batch)
        i, j, r = ii[idx], jj[idx], rr[idx]
        l, g = jax.value_and_grad(loss_fn)(s, i, j, r)
        s = jax.tree.map(lambda p, gg: p - cfg.lr * gg, s, g)
        return s, l

    state, losses = jax.lax.scan(scan_step, state, batches)
    return state, losses.mean()


def train_mf(key, n: int, m: int, ii, jj, rr, cfg: MFConfig
             ) -> tuple[dict, list]:
    """Full MF training loop. Returns (state, per-epoch losses)."""
    state = init_mf(key, n, m, cfg)
    losses = []
    for e in range(cfg.epochs):
        perm = jax.random.permutation(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), e),
            ii.shape[0])
        state, l = mf_epoch(state, ii, jj, rr, perm, cfg)
        losses.append(float(l))
    return state, losses


def embeddings(state: dict) -> tuple[jax.Array, jax.Array]:
    """(users, items) for the reverse k-ranks engine. Bias terms fold into
    an extra dimension so inner products keep the rating semantics."""
    n, m = state["u"].shape[0], state["v"].shape[0]
    users = jnp.concatenate(
        [state["u"], jnp.ones((n, 1)), state["bu"][:, None]], axis=1)
    items = jnp.concatenate(
        [state["v"], state["bv"][:, None], jnp.ones((m, 1))], axis=1)
    return users, items
