"""Deterministic, stateless LM data pipeline.

Every batch is a pure function of (seed, step): `batch_at(step)` folds the
step counter into the PRNG key, so

  * resume after preemption replays the exact stream (bitwise) — the
    checkpoint only needs to store the step;
  * host sharding is trivial: host h of H takes rows [h·B/H, (h+1)·B/H) of
    the same deterministic batch (single-process here, but the slicing API
    is what a multi-host launcher uses).

Tokens follow a Zipf-like marginal over the vocab with short-range
repetition structure, so cross-entropy actually decreases during the
example training runs (unlike uniform noise).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3       # P(copy an earlier nearby token)


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        ranks = jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32)
        logp = -cfg.zipf_a * jnp.log(ranks)
        self._logits = logp - jax.nn.logsumexp(logp)

    def batch_at(self, step: int, host_index: int = 0, host_count: int = 1
                 ) -> dict:
        cfg = self.cfg
        b = cfg.global_batch // host_count
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        key = jax.random.fold_in(key, host_index)
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (b, cfg.seq_len + 1,
                                                cfg.vocab)))
        # short-range repetition: with prob repeat_p, copy token t-Δ
        delta = jax.random.randint(k2, (b, cfg.seq_len + 1), 1, 8)
        idx = jnp.maximum(jnp.arange(cfg.seq_len + 1)[None, :] - delta, 0)
        copied = jnp.take_along_axis(base, idx, axis=1)
        mask = jax.random.bernoulli(k3, cfg.repeat_p,
                                    (b, cfg.seq_len + 1))
        seq = jnp.where(mask, copied, base).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def synthetic_embeddings(key, n: int, m: int, d: int,
                         norm_spread: float = 0.3, n_clusters: int = 32,
                         cluster_strength: float = 1.0):
    """MF-like user/item vectors: Gaussian norm distribution (paper
    Fig. 2) PLUS shared latent clusters, so rankings are genuinely
    user-dependent. Pure isotropic noise with multiplicative item norms
    makes high-norm items everyone's top ranks — a degenerate reverse
    k-ranks instance real MF embeddings don't exhibit."""
    ku, ki, ks, kc, kcu, kci = jax.random.split(key, 6)
    centers = jax.random.normal(kc, (n_clusters, d), jnp.float32)
    cu = jax.random.randint(kcu, (n,), 0, n_clusters)
    ci = jax.random.randint(kci, (m,), 0, n_clusters)
    users = jax.random.normal(ku, (n, d), jnp.float32) \
        + cluster_strength * centers[cu]
    items = jax.random.normal(ki, (m, d), jnp.float32) \
        + cluster_strength * centers[ci]
    scale = 1.0 + norm_spread * jax.random.normal(ks, (m, 1), jnp.float32)
    return users, items * jnp.abs(scale)


def synthetic_ratings(key, n: int, m: int, n_obs: int, d_true: int = 16):
    """Low-rank ground-truth ratings r_ij = u_i·v_j + ε on a random sample
    of (i, j) pairs — input for the MF trainer."""
    ku, kv, ki, kj, ke = jax.random.split(key, 5)
    ut = jax.random.normal(ku, (n, d_true)) / d_true ** 0.25
    vt = jax.random.normal(kv, (m, d_true)) / d_true ** 0.25
    ii = jax.random.randint(ki, (n_obs,), 0, n)
    jj = jax.random.randint(kj, (n_obs,), 0, m)
    r = jnp.einsum("kd,kd->k", ut[ii], vt[jj]) + \
        0.05 * jax.random.normal(ke, (n_obs,))
    return ii.astype(jnp.int32), jj.astype(jnp.int32), r.astype(jnp.float32)
