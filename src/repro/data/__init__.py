"""Data substrate: deterministic token pipeline, synthetic embedding /
ratings generators, and the JAX matrix-factorization trainer (the paper's
LIBMF step)."""
