"""Online quality auditor: shadow-sample served queries, re-score them
EXACTLY in the background, publish rolling §5 quality gauges.

The c-approximation contract is certified analytically (bound widening,
PR 5/6) and measured in benches — but a production operator needs the
live signal: "is the overall-ratio of what we are ACTUALLY serving still
inside the envelope the bench measured?" The auditor closes that loop:

  * `observe(q, result, k=, c=, snapshot=)` is called by the serving path
    (the `MicroBatcher` calls it per resolved request when constructed
    with `auditor=`). A seeded `random.Random` samples a configurable
    fraction — DETERMINISTIC in observation order, so a replayed request
    log audits the same subset (pinned in tests/test_obs.py);
  * sampled queries are queued (bounded; overflow increments
    `audit_dropped_total` instead of back-pressuring the serving path)
    and re-scored on ONE background thread against the exact O(nmd)
    oracle (`core.exact`), on the SNAPSHOT they were served from — users
    are the f32 system of record, items the snapshot's live set, so the
    verdict judges the answer against the state that produced it;
  * rolling windows of per-query `overall_ratio` / `accuracy`
    (`core.metrics`, the §5 criteria) feed gauges, alongside the mean
    certified bound width r↑−r↓ over the SELECTED users (how much slack
    the certification is carrying) — `audit_overall_ratio`,
    `audit_accuracy`, `audit_bound_width` in the default registry.

The audit cost is one exact scan per sampled query, on a thread the
scheduler never waits for; `fraction` is the knob trading audit freshness
against background CPU. Prune-skip-rate gauges are NOT published here —
the pruned backend publishes its own (`prune_skip_rate`) per batch; the
auditor's gauges are the quality half of the same dashboard.
"""
from __future__ import annotations

import logging
import random
import threading
from collections import deque
from typing import Optional

import numpy as np

from repro.obs import registry as obs
from repro.serve import faults


class QualityAuditor:
    """Shadow-sampling exact re-scorer (module docstring).

    Args:
      engine:      a `ReverseKRanksEngine` (or anything exposing
                   `current_snapshot()` returning snapshots with
                   `.users` / `.live_items()`).
      fraction:    probability each observed request is audited.
      seed:        RNG seed — sampling is deterministic in observe order.
      window:      rolling-window length for the quality gauges.
      max_pending: bound on queued-but-unscored samples; overflow drops
                   (counted), never blocks the caller.
      registry:    metrics registry (default: the process-global one).
    """

    def __init__(self, engine, *, fraction: float = 0.02, seed: int = 0,
                 window: int = 64, max_pending: int = 128,
                 registry: Optional[obs.MetricsRegistry] = None):
        if not 0.0 <= fraction <= 1.0:
            raise ValueError(f"fraction must be in [0, 1]; got {fraction}")
        if window < 1:
            raise ValueError(f"window must be >= 1; got {window}")
        self.engine = engine
        self.fraction = float(fraction)
        self.window = int(window)
        self.max_pending = int(max_pending)
        self._rng = random.Random(int(seed))
        self._ratios: deque = deque(maxlen=self.window)
        self._accs: deque = deque(maxlen=self.window)
        self._widths: deque = deque(maxlen=self.window)
        self._pending: deque = deque()
        self._cond = threading.Condition()
        self._in_flight = 0
        self._stop = False
        reg = registry if registry is not None else obs.get_default()
        self._m_observed = reg.counter(
            "audit_observed_total", "requests offered to the auditor")
        self._m_sampled = reg.counter(
            "audit_sampled_total", "requests sampled for exact re-scoring")
        self._m_scored = reg.counter(
            "audit_scored_total", "samples re-scored against the oracle")
        self._m_dropped = reg.counter(
            "audit_dropped_total", "samples dropped (queue at max_pending)")
        self._m_skipped = reg.counter(
            "audit_skipped_total",
            "samples skipped (snapshot lacks its item set)")
        self._m_ratio = reg.gauge(
            "audit_overall_ratio",
            "rolling mean §5 overall-ratio of audited served queries")
        self._m_acc = reg.gauge(
            "audit_accuracy",
            "rolling mean §5 accuracy of audited served queries")
        self._m_width = reg.gauge(
            "audit_bound_width",
            "rolling mean certified r_up - r_lo over selected users")
        self._m_backlog = reg.gauge(
            "audit_backlog", "sampled queries awaiting exact re-scoring")
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="quality-auditor")
        # Liveness read at scrape time via callback — a dead scorer
        # thread cannot leave a stale "alive" value behind.
        self._m_alive = reg.gauge(
            "audit_thread_alive",
            "1 while the auditor's scoring thread is running",
            set_fn=self._thread.is_alive)
        self._thread.start()

    # --------------------------------------------------------- serving API
    def observe(self, q, result, *, k: int, c: float,
                snapshot=None) -> bool:
        """Offer one served (query, per-query QueryResult) to the
        auditor; returns True when it was sampled AND enqueued. Cheap on
        the serving path: one RNG draw, one deque append. The RNG draw
        happens for EVERY observation (sampled or not) so the audited
        subset is a pure function of (seed, observation order)."""
        self._m_observed.inc()
        sampled = self._rng.random() < self.fraction
        if not sampled:
            return False
        self._m_sampled.inc()
        if snapshot is None:
            snap_fn = getattr(self.engine, "current_snapshot", None)
            snapshot = snap_fn() if snap_fn is not None else None
        with self._cond:
            if self._stop:
                return False
            if len(self._pending) >= self.max_pending:
                self._m_dropped.inc()
                return False
            self._pending.append((np.array(q, dtype=np.float32, copy=True),
                                  result, int(k), float(c), snapshot))
            self._m_backlog.set(len(self._pending))
            self._cond.notify_all()
        return True

    # ------------------------------------------------------------- results
    @property
    def overall_ratio(self) -> float:
        """Rolling-window mean overall-ratio (nan before the first score)."""
        with self._cond:
            return (float(np.mean(self._ratios)) if self._ratios
                    else float("nan"))

    @property
    def accuracy(self) -> float:
        with self._cond:
            return (float(np.mean(self._accs)) if self._accs
                    else float("nan"))

    @property
    def bound_width(self) -> float:
        with self._cond:
            return (float(np.mean(self._widths)) if self._widths
                    else float("nan"))

    @property
    def scored(self) -> int:
        return int(self._m_scored.value)

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every enqueued sample has been scored (tests /
        shutdown reporting); returns False on timeout."""
        import time as _t
        t_end = None if timeout is None else _t.monotonic() + timeout
        with self._cond:
            while self._pending or self._in_flight:
                remaining = None if t_end is None else t_end - _t.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._cond.wait(timeout=remaining)
        return True

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- scoring
    def _run(self):
        """Thread body: `_loop` + last-resort visibility (cf.
        `MaintenanceLoop._run`): an exception escaping `_loop` — i.e.
        outside the per-item scoring try/except — is logged once, then
        the thread dies VISIBLY (`audit_thread_alive` flips to 0 at the
        next scrape) instead of vanishing."""
        try:
            self._loop()
        except Exception:
            logging.getLogger(__name__).exception(
                "quality auditor thread died; online quality gauges are "
                "FROZEN (audit_thread_alive gauge is now 0)")
            raise

    def _loop(self):
        while True:
            with self._cond:
                while not self._pending and not self._stop:
                    self._cond.wait()
                if not self._pending:           # stop requested, drained
                    return
                item = self._pending.popleft()
                self._m_backlog.set(len(self._pending))
                self._in_flight = 1
            if faults.ACTIVE is not None:
                # chaos site outside the per-item try/except: a raise
                # here kills the thread (liveness-gauge regression test).
                # _in_flight is restored so flush() cannot hang forever
                # on a dead scorer.
                try:
                    faults.fire("audit.loop")
                except BaseException:
                    with self._cond:
                        self._in_flight = 0
                        self._cond.notify_all()
                    raise
            try:
                self._score(*item)
            except Exception:
                # an audit failure must never look like a quality pass —
                # it is counted, and the serving path is unaffected
                self._m_skipped.inc()
            finally:
                with self._cond:
                    self._in_flight = 0
                    self._cond.notify_all()

    def _score(self, q, result, k, c, snapshot):
        from repro.core import metrics as M
        from repro.core.exact import exact_ranks, reverse_k_ranks

        if snapshot is None:
            self._m_skipped.inc()
            return
        try:
            items = snapshot.live_items()
        except ValueError:          # engine built without its item set
            self._m_skipped.inc()
            return
        users = snapshot.users      # f32 system of record
        truth = np.asarray(exact_ranks(users, items, q))
        ex_idx, _ = reverse_k_ranks(users, items, q, k)
        got = np.asarray(result.indices)
        ratio = M.overall_ratio(got, np.asarray(ex_idx), truth)
        acc = M.accuracy(got, np.asarray(ex_idx), truth, c)
        # certified slack the selection is carrying: mean r_up − r_lo over
        # the selected users (full-bounds backends; candidate-set shapes
        # like sharded's (k·P,) index the same way)
        width = float("nan")
        r_lo, r_up = np.asarray(result.r_lo), np.asarray(result.r_up)
        if r_lo.ndim == 1 and r_lo.shape[0] >= got.max() + 1:
            width = float(np.mean(r_up[got] - r_lo[got]))
        with self._cond:
            self._ratios.append(ratio)
            self._accs.append(acc)
            if np.isfinite(width):
                self._widths.append(width)
            self._m_ratio.set(float(np.mean(self._ratios)))
            self._m_acc.set(float(np.mean(self._accs)))
            if self._widths:
                self._m_width.set(float(np.mean(self._widths)))
        self._m_scored.inc()
