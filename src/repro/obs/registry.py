"""Low-overhead serving metrics: counters, gauges, fixed-bucket histograms.

The serving stack (scheduler ticks, cache lookups, pruned-scan phases,
elastic repads, maintenance rebuilds, the quality auditor) publishes into
ONE process-global `MetricsRegistry`, exported two ways:

  * `to_prometheus_text()` — the Prometheus text exposition format, served
    by `start_http_server(port)` at ``/metrics`` (and ``/metrics.json``);
  * `snapshot()` — a plain JSON-able dict, embedded in `perf_engine
    --json` artifacts so bench runs carry the same counters a live fleet
    exposes.

Design constraints (this is ON the serving path, so it must be boring):

  * stdlib only — importing this module must not pull in jax/numpy;
  * one `threading.Lock` per instrument, held for a few float ops;
    `observe()` on a histogram is a bisect over ~100 bucket bounds;
  * instruments are get-or-create by (name, labels) and live for the
    process — call sites cache them at module scope, and `reset()` zeroes
    VALUES in place so cached references stay valid across tests;
  * nothing here runs per user row. Per-row work is instrumented at the
    tick/batch level by the callers.

Histogram percentile reconstruction
-----------------------------------
Buckets are FIXED at construction (default: log-spaced latency bounds,
~4 buckets per octave from 1 µs to 60 s, in ms). Each bucket additionally
tracks the min/max observation it absorbed, so `percentile(p)` is:

  * EXACT whenever the bucket straddling the requested rank is degenerate
    (all its observations equal — true in particular for any observation
    stream drawn from the bucket boundaries themselves, the regression
    surface tests/test_obs.py pins);
  * otherwise linearly interpolated between that bucket's observed
    min/max, so the error is bounded by ONE bucket's width (≈ 19%
    relative at the default spacing) rather than by the histogram range.

`p50()`/`p99()` are the dashboard shorthands.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_latency_bounds", "get_default", "set_default",
    "counter", "gauge", "histogram", "start_http_server",
]

LabelsT = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[dict]) -> LabelsT:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(labels: LabelsT, extra: Optional[List[Tuple[str, str]]]
                   = None) -> str:
    pairs = list(labels) + (extra or [])
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


class _Instrument:
    """Shared identity/locking plumbing for the three instrument kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", labels: LabelsT = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = threading.Lock()


class Counter(_Instrument):
    """Monotone float counter (`inc` only; `reset()` re-zeroes)."""

    kind = "counter"

    def __init__(self, name, help="", labels=()):
        super().__init__(name, help, labels)
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge(_Instrument):
    """Last-write-wins float gauge; `set_fn` makes it a CALLBACK gauge
    whose value is read lazily at export time (e.g. the elastic backend's
    compiled-program count — sampling it per export beats paying the scan
    per tick)."""

    kind = "gauge"

    def __init__(self, name, help="", labels=(),
                 set_fn: Optional[Callable[[], float]] = None):
        super().__init__(name, help, labels)
        self._value = 0.0
        self._fn = set_fn

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            self._fn = None         # explicit set wins over the callback

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def set_function(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    @property
    def value(self) -> float:
        with self._lock:
            fn = self._fn
            if fn is None:
                return self._value
        try:                        # callback outside the lock
            return float(fn())
        except Exception:
            return float("nan")

    def _reset(self) -> None:
        with self._lock:
            if self._fn is None:
                self._value = 0.0


def default_latency_bounds(lo_ms: float = 1e-3, hi_ms: float = 60_000.0,
                           per_octave: int = 4) -> Tuple[float, ...]:
    """Log-spaced bucket UPPER bounds in milliseconds: `per_octave`
    buckets per factor of two from `lo_ms` to at least `hi_ms` (~101
    buckets at the defaults — fine-grained enough that one-bucket
    interpolation error is ≈ 2^(1/per_octave) − 1 ≈ 19% relative)."""
    n = int(math.ceil(math.log2(hi_ms / lo_ms) * per_octave)) + 1
    return tuple(lo_ms * 2.0 ** (i / per_octave) for i in range(n))


class Histogram(_Instrument):
    """Fixed-bucket histogram with per-bucket min/max for percentile
    reconstruction (module docstring). Observations above the last bound
    land in the implicit +Inf bucket."""

    kind = "histogram"

    def __init__(self, name, help="", labels=(),
                 bounds: Optional[Iterable[float]] = None):
        super().__init__(name, help, labels)
        b = tuple(float(x) for x in (bounds if bounds is not None
                                     else default_latency_bounds()))
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram {name}: bounds must be a "
                             f"non-empty strictly increasing sequence")
        self.bounds = b
        nb = len(b) + 1                     # + the +Inf bucket
        self._counts = [0] * nb
        self._mins = [math.inf] * nb
        self._maxs = [-math.inf] * nb
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        # bucket i holds observations with  bounds[i-1] < v <= bounds[i]
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            if v < self._mins[i]:
                self._mins[i] = v
            if v > self._maxs[i]:
                self._maxs[i] = v
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile reconstructed from the buckets
        (exactness contract in the module docstring); 0.0 when empty."""
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100]; got {p}")
        with self._lock:
            total = self._count
            if total == 0:
                return 0.0
            rank = max(0, math.ceil(p / 100.0 * total) - 1)   # 0-based
            cum = 0
            for i, cnt in enumerate(self._counts):
                if cnt == 0:
                    continue
                if rank < cum + cnt:
                    lo, hi = self._mins[i], self._maxs[i]
                    if lo == hi:
                        return lo           # degenerate bucket: exact
                    frac = (rank - cum) / (cnt - 1)
                    return lo + (hi - lo) * frac
                cum += cnt
        return 0.0                          # unreachable

    def p50(self) -> float:
        return self.percentile(50.0)

    def p99(self) -> float:
        return self.percentile(99.0)

    def _reset(self) -> None:
        with self._lock:
            nb = len(self.bounds) + 1
            self._counts = [0] * nb
            self._mins = [math.inf] * nb
            self._maxs = [-math.inf] * nb
            self._sum = 0.0
            self._count = 0

    def _cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative count), ...] incl. +Inf, for the exporter."""
        out, cum = [], 0
        with self._lock:
            for le, cnt in zip(self.bounds + (math.inf,), self._counts):
                cum += cnt
                out.append((le, cum))
        return out


class MetricsRegistry:
    """Get-or-create instrument registry keyed on (name, labels).

    A name maps to ONE instrument kind — re-requesting with a different
    kind (or different histogram bounds) raises, so two call sites cannot
    silently split a time series.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "Dict[Tuple[str, LabelsT], _Instrument]" = {}

    def _get_or_create(self, cls, name, help, labels, **kw):
        key = (name, _labels_key(labels))
        with self._lock:
            hit = self._metrics.get(key)
            if hit is not None:
                if not isinstance(hit, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{hit.kind}, requested {cls.kind}")
                if (isinstance(hit, Histogram) and kw.get("bounds")
                        is not None
                        and tuple(kw["bounds"]) != hit.bounds):
                    raise ValueError(
                        f"histogram {name!r} already registered with "
                        f"different bounds")
                return hit
            inst = cls(name, help, key[1], **kw)
            self._metrics[key] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None,
              set_fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get_or_create(Gauge, name, help, labels, set_fn=set_fn)
        if set_fn is not None and g._fn is None and g._value == 0.0:
            g.set_function(set_fn)
        return g

    def histogram(self, name: str, help: str = "",
                  labels: Optional[dict] = None,
                  bounds: Optional[Iterable[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   bounds=bounds)

    def metrics(self) -> List[_Instrument]:
        with self._lock:
            return list(self._metrics.values())

    def reset(self) -> None:
        """Zero every instrument IN PLACE (cached call-site references
        stay valid — tests use this between cases)."""
        for m in self.metrics():
            m._reset()

    # ----------------------------------------------------------- exporters
    def snapshot(self) -> dict:
        """JSON-able dump: {name: [{labels, ...kind-specific}]}."""
        out: dict = {}
        for m in self.metrics():
            entry: dict = {"labels": dict(m.labels), "type": m.kind}
            if isinstance(m, Histogram):
                entry.update(
                    count=m.count, sum=m.sum,
                    p50=m.p50(), p99=m.p99(),
                    buckets=[{"le": le, "cumulative": c}
                             for le, c in m._cumulative()
                             if c or math.isinf(le)])
            else:
                entry["value"] = m.value
            out.setdefault(m.name, []).append(entry)
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        lines: List[str] = []
        seen_header = set()
        by_name: "Dict[str, List[_Instrument]]" = {}
        for m in self.metrics():
            by_name.setdefault(m.name, []).append(m)
        for name in sorted(by_name):
            for m in by_name[name]:
                if name not in seen_header:
                    if m.help:
                        lines.append(f"# HELP {name} {m.help}")
                    lines.append(f"# TYPE {name} {m.kind}")
                    seen_header.add(name)
                if isinstance(m, Histogram):
                    for le, cum in m._cumulative():
                        le_s = "+Inf" if le is math.inf or le == math.inf \
                            else repr(le)
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels(m.labels, [('le', le_s)])} "
                            f"{cum}")
                    lines.append(f"{name}_sum{_render_labels(m.labels)} "
                                 f"{m.sum!r}")
                    lines.append(f"{name}_count{_render_labels(m.labels)} "
                                 f"{m.count}")
                else:
                    v = m.value
                    lines.append(f"{name}{_render_labels(m.labels)} {v!r}")
        return "\n".join(lines) + "\n"


_DEFAULT = MetricsRegistry()


def get_default() -> MetricsRegistry:
    """The process-global registry every serving component publishes to."""
    return _DEFAULT


def set_default(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests). Call-site-cached
    instruments keep pointing at the OLD registry — prefer
    `get_default().reset()` unless isolation is the point."""
    global _DEFAULT
    _DEFAULT = reg
    return reg


def counter(name: str, help: str = "",
            labels: Optional[dict] = None) -> Counter:
    return _DEFAULT.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: Optional[dict] = None,
          set_fn: Optional[Callable[[], float]] = None) -> Gauge:
    return _DEFAULT.gauge(name, help, labels, set_fn=set_fn)


def histogram(name: str, help: str = "", labels: Optional[dict] = None,
              bounds: Optional[Iterable[float]] = None) -> Histogram:
    return _DEFAULT.histogram(name, help, labels, bounds=bounds)


# ------------------------------------------------------------ HTTP export
def start_http_server(port: int, registry: Optional[MetricsRegistry] = None,
                      host: str = "127.0.0.1"):
    """Serve the registry at ``http://host:port/metrics`` (Prometheus
    text) and ``/metrics.json`` (the `snapshot()` dict) from a daemon
    thread. Port 0 binds an ephemeral port; read it back from the
    returned server's ``server_address``. `shutdown()` the returned
    `ThreadingHTTPServer` to stop."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    reg = registry if registry is not None else get_default()

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):                                   # noqa: N802
            if self.path.split("?")[0] == "/metrics.json":
                body = json.dumps(
                    {"unix_time": time.time(), "metrics": reg.snapshot()},
                    default=str).encode()
                ctype = "application/json"
            elif self.path.split("?")[0] in ("/metrics", "/"):
                body = reg.to_prometheus_text().encode()
                ctype = "text/plain; version=0.0.4"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):          # quiet: scrapes are periodic
            pass

    srv = ThreadingHTTPServer((host, int(port)), Handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True,
                         name="metrics-http")
    t.start()
    return srv
