"""Serving observability: metrics registry, trace spans, quality auditor.

Three small, separable pieces (each its own module):

  * `repro.obs.registry` — counters / gauges / fixed-bucket latency
    histograms in a process-global `MetricsRegistry`, with Prometheus
    text and JSON snapshot exporters and an optional scrape HTTP server.
  * `repro.obs.trace` — nestable monotonic-clock spans in a ring buffer,
    disabled by default (shared no-op object on the hot path), with
    opt-in `jax.profiler` annotations.
  * `repro.obs.audit` — the online quality auditor: shadow-samples
    served queries and re-scores them exactly in the background,
    publishing rolling §5 overall-ratio / accuracy gauges.

`registry` and `trace` are stdlib-only and safe to import from any core
module (no jax, no numpy, no cycles); `audit` needs numpy + the exact
oracle and is loaded lazily on first attribute access.
"""
from __future__ import annotations

from repro.obs import trace
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    default_latency_bounds,
    gauge,
    get_default,
    histogram,
    set_default,
    start_http_server,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "QualityAuditor",
    "counter",
    "default_latency_bounds",
    "gauge",
    "get_default",
    "histogram",
    "set_default",
    "start_http_server",
    "trace",
]


def __getattr__(name):
    if name == "QualityAuditor":        # defer numpy/oracle import
        from repro.obs.audit import QualityAuditor
        return QualityAuditor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
