"""Structured per-query trace spans for the serving path.

A span is one timed region on ONE thread — admission→dispatch queue wait,
a cache lookup, the pruned scan's phase A, the elastic repad, a rebuild's
build/swap halves. Spans NEST through a thread-local stack (each record
carries its parent's name path and depth), and completed records land in
a process-global RING BUFFER (`deque(maxlen=...)`): a serving process
keeps the most recent few thousand spans for a dashboard or post-mortem
without unbounded growth.

Spans are DISABLED by default and the hot path stays out of their way:
`span(...)` with tracing off returns a shared no-op context manager — one
module-global check, no allocation, no clock read — which is what the
≤ 1.03× instrumented-serving overhead gate requires. Enable with
`enable()` (or the `REPRO_OBS_SPANS=1` env var at import), and pass
`profiler=True` to additionally wrap every span in a
`jax.profiler.TraceAnnotation`, so HOST spans line up with DEVICE traces
in the XLA profiler UI (the import is deferred and failure-tolerant:
tracing works on builds without the profiler extras).

Cross-thread intervals (a queue wait measured at dispatch for a request
submitted on a client thread) cannot be a `with` block; `event()` records
one retroactively from (t_start, duration).

Usage::

    from repro.obs import trace

    trace.enable()
    with trace.span("serve.tick", batch=16) as sp:
        ...
        sp.set(epoch=snap.epoch)        # attrs may land mid-span
    trace.spans("serve.tick")           # recent completed records
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

__all__ = ["SpanRecord", "span", "event", "enable", "disable",
           "is_enabled", "spans", "clear", "set_capacity"]

_enabled = False
_profiler = False
_tls = threading.local()
_lock = threading.Lock()                # guards buffer swaps only
_buffer: Deque["SpanRecord"] = deque(maxlen=4096)


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span (immutable; safe to hand to dashboards)."""

    name: str
    t_start: float                      # time.monotonic() at entry
    duration_s: float
    depth: int                          # 0 = top-level on its thread
    parent: Optional[str]               # enclosing span's name, if any
    thread: str
    attrs: Tuple[Tuple[str, object], ...] = ()

    @property
    def duration_ms(self) -> float:
        return self.duration_s * 1e3


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


class _Span:
    __slots__ = ("name", "attrs", "t0", "_prof")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        self._prof = None
        if _profiler:
            try:
                import jax.profiler
                self._prof = jax.profiler.TraceAnnotation(self.name)
                self._prof.__enter__()
            except Exception:
                self._prof = None
            # host and device timelines align because the annotation
            # brackets exactly this span's body
        _stack().append(self.name)
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        t1 = time.monotonic()
        stack = _stack()
        # tolerate enable()/disable() races mid-span: only pop our frame
        if stack and stack[-1] is self.name:
            stack.pop()
        depth = len(stack)
        parent = stack[-1] if stack else None
        if self._prof is not None:
            try:
                self._prof.__exit__(*exc)
            except Exception:
                pass
        _buffer.append(SpanRecord(
            name=self.name, t_start=self.t0, duration_s=t1 - self.t0,
            depth=depth, parent=parent,
            thread=threading.current_thread().name,
            attrs=tuple(sorted(self.attrs.items()))))
        return False


def span(name: str, **attrs):
    """A context manager timing `name`; no-op (shared null object) while
    tracing is disabled."""
    if not _enabled:
        return _NULL
    return _Span(name, attrs)


def event(name: str, t_start: float, duration_s: float, **attrs) -> None:
    """Record a RETROACTIVE span — an interval measured across threads
    (e.g. a request's submit→dispatch queue wait, timed on the dispatcher
    thread from the client thread's submit timestamp). It is attributed
    to the calling thread's current span stack."""
    if not _enabled:
        return
    stack = _stack()
    _buffer.append(SpanRecord(
        name=name, t_start=t_start, duration_s=duration_s,
        depth=len(stack), parent=stack[-1] if stack else None,
        thread=threading.current_thread().name,
        attrs=tuple(sorted(attrs.items()))))


def enable(profiler: bool = False) -> None:
    """Turn span recording on; `profiler=True` additionally emits
    `jax.profiler.TraceAnnotation`s so device traces line up."""
    global _enabled, _profiler
    _profiler = bool(profiler)
    _enabled = True


def disable() -> None:
    global _enabled, _profiler
    _enabled = False
    _profiler = False


def is_enabled() -> bool:
    return _enabled


def spans(name: Optional[str] = None) -> List[SpanRecord]:
    """Completed spans currently in the ring buffer, oldest first;
    optionally filtered by exact name."""
    with _lock:
        out = list(_buffer)
    if name is not None:
        out = [s for s in out if s.name == name]
    return out


def clear() -> None:
    with _lock:
        _buffer.clear()


def set_capacity(n: int) -> None:
    """Resize the ring buffer (keeps the most recent records)."""
    global _buffer
    if n < 1:
        raise ValueError(f"span buffer capacity must be >= 1; got {n}")
    with _lock:
        _buffer = deque(_buffer, maxlen=int(n))


if os.environ.get("REPRO_OBS_SPANS", "").strip() in ("1", "true", "on"):
    enable(profiler=os.environ.get("REPRO_OBS_PROFILER", "").strip()
           in ("1", "true", "on"))
