"""Three-term roofline model from a compiled SPMD artifact (§Roofline).

    compute    = HLO_FLOPs(per-device)      / peak_FLOP/s per chip
    memory     = HLO_bytes(per-device)      / HBM bytes/s per chip
    collective = collective_bytes(per-dev)  / ICI bytes/s per link

cost_analysis() reports per-device numbers for SPMD programs (verified
empirically: a (32,128)x(128,256) matmul on 8 devices reports 1/8 of the
global FLOPs). Collective bytes are NOT in cost_analysis — they are parsed
from the compiled HLO text by summing the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch.mesh import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,2048]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^)\s]*\s*,?\s*)+)\)?\s*"
    r"(" + "|".join(_COLLECTIVES) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind byte totals (result shapes, per-device)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = sum(shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes))
        out[kind] += total
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None   # 6·N·D (global)
    useful_ratio: Optional[float] = None  # MODEL / (HLO · chips)
    coll_detail: Optional[dict] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, chips: int, model_flops: Optional[float] = None,
            hw: dict = TPU_V5E) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    t_c = flops / hw["peak_bf16_flops"]
    t_m = hbm / hw["hbm_bw"]
    t_x = coll["total"] / hw["ici_bw"]
    bottleneck = max((("compute", t_c), ("memory", t_m),
                      ("collective", t_x)), key=lambda kv: kv[1])[0]
    useful = (model_flops / (flops * chips)
              if model_flops and flops else None)
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll["total"],
                    compute_s=t_c, memory_s=t_m, collective_s=t_x,
                    bottleneck=bottleneck, model_flops=model_flops,
                    useful_ratio=useful, coll_detail=coll)


def count_params(tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree.leaves(tree))


def model_flops_train(cfg, abstract_params, tokens: int) -> float:
    """6·N·D with MoE activation discounting (6·N_active·D)."""
    import jax
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            abstract_params)[0]:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = int(leaf.size)
        total += n
        if "moe/w_" in keys and cfg.n_experts:
            active += n * cfg.experts_per_tok / cfg.n_experts
        elif "embed/tok" in keys or "lm_head" in keys:
            # embedding gather is not a matmul; the LM head is — count the
            # head, skip the table (standard 6ND convention)
            active += n if "lm_head" in keys else 0
        else:
            active += n
    return 6.0 * active * tokens


def model_flops_decode(cfg, abstract_params, tokens: int) -> float:
    """2·N_active per generated token (forward only)."""
    return model_flops_train(cfg, abstract_params, tokens) / 3.0
