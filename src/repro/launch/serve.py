"""Serving driver for the paper's engine: build a rank-table index over
user/item embeddings and answer batched c-approximate reverse k-ranks
queries, reporting the §5 quality metrics against the exact oracle.

`python -m repro.launch.serve --n 20000 --m 8000 [--backend fused] [--mf]`

Queries execute through the pluggable backend registry
(`repro.core.backends`): --backend dense|fused|sharded. --batch B routes
the timed loop through `query_batch`, which reads the rank table once per
B-query block (the bandwidth amortization measured in
benchmarks/perf_engine.py --batched).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReverseKRanksEngine, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.types import RankTableConfig
from repro.data.pipeline import synthetic_embeddings
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import synthetic_ratings


def build_embeddings(args):
    key = jax.random.PRNGKey(args.seed)
    if args.mf:
        ii, jj, rr = synthetic_ratings(key, args.n, args.m,
                                       n_obs=args.n_ratings)
        state, losses = train_mf(key, args.n, args.m, ii, jj, rr,
                                 MFConfig(d=args.d, epochs=args.mf_epochs))
        print(f"MF losses: {losses[0]:.4f} → {losses[-1]:.4f}")
        return embeddings(state)
    return synthetic_embeddings(key, args.n, args.m, args.d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=8_000)
    ap.add_argument("--d", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--c", type=float, default=2.0)
    ap.add_argument("--tau", type=int, default=500)
    ap.add_argument("--omega", type=int, default=10)
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--backend", default="dense",
                    choices=ReverseKRanksEngine.backends(),
                    help="query-execution backend (see repro.core.backends)")
    ap.add_argument("--batch", type=int, default=16,
                    help="queries per query_batch call in the timed loop")
    ap.add_argument("--kernels", action="store_true",
                    help="deprecated alias for --backend fused")
    ap.add_argument("--mf", action="store_true",
                    help="produce embeddings with the JAX MF trainer")
    ap.add_argument("--mf-epochs", type=int, default=5)
    ap.add_argument("--n-ratings", type=int, default=200_000)
    ap.add_argument("--eval-exact", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    users, items = build_embeddings(args)
    cfg = RankTableConfig(tau=args.tau, omega=args.omega, s=args.s)
    backend = "fused" if args.kernels else args.backend

    t0 = time.time()
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1),
                                    backend=backend)
    jax.block_until_ready(eng.rank_table.table)
    print(f"build: {time.time()-t0:.2f}s  "
          f"index {eng.memory_bytes()/2**20:.1f} MiB "
          f"(n={args.n:,} m={args.m:,} d={args.d})")

    qkey = jax.random.PRNGKey(2)
    qidx = jax.random.randint(qkey, (args.queries,), 0, args.m)
    qs = items[qidx]

    # warm-up + timed loop, query_batch over --batch-sized blocks
    B = max(1, min(args.batch, args.queries))
    nblocks = args.queries // B
    res = eng.query_batch(qs[:B], k=args.k, c=args.c)
    jax.block_until_ready(res.indices)
    t0 = time.time()
    for i in range(nblocks):
        res = eng.query_batch(qs[i * B:(i + 1) * B], k=args.k, c=args.c)
    jax.block_until_ready(res.indices)
    per_q = (time.time() - t0) / (nblocks * B)
    print(f"query: {per_q*1e3:.2f} ms/query "
          f"({eng.backend_name} backend, batch={B}, "
          f"{nblocks * B} of {args.queries} queries timed)")

    if args.eval_exact:
        accs, ratios = [], []
        for i in range(min(args.queries, 20)):
            truth = np.asarray(exact_ranks(users, items, qs[i]))
            ex_idx, _ = reverse_k_ranks(users, items, qs[i], args.k)
            r = eng.query(qs[i], k=args.k, c=args.c)
            accs.append(metrics.accuracy(np.asarray(r.indices),
                                         np.asarray(ex_idx), truth, args.c))
            ratios.append(metrics.overall_ratio(
                np.asarray(r.indices), np.asarray(ex_idx), truth))
        print(f"accuracy {np.mean(accs):.4f}  overall-ratio "
              f"{np.mean(ratios):.4f}  (k={args.k}, c={args.c})")


if __name__ == "__main__":
    main()
