"""Serving driver for the paper's engine: build a rank-table index over
user/item embeddings and serve c-approximate reverse k-ranks queries
ONLINE through the async micro-batching scheduler, reporting the §5
quality metrics against the exact oracle.

`python -m repro.launch.serve --n 20000 --m 8000 [--backend fused] [--mf]`

Queries are submitted one at a time to `repro.serve.MicroBatcher`, which
coalesces them into --max-batch-sized ticks dispatched through
`engine.query_batch` (one rank-table pass per tick); --max-wait-ms is the
latency-vs-throughput knob (how long a partial tick waits to fill).
--backend accepts any registry name (dense|fused|sharded|pruned) plus wrapped
specs such as "cached:fused" (within-tick dedupe + cross-tick per-query
LRU; see repro.serve.cache). --max-depth bounds the queue (fail-fast
back-pressure). --no-eval-exact skips the oracle pass.

--update-stream replays a live item-churn workload WHILE serving: every
--update-every submissions a batch of --insert-batch fresh items is
inserted and --delete-batch live items are deleted (absorbed by the delta
buffer, `repro.index`), with a background `MaintenanceLoop` rebuilding
and hot-swapping the index whenever the delta ratio or stale-sample
budget is exceeded — queries keep flowing through every swap (each tick
pins one epoch; `TickStats.epoch` shows the generations served). The
oracle pass then scores post-churn queries against the FINAL live item
set.

Telemetry (`repro.obs`)
-----------------------
The whole serving path publishes to the process-global metrics registry.
To watch a live run, expose the scrape endpoint and point a browser (or
Prometheus) at it::

    python -m repro.launch.serve --n 20000 --m 8000 \
        --backend cached:pruned:dense --update-stream \
        --metrics-port 9100 --audit-fraction 0.05 --stats-every 200

    curl localhost:9100/metrics          # Prometheus text exposition
    curl localhost:9100/metrics.json     # same registry as JSON

Key series: `serve_request_latency_ms` (histogram; p50/p99 in the JSON
snapshot), `serve_queue_depth` / `serve_rejected_total` (back-pressure),
`cache_hits_total` / `cache_misses_total`, `prune_skip_rate`,
`query_compiled_programs` (flat slope in steady state = no recompile
storm), and `maintenance_rebuilds_total` / `maintenance_build_ms`.

--audit-fraction > 0 starts the online quality auditor
(`repro.obs.audit`): that fraction of served queries is re-scored
EXACTLY against the snapshot it was served from, on a background thread.
Read the verdict from the gauges `audit_overall_ratio` /
`audit_accuracy` (rolling §5 criteria over the audit window — the
overall-ratio staying ≤ the bench-measured envelope means the c-contract
holds in production) and `audit_bound_width` (mean certified r↑−r↓ slack
of selected users). --metrics-json PATH dumps the final registry
snapshot to a file; --trace turns on `repro.obs.trace` spans
(per-tick/per-phase timing in `trace.spans()`; disabled by default —
the hot path only pays one flag check).

Ops runbook (PR 9 — fault tolerance)
------------------------------------
--deadline-ms D      every submission carries a D-millisecond deadline:
                     requests that expire in the queue are SHED before
                     occupying a tick slot (their futures raise
                     `DeadlineExceeded`), keeping tail latency bounded
                     under overload instead of serving everyone late.
                     Watch `serve_rejected_total{reason="deadline"}` and
                     `serve_expired_total`-adjacent tick stats (`exp` in
                     the stats line).
--degrade            arm the certified degrade ladder
                     (`repro.serve.degrade`): under sustained queue
                     pressure (depth ≥ --degrade-high for consecutive
                     ticks) the scheduler steps DOWN — 1: pruned
                     backends stop their dense fallback; 2: the
                     effective c widens (bounds still certified, the
                     auditor judges at the widened contract); 3:
                     cache-only serving (misses shed) — and back UP with
                     hysteresis once depth ≤ --degrade-low. The current
                     rung is the `serve_degrade_level` gauge; every
                     answer remains a certified (r↓, r↑) result — the
                     contract is RELAXED EXPLICITLY, never silently
                     violated.
--persist-dir PATH   crash-safe durability (`repro.index.persist`): an
                     atomic checksummed spill at startup and at every
                     rebuild, plus a per-mutation fsynced WAL between
                     spills. Recovery after a crash:
                     `ReverseKRanksEngine.restore(PATH)` — bitwise the
                     state at the durable point, `PersistError` means
                     rebuild from the master copy. A WAL write failure
                     degrades durability to the last spill (counted by
                     `persist_wal_errors_total`), never takes serving
                     down.
Signals              SIGTERM/SIGINT request GRACEFUL shutdown: the
                     submit loop stops, in-flight futures drain for at
                     most --drain-s seconds (whatever is still queued
                     past the drain deadline is shed with reason
                     "shutdown"), a final snapshot spill lands in
                     --persist-dir, and the process exits 0.
Fault injection      set REPRO_FAULTS="site:mode[:rate[:max_fires
                     [:latency_ms]]],..." (+ REPRO_FAULTS_SEED) before
                     launch to chaos-test any site in
                     `repro.serve.faults.SITES`; see also
                     `benchmarks/perf_engine.py --faults`.

Thread health: `maintenance_thread_alive` / `audit_thread_alive` are
callback gauges — 0 at scrape time means the background thread died (a
traceback was logged once); `maintenance_consecutive_failures` returning
to 0 after a rebuild failure means the loop recovered on its own.

Ops runbook (PR 10 — overlapped pipeline)
-----------------------------------------
--pipeline-depth N   how many dispatched ticks may be in flight at once
                     (default 2, double-buffered): the scheduler cuts
                     and launches tick t+1 while tick t's results are
                     still on device; a separate completion stage does
                     the tick's SINGLE blocking D2H off the dispatch
                     path and resolves futures from there. Queries stay
                     host-resident from submit to batch assembly (one
                     H2D per tick, donated on accelerator backends), so
                     `submit` never touches the device; under a caching
                     backend an exact LRU hit resolves AT ADMISSION
                     without occupying a queue or tick slot
                     (`serve_admission_hits_total`). Results are
                     bit-identical at every depth — 1 is the synchronous
                     schedule (stop-and-wait), worth choosing on
                     single-core CPU hosts where there is no transfer
                     latency to hide and eager tick cutting only adds
                     tail latency; ≥ 2 pays off where dispatch and D2H
                     are genuinely asynchronous (GPU/TPU).
Saturation           find this host's throughput knee (the offered load
                     where p99 > 2×p50) with the offered-load ramp:
                     `python -m benchmarks.perf_engine --serve
                     --saturate [--json out.json]` — per-arm knee QPS
                     and overlap efficiency land in the JSON; watch
                     `serve_inflight_ticks` (gauge), `serve_transfer_ms`
                     (the completion stage's D2H histogram) and the
                     `ovl {..}` overlap-efficiency field in the stats
                     line during a live run.
"""
from __future__ import annotations

import argparse
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReverseKRanksEngine, available_backends, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.types import RankTableConfig
from repro.data.pipeline import synthetic_embeddings
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import synthetic_ratings
from repro.index import IndexPersister, MaintenanceLoop, MaintenancePolicy
from repro.obs import registry as obs
from repro.obs import trace
from repro.obs.audit import QualityAuditor
from repro.serve import (DeadlineExceeded, DegradeController, DegradePolicy,
                         MicroBatcher, QueueFull, SchedulerClosed)


def build_embeddings(args):
    key = jax.random.PRNGKey(args.seed)
    if args.mf:
        ii, jj, rr = synthetic_ratings(key, args.n, args.m,
                                       n_obs=args.n_ratings)
        state, losses = train_mf(key, args.n, args.m, ii, jj, rr,
                                 MFConfig(d=args.d, epochs=args.mf_epochs))
        print(f"MF losses: {losses[0]:.4f} → {losses[-1]:.4f}")
        return embeddings(state)
    return synthetic_embeddings(key, args.n, args.m, args.d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=8_000)
    ap.add_argument("--d", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--c", type=float, default=2.0)
    ap.add_argument("--tau", type=int, default=500)
    ap.add_argument("--omega", type=int, default=10)
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--storage", default="f32",
                    choices=("f32", "bf16", "int8"),
                    help="storage spec for users/thresholds/table (PR 5): "
                         "f32 exact; bf16/int8 quantized with certified "
                         "bound widening")
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--backend", default="dense",
                    help="query-execution backend: one of "
                         f"{available_backends()} or a wrapped spec like "
                         "'cached:fused' (see repro.core.backends)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="scheduler tick size (compiled query_batch shape)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="latency/throughput knob: how long a partial tick "
                         "waits for more queries before dispatching")
    ap.add_argument("--max-depth", type=int, default=None,
                    help="admission bound: submits beyond this queue depth "
                         "fail fast with QueueFull (default: unbounded)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="ticks allowed in flight at once (PR 10): 1 = "
                         "synchronous stop-and-wait, 2 = double-buffered "
                         "overlap of dispatch and completion (default)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: queued requests past it "
                         "are shed (DeadlineExceeded) instead of served "
                         "late (default: none)")
    ap.add_argument("--degrade", action="store_true",
                    help="arm the certified degrade ladder under "
                         "sustained overload (see module docstring)")
    ap.add_argument("--degrade-high", type=int, default=32,
                    help="queue depth at/above which the ladder steps "
                         "down (after a dwell of consecutive ticks)")
    ap.add_argument("--degrade-low", type=int, default=4,
                    help="queue depth at/below which it steps back up")
    ap.add_argument("--persist-dir", default=None, metavar="PATH",
                    help="crash-safe durability: spill + WAL under PATH; "
                         "recover with ReverseKRanksEngine.restore(PATH)")
    ap.add_argument("--drain-s", type=float, default=5.0,
                    help="graceful-shutdown bound: how long SIGTERM/"
                         "SIGINT waits for queued requests before "
                         "shedding the remainder")
    ap.add_argument("--update-stream", action="store_true",
                    help="replay streaming item inserts/deletes while "
                         "serving, with background rebuild + hot-swap")
    ap.add_argument("--update-every", type=int, default=16,
                    help="queries between update batches")
    ap.add_argument("--insert-batch", type=int, default=8)
    ap.add_argument("--delete-batch", type=int, default=4)
    ap.add_argument("--rebuild-delta-ratio", type=float, default=0.05,
                    help="maintenance policy: rebuild past this |delta|/m")
    ap.add_argument("--rebuild-stale-frac", type=float, default=0.02,
                    help="maintenance policy: rebuild past this tombstoned-"
                         "sample weight fraction (rank-error budget)")
    ap.add_argument("--kernels", action="store_true",
                    help="deprecated alias for --backend fused")
    ap.add_argument("--mf", action="store_true",
                    help="produce embeddings with the JAX MF trainer")
    ap.add_argument("--mf-epochs", type=int, default=5)
    ap.add_argument("--n-ratings", type=int, default=200_000)
    ap.add_argument("--eval-exact", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="score against the exact oracle "
                         "(--no-eval-exact to skip)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus text) and "
                         "/metrics.json on this port (0 = ephemeral)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the final registry snapshot to PATH")
    ap.add_argument("--audit-fraction", type=float, default=0.0,
                    help="fraction of served queries shadow-sampled by "
                         "the online quality auditor (exact re-scoring "
                         "on a background thread; 0 disables)")
    ap.add_argument("--stats-every", type=int, default=0,
                    help="print a one-line serving stats summary every N "
                         "submissions (0 disables)")
    ap.add_argument("--trace", action="store_true",
                    help="record repro.obs trace spans for every tick/"
                         "phase (off by default; tiny per-tick cost)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.kernels and args.backend != "dense":
        ap.error("--kernels is a deprecated alias for --backend fused; "
                 f"it cannot be combined with --backend {args.backend}")

    if args.trace:
        trace.enable()
    if args.metrics_port is not None:
        srv = obs.start_http_server(args.metrics_port)
        host, port = srv.server_address[:2]
        print(f"metrics: http://{host}:{port}/metrics  (+ /metrics.json)")

    users, items = build_embeddings(args)
    cfg = RankTableConfig(tau=args.tau, omega=args.omega, s=args.s,
                          storage_dtype=args.storage)
    backend = "fused" if args.kernels else args.backend

    t0 = time.time()
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1),
                                    backend=backend)
    jax.block_until_ready(eng.rank_table.table)
    print(f"build: {time.time()-t0:.2f}s  "
          f"index {eng.memory_bytes()/2**20:.1f} MiB "
          f"(n={args.n:,} m={args.m:,} d={args.d})")

    qkey = jax.random.PRNGKey(2)
    qidx = jax.random.randint(qkey, (args.queries,), 0, args.m)
    qs = items[qidx]

    # warm-up (compiles the padded tick shape), then the async serving loop:
    # every query is SUBMITTED individually; the MicroBatcher coalesces
    # them into --max-batch ticks, waiting at most --max-wait-ms to fill.
    B = max(1, min(args.max_batch, args.queries))
    res = eng.query_batch(qs[:B], k=args.k, c=args.c)
    jax.block_until_ready(res.indices)

    persister = None
    if args.persist_dir:
        persister = IndexPersister(args.persist_dir)
        eng.attach_persister(persister)
        print(f"persistence: spill + WAL under {args.persist_dir} "
              f"(recover with ReverseKRanksEngine.restore(...))")
    maint = None
    if args.update_stream:
        maint = MaintenanceLoop(
            eng, policy=MaintenancePolicy(
                max_delta_ratio=args.rebuild_delta_ratio,
                max_stale_fraction=args.rebuild_stale_frac),
            poll_ms=10.0)
    auditor = None
    if args.audit_fraction > 0:
        auditor = QualityAuditor(eng, fraction=args.audit_fraction,
                                 seed=args.seed)
    degrade = None
    if args.degrade:
        degrade = DegradeController(
            DegradePolicy(high_depth=args.degrade_high,
                          low_depth=args.degrade_low),
            backend=eng._backend)      # cache auto-discovered for rung 3

    # graceful shutdown: first SIGTERM/SIGINT stops the submit loop; the
    # scheduler then drains for at most --drain-s and sheds the rest with
    # reason "shutdown"; a final spill lands before exit 0
    stop = threading.Event()

    def _on_signal(signum, frame):
        if not stop.is_set():
            print(f"\nsignal {signal.Signals(signum).name}: draining "
                  f"(bounded {args.drain_s:.0f}s), then exiting 0")
        stop.set()

    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, _on_signal)

    ukey = jax.random.PRNGKey(args.seed + 17)
    rng = np.random.default_rng(args.seed + 17)
    try:
        with MicroBatcher(eng, max_batch=B, max_wait_ms=args.max_wait_ms,
                          max_depth=args.max_depth,
                          auditor=auditor, degrade=degrade,
                          pipeline_depth=args.pipeline_depth) as mb:
            t0 = time.time()
            futs, accepted = [], []
            for i, q in enumerate(qs):
                if stop.is_set():
                    break
                if args.stats_every and i and i % args.stats_every == 0:
                    line = f"  [{i}/{args.queries}] {mb.stats()}"
                    if auditor is not None and auditor.scored:
                        line += (f"  audit ratio "
                                 f"{auditor.overall_ratio:.3f} "
                                 f"acc {auditor.accuracy:.3f}")
                    print(line)
                if (args.update_stream and i
                        and i % args.update_every == 0):
                    # live churn: fresh items in, random live items out —
                    # absorbed by the delta buffer while futures resolve;
                    # the maintenance loop hot-swaps rebuilds in the
                    # background when the policy triggers.
                    ukey, sub = jax.random.split(ukey)
                    eng.insert_items(jax.random.normal(
                        sub, (args.insert_batch, eng.d), jnp.float32))
                    live = eng.live_item_ids()
                    drop = rng.choice(live, size=min(args.delete_batch,
                                                     live.size - 1),
                                      replace=False)
                    eng.delete_items(drop)
                try:
                    futs.append(mb.submit(q, args.k, args.c,
                                          deadline_ms=args.deadline_ms))
                    accepted.append(i)
                except (QueueFull, DeadlineExceeded):
                    pass        # fail-fast back-pressure; counted in stats
            # pair each resolved result with ITS query index; shed
            # futures (deadline, shutdown drain, degrade-level-3 misses)
            # raise typed errors and are counted, never torn. A signal —
            # whether it landed during submission or while waiting here —
            # triggers ONE bounded drain: queued requests past --drain-s
            # are shed with reason "shutdown" (close is idempotent; the
            # context manager's second close is a no-op).
            results, shed, drained = [], 0, False
            for j, f in enumerate(futs):
                if stop.is_set() and not drained:
                    mb.close(drain_s=args.drain_s)
                    drained = True
                try:
                    results.append((accepted[j], f.result()))
                except (QueueFull, DeadlineExceeded, SchedulerClosed):
                    shed += 1
            elapsed = time.time() - t0
            st = mb.stats()
            epochs = sorted({t.epoch for t in mb.tick_log})
    finally:
        if maint is not None:
            maint.close()
        if persister is not None:
            # final durable point: mutations since the last spill were
            # already WAL-durable; this collapses them into one spill
            try:
                persister.spill(eng.current_snapshot(),
                                next_item_id=eng._next_item_id,
                                build_key=eng.build_key)
            except OSError:
                print("  WARNING: final spill failed; the WAL still "
                      "holds the mutations since the last spill")
            persister.close()
    print(f"serve: {elapsed/max(len(results), 1)*1e3:.2f} ms/query wall "
          f"({eng.backend_name} backend, max_batch={B}, "
          f"max_wait_ms={args.max_wait_ms})")
    print(f"  ticks: {st}" + (f"  shed futures: {shed}" if shed else ""))
    if degrade is not None and degrade.transitions:
        print(f"  degrade ladder: level now {degrade.level}, "
              f"transitions {degrade.transitions}")
    if args.update_stream:
        print(f"  update stream: final epoch {eng.epoch}, "
              f"{len(maint.rebuilds)} rebuild(s), epochs served {epochs}, "
              f"delta now: {eng.delta_stats()}")
        for r in maint.rebuilds:
            print(f"    rebuild {r.epoch_before}->{r.epoch_after} "
                  f"[{r.reason}] build {r.build_s:.2f}s "
                  f"swap {r.swap_s*1e3:.1f}ms")
    if auditor is not None:
        auditor.flush(timeout=60.0)
        print(f"  audit: {auditor.scored} scored "
              f"(fraction {args.audit_fraction})  rolling overall-ratio "
              f"{auditor.overall_ratio:.4f}  accuracy "
              f"{auditor.accuracy:.4f}  bound-width "
              f"{auditor.bound_width:.2f}")
        auditor.close()
    if args.metrics_json:
        import json
        with open(args.metrics_json, "w") as f:
            json.dump({"unix_time": time.time(),
                       "metrics": obs.get_default().snapshot()},
                      f, indent=2, default=str)
        print(f"  metrics snapshot → {args.metrics_json}")

    if stop.is_set():
        print("shutdown complete (drained, final state spilled); exit 0")
        return
    if args.eval_exact:
        # update-stream results span epochs; score POST-CHURN queries
        # against the FINAL live item set (a fresh engine pass, so every
        # scored result was computed on the state it is judged against).
        eval_items = eng.live_items() if args.update_stream else items
        n_eval = (min(args.queries, 20) if args.update_stream
                  else min(len(results), 20))
        if args.update_stream:
            post = eng.query_batch(qs[:n_eval], args.k, args.c)
            eval_pairs = [
                (qs[i], jax.tree_util.tree_map(lambda x, i=i: x[i], post))
                for i in range(n_eval)]
        else:
            # pair each served result with ITS query (back-pressure,
            # deadlines, or degrade sheds may have dropped some)
            eval_pairs = [(qs[i0], r) for i0, r in results[:n_eval]]
        accs, ratios = [], []
        for q_i, r in eval_pairs:
            truth = np.asarray(exact_ranks(users, eval_items, q_i))
            ex_idx, _ = reverse_k_ranks(users, eval_items, q_i, args.k)
            accs.append(metrics.accuracy(np.asarray(r.indices),
                                         np.asarray(ex_idx), truth, args.c))
            ratios.append(metrics.overall_ratio(
                np.asarray(r.indices), np.asarray(ex_idx), truth))
        print(f"accuracy {np.mean(accs):.4f}  overall-ratio "
              f"{np.mean(ratios):.4f}  (k={args.k}, c={args.c}"
              f"{', post-churn state' if args.update_stream else ''})")


if __name__ == "__main__":
    main()
