"""Serving driver for the paper's engine: build a rank-table index over
user/item embeddings and serve c-approximate reverse k-ranks queries
ONLINE through the async micro-batching scheduler, reporting the §5
quality metrics against the exact oracle.

`python -m repro.launch.serve --n 20000 --m 8000 [--backend fused] [--mf]`

Queries are submitted one at a time to `repro.serve.MicroBatcher`, which
coalesces them into --max-batch-sized ticks dispatched through
`engine.query_batch` (one rank-table pass per tick); --max-wait-ms is the
latency-vs-throughput knob (how long a partial tick waits to fill).
--backend accepts any registry name (dense|fused|sharded) plus wrapped
specs such as "cached:fused" (within-tick dedupe + cross-tick per-query
LRU; see repro.serve.cache). --no-eval-exact skips the oracle pass.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReverseKRanksEngine, available_backends, metrics
from repro.core.exact import exact_ranks, reverse_k_ranks
from repro.core.types import RankTableConfig
from repro.data.pipeline import synthetic_embeddings
from repro.data.mf import MFConfig, embeddings, train_mf
from repro.data.pipeline import synthetic_ratings
from repro.serve import MicroBatcher


def build_embeddings(args):
    key = jax.random.PRNGKey(args.seed)
    if args.mf:
        ii, jj, rr = synthetic_ratings(key, args.n, args.m,
                                       n_obs=args.n_ratings)
        state, losses = train_mf(key, args.n, args.m, ii, jj, rr,
                                 MFConfig(d=args.d, epochs=args.mf_epochs))
        print(f"MF losses: {losses[0]:.4f} → {losses[-1]:.4f}")
        return embeddings(state)
    return synthetic_embeddings(key, args.n, args.m, args.d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--m", type=int, default=8_000)
    ap.add_argument("--d", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--c", type=float, default=2.0)
    ap.add_argument("--tau", type=int, default=500)
    ap.add_argument("--omega", type=int, default=10)
    ap.add_argument("--s", type=int, default=64)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--backend", default="dense",
                    help="query-execution backend: one of "
                         f"{available_backends()} or a wrapped spec like "
                         "'cached:fused' (see repro.core.backends)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="scheduler tick size (compiled query_batch shape)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="latency/throughput knob: how long a partial tick "
                         "waits for more queries before dispatching")
    ap.add_argument("--kernels", action="store_true",
                    help="deprecated alias for --backend fused")
    ap.add_argument("--mf", action="store_true",
                    help="produce embeddings with the JAX MF trainer")
    ap.add_argument("--mf-epochs", type=int, default=5)
    ap.add_argument("--n-ratings", type=int, default=200_000)
    ap.add_argument("--eval-exact", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="score against the exact oracle "
                         "(--no-eval-exact to skip)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.kernels and args.backend != "dense":
        ap.error("--kernels is a deprecated alias for --backend fused; "
                 f"it cannot be combined with --backend {args.backend}")

    users, items = build_embeddings(args)
    cfg = RankTableConfig(tau=args.tau, omega=args.omega, s=args.s)
    backend = "fused" if args.kernels else args.backend

    t0 = time.time()
    eng = ReverseKRanksEngine.build(users, items, cfg,
                                    jax.random.PRNGKey(1),
                                    backend=backend)
    jax.block_until_ready(eng.rank_table.table)
    print(f"build: {time.time()-t0:.2f}s  "
          f"index {eng.memory_bytes()/2**20:.1f} MiB "
          f"(n={args.n:,} m={args.m:,} d={args.d})")

    qkey = jax.random.PRNGKey(2)
    qidx = jax.random.randint(qkey, (args.queries,), 0, args.m)
    qs = items[qidx]

    # warm-up (compiles the padded tick shape), then the async serving loop:
    # every query is SUBMITTED individually; the MicroBatcher coalesces
    # them into --max-batch ticks, waiting at most --max-wait-ms to fill.
    B = max(1, min(args.max_batch, args.queries))
    res = eng.query_batch(qs[:B], k=args.k, c=args.c)
    jax.block_until_ready(res.indices)
    with MicroBatcher(eng, max_batch=B,
                      max_wait_ms=args.max_wait_ms) as mb:
        t0 = time.time()
        futs = [mb.submit(q, args.k, args.c) for q in qs]
        results = [f.result() for f in futs]
        elapsed = time.time() - t0
        st = mb.stats()
    print(f"serve: {elapsed/args.queries*1e3:.2f} ms/query wall "
          f"({eng.backend_name} backend, max_batch={B}, "
          f"max_wait_ms={args.max_wait_ms})")
    print(f"  ticks: {st}")

    if args.eval_exact:
        accs, ratios = [], []
        for i in range(min(args.queries, 20)):
            truth = np.asarray(exact_ranks(users, items, qs[i]))
            ex_idx, _ = reverse_k_ranks(users, items, qs[i], args.k)
            r = results[i]                  # served through the scheduler
            accs.append(metrics.accuracy(np.asarray(r.indices),
                                         np.asarray(ex_idx), truth, args.c))
            ratios.append(metrics.overall_ratio(
                np.asarray(r.indices), np.asarray(ex_idx), truth))
        print(f"accuracy {np.mean(accs):.4f}  overall-ratio "
              f"{np.mean(ratios):.4f}  (k={args.k}, c={args.c})")


if __name__ == "__main__":
    main()
