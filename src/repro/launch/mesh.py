"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run forces 512 host devices before any
jax initialization, unit tests keep the single real device.
"""
from __future__ import annotations

import jax

TPU_V5E = {
    "peak_bf16_flops": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
