"""Training driver: `python -m repro.launch.train --arch <id> [...]`.

Production path: builds the mesh, shards params/optimizer with the model's
sharding rules, runs the pjit train step with checkpoint cadence,
preemption-safe resume, and a heartbeat/straggler log. On this CPU
container it runs reduced configs end-to-end (examples/train_lm.py) —
the full configs go through dryrun.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, reduced
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import Model
from repro.models.sharding import rules_for
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, cosine_schedule
from repro.train.trainer import make_train_step


def run_training(cfg, *, steps: int, global_batch: int, seq_len: int,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 mesh=None, microbatches: int = 1, lr: float = 3e-4,
                 log_every: int = 10, seed: int = 0):
    model = Model(cfg)
    rules = rules_for(cfg, mesh, batch_size=global_batch) if mesh else None
    opt = AdamWConfig(lr=lr)
    sched = lambda s: cosine_schedule(s, warmup=max(steps // 20, 10),
                                      total=steps)
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=seq_len,
                                        global_batch=global_batch,
                                        seed=seed))
    step_fn = make_train_step(model, opt, rules, microbatches=microbatches,
                              schedule=sched)

    params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        tpl = {"params": model.abstract_params(),
               "opt": jax.eval_shape(adamw_init, model.abstract_params())}
        state, start, _ = ckpt.restore(ckpt_dir, tpl)
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    if mesh is not None:
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              model.param_specs(rules))
        oshard = type(opt_state)(mu=pshard, nu=pshard,
                                 step=NamedSharding(mesh, P()))
        step_fn = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                          out_shardings=(pshard, oshard, None),
                          donate_argnums=(0, 1))
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    losses, step_times = [], []
    for step in range(start, steps):
        t0 = time.time()
        batch = pipe.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        dt = time.time() - t0
        step_times.append(dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{dt*1e3:.0f} ms/step")
        # straggler heartbeat: a step >5× the running median is flagged
        # (on a real cluster this triggers the preemption/replace path)
        if len(step_times) > 5 and dt > 5 * float(
                np.median(step_times[-50:])):
            print(f"[heartbeat] straggler step {step}: {dt:.2f}s vs "
                  f"median {np.median(step_times[-50:]):.2f}s")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state})
            ckpt.prune_old(ckpt_dir, keep=3)
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published config (needs real accelerators)")
    ap.add_argument("--d-model", type=int, default=256,
                    help="reduced-config width override")
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced(cfg, layers=args.layers)
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_model * 4,
            vocab=min(cfg.vocab, 8192))
    _, losses = run_training(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, ckpt_dir=args.ckpt_dir, lr=args.lr,
        microbatches=args.microbatches)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
