import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below this line may touch jax ------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P    # noqa: E402

from repro.configs import ARCH_IDS, get_config                # noqa: E402
from repro.configs.paper_engine import (AMAZON_K, DEFAULT_TABLE,  # noqa: E402
                                        DATASETS)
from repro.core import distributed as D                       # noqa: E402
from repro.core.types import RankTable                        # noqa: E402
from repro.launch import roofline as RL                       # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models.config import SHAPE_CELLS, cell_applicable  # noqa: E402
from repro.models.model import Model                          # noqa: E402
from repro.models.sharding import rules_for                   # noqa: E402
from repro.train.optimizer import AdamWConfig, adamw_init     # noqa: E402
from repro.train.trainer import (make_prefill_step,           # noqa: E402
                                 make_serve_step, make_train_step)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) cell with ShapeDtypeStruct stand-ins —
no allocation — and record memory/cost/collective analyses for §Roofline.

The XLA_FLAGS line above MUST precede any jax-touching import: jax locks
the device count at first backend initialization.
"""

CELLS = {c.name: c for c in SHAPE_CELLS}
OUTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "experiments", "dryrun")


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def dryrun_cell(arch_id: str, cell_name: str, *, multi_pod: bool = False,
                mesh=None, verbose: bool = True) -> dict:
    """Lower+compile one cell; returns the §Dry-run/§Roofline record."""
    cfg = get_config(arch_id)
    cell = CELLS[cell_name]
    ok, reason = cell_applicable(cfg, cell)
    rec = {"arch": arch_id, "cell": cell_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="SKIP", reason=reason)
        return rec

    t0 = time.time()
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    # Train: f32 master weights + FSDP on the expert axis (params+AdamW of
    # a 109B MoE cannot fit 16 GB/chip otherwise). Serve: bf16 weights,
    # no FSDP (weights stay resident; no per-step gather at decode).
    is_train = cell.kind == "train"
    rules = rules_for(cfg, mesh, batch_size=cell.global_batch,
                      fsdp=is_train)
    model = Model(cfg)

    params_sds = model.abstract_params()
    if not is_train:
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else
                s.dtype), params_sds)
    pshard = _sharding_tree(mesh, model.param_specs(rules))
    batch_sds = model.input_specs(cell)
    bshard = _sharding_tree(mesh, model.batch_specs(rules, cell))
    tokens = cell.global_batch * (1 if cell.is_decode else cell.seq_len)

    with jax.set_mesh(mesh):
        if cell.kind == "train":
            opt_sds = jax.eval_shape(adamw_init, params_sds)
            oshard = type(opt_sds)(mu=pshard, nu=pshard,
                                   step=NamedSharding(mesh, P()))
            fn = make_train_step(model, AdamWConfig(), rules)
            lowered = jax.jit(
                fn, in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None)).lower(
                    params_sds, opt_sds, batch_sds)
            mf = RL.model_flops_train(cfg, params_sds, tokens)
        elif cell.kind == "prefill":
            fn = make_prefill_step(model, rules)
            lowered = jax.jit(
                fn, in_shardings=(pshard, bshard)).lower(
                    params_sds, batch_sds)
            mf = RL.model_flops_train(cfg, params_sds, tokens) / 3.0
        else:                                   # decode
            cache_sds = model.abstract_cache(cell.global_batch,
                                             cell.seq_len)
            cshard = _sharding_tree(
                mesh, model.cache_specs(rules, cell.global_batch,
                                        cell.seq_len))
            fn = make_serve_step(model, rules)
            lowered = jax.jit(
                fn, in_shardings=(pshard, cshard, bshard["tokens"]),
                out_shardings=(None, cshard)).lower(
                    params_sds, cache_sds, batch_sds["tokens"])
            mf = RL.model_flops_decode(cfg, params_sds, tokens)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = RL.analyze(compiled, chips=chips, model_flops=mf)
    rec.update(
        status="OK",
        chips=chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0)
                             + getattr(mem, "argument_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        roofline=roof.as_dict(),
    )
    if verbose:
        print(f"[{rec['mesh']}] {arch_id} × {cell_name}: OK  "
              f"flops/dev={roof.flops:.3e} hbm/dev={roof.hbm_bytes:.3e} "
              f"coll/dev={roof.coll_bytes:.3e} → {roof.bottleneck}-bound  "
              f"(compile {rec['compile_s']}s, "
              f"args/dev {rec['arg_bytes']/2**30:.2f} GiB, "
              f"temp/dev {rec['temp_bytes']/2**30:.2f} GiB)")
    return rec


def dryrun_engine(*, multi_pod: bool = True, dataset=AMAZON_K,
                  k: int = 10, c: float = 2.0, verbose: bool = True
                  ) -> list[dict]:
    """Paper-engine cells at full dataset scale on the flat mesh:
    build (Algorithm 1), query (§4.3 tree-merge), ring refinement."""
    mesh = D.flat_mesh(make_production_mesh(multi_pod=multi_pod))
    chips = mesh.devices.size
    # shard_map needs equal shards: pad n, m up to multiples of |mesh|
    n = -(-dataset.n_users // chips) * chips
    m_raw = dataset.n_items
    m = -(-m_raw // chips) * chips
    d = dataset.d
    f32 = jnp.float32
    users_sds = jax.ShapeDtypeStruct((n, d), f32)
    items_sds = jax.ShapeDtypeStruct((m, d), f32)
    q_sds = jax.ShapeDtypeStruct((d,), f32)
    cfg = DEFAULT_TABLE
    recs = []

    def record(name, lowered, mf=None):
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        roof = RL.analyze(compiled, chips=chips, model_flops=mf)
        rec = {"arch": f"engine/{dataset.name}", "cell": name,
               "mesh": f"flat{chips}", "status": "OK", "chips": chips,
               "bytes_per_device": int(mem.temp_size_in_bytes
                                       + mem.argument_size_in_bytes),
               "temp_bytes": int(mem.temp_size_in_bytes),
               "arg_bytes": int(mem.argument_size_in_bytes),
               "roofline": roof.as_dict()}
        if verbose:
            print(f"[flat{chips}] engine/{dataset.name} × {name}: OK  "
                  f"flops/dev={roof.flops:.3e} coll/dev="
                  f"{roof.coll_bytes:.3e} → {roof.bottleneck}-bound")
        recs.append(rec)

    key = jax.random.PRNGKey(0)
    record("build", jax.jit(
        lambda u, i: D.build_sharded(u, i, cfg, key, mesh)).lower(
            users_sds, items_sds),
        mf=2.0 * n * cfg.omega * cfg.s * d)           # score matmul FLOPs

    rt_sds = RankTable(
        thresholds=jax.ShapeDtypeStruct((n, cfg.tau), f32),
        table=jax.ShapeDtypeStruct((n, cfg.tau), f32),
        m=jax.ShapeDtypeStruct((), jnp.int32))
    qfn = D.make_query_fn(mesh, k=k, n=n, c=c)
    record("query", jax.jit(qfn).lower(rt_sds, users_sds, q_sds),
           mf=2.0 * n * d)                            # the O(nd) step 1
    record("refine_ring", jax.jit(
        lambda u, i, q: D.ring_exact_ranks(u, i, q, mesh)).lower(
            users_sds, items_sds, q_sds),
        mf=2.0 * n * m * d / 1.0)
    return recs


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell")
    ap.add_argument("--engine", action="store_true",
                    help="paper-engine cells at dataset scale")
    ap.add_argument("--dataset", default="amazon-k",
                    choices=list(DATASETS))
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    records = []
    meshes = ([False, True] if args.both_meshes else [args.multi_pod])
    if args.engine:
        for mp in meshes:
            records += dryrun_engine(multi_pod=mp,
                                     dataset=DATASETS[args.dataset])
    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(CELLS) if args.all or args.shape is None else [args.shape]
    if not args.engine or args.all or args.arch or args.shape:
        for mp in meshes:
            mesh = make_production_mesh(multi_pod=mp)
            for a in archs:
                for s in shapes:
                    try:
                        records.append(dryrun_cell(a, s, multi_pod=mp,
                                                   mesh=mesh))
                    except Exception as e:      # a failure is a bug: record
                        traceback.print_exc()
                        records.append({"arch": a, "cell": s,
                                        "mesh": "2x16x16" if mp else "16x16",
                                        "status": "FAIL",
                                        "error": repr(e)})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    n_ok = sum(r["status"] == "OK" for r in records)
    n_skip = sum(r["status"] == "SKIP" for r in records)
    n_fail = sum(r["status"] == "FAIL" for r in records)
    print(f"\ndry-run: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL "
          f"/ {len(records)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
