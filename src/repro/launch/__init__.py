"""Launchers: production mesh, multi-pod dry-run, training and serving
drivers, roofline extraction."""
