"""Crash-safe index persistence: atomic snapshot spills + a delta WAL.

The engine's durable state is (a) the big, rarely-changing build output
and (b) a small, hot stream of mutations. Persisting them the same way
would either fsync a multi-GB table per insert or leave rebuilds
unrecoverable — so this module splits them:

  spill  — one ATOMIC file per published rebuild epoch
           (``spill-<epoch:016d>``): write to a temp name, flush, fsync,
           ``os.replace`` — a reader can never observe a half-written
           spill under its final name. Content is CRC-framed, so a spill
           torn by the filesystem anyway (crash between rename and data
           sync on a non-ordered fs, bit rot, an injected
           ``persist.spill`` fault) is DETECTED and skipped, never
           loaded. Spills are spec-aware: rank-table arrays are stored
           exactly as packed (int8 tables spill packed, bf16 as raw
           bits), and everything re-derivable is NOT stored — samples /
           weights re-derive from (items, item_ids, config, build_key)
           via `BaseIndex.create`, spec-space user storage from
           `pack_users`, the delta correction from `build_correction`;
           all deterministic, so a restore is bitwise the state that was
           spilled.
  WAL    — an append-only log per spill epoch (``wal-<epoch:016d>.log``)
           of the four mutation ops (insert_items / delete_items /
           upsert_users / delete_users), one CRC-framed record each,
           fsynced per append. Recovery replays the WAL through the
           NORMAL mutation API, so every invariant of the live path
           (row re-estimation, correction rebuild, epoch bump) holds on
           the recovered engine by construction; inserted ids are
           asserted against the recorded ones — a divergence is a
           `PersistError`, never a silently different index.

Durability model: the durable point is (newest valid spill) + (its WAL
prefix up to the first torn record). A torn WAL TAIL — the expected
artifact of crashing mid-append — truncates to the last complete record;
a corrupt INTERIOR record (a later record is intact while an earlier one
is not) means the log cannot be trusted at all and recovery raises
`PersistError` — rebuild from the master copy rather than serve wrong
answers. A torn NEWEST spill falls back to the previous spill epoch (its
own WAL is still on disk), trading recency for validity; `keep_spills`
bounds how many durable points are retained.

A WAL WRITE failure at runtime (disk full, injected ``persist.wal_write``
fault) must not take serving down: the error is logged once, counted
(``persist_wal_errors_total``), and the WAL is disabled until the next
spill re-baselines durability — the engine keeps serving with
durability degraded to the last spill, never wedged.

Fault sites (`repro.serve.faults`): ``persist.spill`` (mode="torn"
truncates the spill mid-write) and ``persist.wal_write`` (append raises)
— both evaluated through `should_fire`, one flag check when disabled.
"""
from __future__ import annotations

import dataclasses
import io
import json
import logging
import os
import re
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.types import RankTable, RankTableConfig
from repro.index import delta as delta_mod
from repro.index.snapshot import IndexSnapshot
from repro.obs import registry as obs
from repro.serve import faults

log = logging.getLogger(__name__)

SPILL_MAGIC = b"RKRSPIL1"       # 8 bytes; bump the digit on format breaks
WAL_MAGIC = b"RKW1"             # 4 bytes
_SPILL_HDR = len(SPILL_MAGIC) + 4 + 8       # magic + crc32 + u64 length
_WAL_HDR = len(WAL_MAGIC) + 4 + 8

WAL_OPS = ("insert_items", "delete_items", "upsert_users", "delete_users")

# RankTable fields spilled verbatim (quant fields absent on the f32 spec)
_RT_FIELDS = ("thresholds", "table", "m", "thr_scale", "thr_off",
              "tab_scale", "tab_off", "thr_dev")


class PersistError(RuntimeError):
    """The durable state is unusable (no valid spill, a corrupt WAL
    interior, or a replay divergence) — rebuild from the master copy.
    Recovery NEVER degrades to a maybe-wrong index: anything checksum- or
    replay-suspect raises this instead of loading."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One decoded mutation record, in append order."""

    op: str
    seq: int
    arrays: Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class RestoredState:
    """Everything `ReverseKRanksEngine.restore` needs: the reconstructed
    spill-point snapshot plus the WAL records to replay on top of it."""

    snapshot: IndexSnapshot
    config: RankTableConfig
    build_key: Any
    next_item_id: int
    wal: List[WalRecord]
    spill_path: str


# --------------------------------------------------------------- encoding
def _encode_array(value) -> tuple:
    """(savez-safe ndarray, true-dtype name). npy cannot serialize the
    ml_dtypes extension types, so bf16 is stored as raw uint16 bits and
    viewed back on load; every other dtype in play is numpy-native."""
    a = np.asarray(jax.device_get(value))
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, a.dtype.name


def _decode_array(a: np.ndarray, name: str) -> np.ndarray:
    if name == "bfloat16":
        return a.view(ml_dtypes.bfloat16)
    return a


def _pack_npz(arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _unpack_npz(payload: bytes) -> Dict[str, np.ndarray]:
    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


def _meta_array(meta: dict) -> np.ndarray:
    return np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8)


def _frame(magic: bytes, payload: bytes) -> bytes:
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    return (magic + crc.to_bytes(4, "little")
            + len(payload).to_bytes(8, "little") + payload)


def _key_arrays(build_key):
    """(storable key bits, typed?, impl name) for the Algorithm-1 key —
    both legacy raw-uint32 keys and typed `jax.random.key` keys spill."""
    if jnp.issubdtype(build_key.dtype, jax.dtypes.prng_key):
        impl = str(jax.random.key_impl(build_key))
        return np.asarray(jax.random.key_data(build_key)), True, impl
    return np.asarray(jax.device_get(build_key)), False, ""


def _key_restore(data: np.ndarray, typed: bool, impl: str):
    if not typed:
        return jnp.asarray(data)
    try:
        return jax.random.wrap_key_data(jnp.asarray(data), impl=impl)
    except (TypeError, ValueError):        # impl spelling drift across jax
        return jax.random.wrap_key_data(jnp.asarray(data))


# ------------------------------------------------------------ spill codec
def _spill_payload(snap: IndexSnapshot, next_item_id: int,
                   build_key) -> bytes:
    if snap.base is None:
        raise PersistError(
            "cannot spill a snapshot without its base item set; build the "
            "engine with ReverseKRanksEngine.build(...)")
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}

    def put(name, value):
        arrays[name], dtypes[name] = _encode_array(value)

    put("users", snap.users)
    for f in _RT_FIELDS:
        v = getattr(snap.rank_table, f)
        if v is not None:
            put(f"rt_{f}", v)
    put("base_items", snap.base.items)
    arrays["base_item_ids"] = np.asarray(snap.base.item_ids, np.int64)
    key_data, key_typed, key_impl = _key_arrays(build_key)
    put("key_data", key_data)
    d = snap.delta
    arrays["delta_base_live"] = np.asarray(d.base_live, bool)
    arrays["delta_added_ids"] = np.asarray(d.added_ids, np.int64)
    if d.added_items is not None:
        put("delta_added_items", d.added_items)
    arrays["delta_user_live"] = np.asarray(d.user_live, bool)
    arrays["delta_touched"] = np.asarray(sorted(d.touched_users), np.int64)
    if snap.user_remap is not None:
        arrays["user_remap"] = np.asarray(snap.user_remap, np.int64)
    meta = {"format": 1, "epoch": int(snap.epoch),
            "next_item_id": int(next_item_id),
            "key_typed": key_typed, "key_impl": key_impl,
            "config": dataclasses.asdict(snap.config),
            "dtypes": dtypes}
    arrays["meta"] = _meta_array(meta)
    return _pack_npz(arrays)


def _snapshot_from_payload(arrays: Dict[str, np.ndarray]):
    """Reconstruct (snapshot, meta, build_key) from decoded spill arrays.
    Everything not stored re-derives deterministically (module doc), so
    the result is bitwise the snapshot that was spilled."""
    meta = json.loads(bytes(arrays["meta"]).decode("utf-8"))
    dt = meta["dtypes"]

    def get(name):
        return jnp.asarray(_decode_array(arrays[name], dt.get(name, "")))

    cfg = RankTableConfig(**meta["config"])
    users = get("users")
    rt = RankTable(**{f: (get(f"rt_{f}") if f"rt_{f}" in arrays else None)
                      for f in _RT_FIELDS})
    key = _key_restore(arrays["key_data"], meta["key_typed"],
                       meta["key_impl"])
    base = delta_mod.BaseIndex.create(
        get("base_items"), np.asarray(arrays["base_item_ids"], np.int64),
        cfg, key)
    delta = delta_mod.DeltaState(
        base_live=np.asarray(arrays["delta_base_live"], bool),
        added_ids=np.asarray(arrays["delta_added_ids"], np.int64),
        added_items=(get("delta_added_items")
                     if "delta_added_items" in arrays else None),
        user_live=np.asarray(arrays["delta_user_live"], bool),
        touched_users=frozenset(int(i) for i in arrays["delta_touched"]))
    # build_correction returns None on an empty delta — exactly the rule
    # `_publish` follows, so corr-is-None round-trips too
    corr = delta_mod.build_correction(users, base, delta, base.m_base,
                                      spec=cfg.storage)
    remap = (np.asarray(arrays["user_remap"], np.int64)
             if "user_remap" in arrays else None)
    snap = IndexSnapshot(
        epoch=int(meta["epoch"]), users=users, rank_table=rt, config=cfg,
        base=base, delta=delta, corr=corr, user_remap=remap,
        stored_users=cfg.storage.pack_users(users))
    return snap, meta, key


def _read_spill(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        data = f.read()
    if len(data) < _SPILL_HDR or data[:len(SPILL_MAGIC)] != SPILL_MAGIC:
        raise PersistError(f"spill {path!r}: bad magic or truncated header")
    crc = int.from_bytes(data[8:12], "little")
    ln = int.from_bytes(data[12:20], "little")
    payload = data[_SPILL_HDR:_SPILL_HDR + ln]
    if len(payload) < ln or len(data) != _SPILL_HDR + ln:
        raise PersistError(f"spill {path!r}: torn (have {len(data)} bytes, "
                           f"framed length says {_SPILL_HDR + ln})")
    if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
        raise PersistError(f"spill {path!r}: checksum mismatch")
    return _unpack_npz(payload)


# -------------------------------------------------------------- WAL codec
def _wal_payload(op: str, seq: int, arrays: Dict[str, Any]) -> bytes:
    enc: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for name, value in arrays.items():
        enc[name], dtypes[name] = _encode_array(value)
    enc["meta"] = _meta_array({"op": op, "seq": int(seq), "dtypes": dtypes})
    return _pack_npz(enc)


def _decode_wal_payload(payload: bytes) -> WalRecord:
    arrays = _unpack_npz(payload)
    meta = json.loads(bytes(arrays.pop("meta")).decode("utf-8"))
    out = {k: _decode_array(v, meta["dtypes"].get(k, ""))
           for k, v in arrays.items()}
    return WalRecord(op=meta["op"], seq=int(meta["seq"]), arrays=out)


def _read_wal(path: str) -> List[WalRecord]:
    """Decode records in order. Torn TAIL → accept the prefix (crash
    mid-append); corrupt INTERIOR (an intact frame exists after the bad
    one) → `PersistError` (module doc)."""
    with open(path, "rb") as f:
        data = f.read()
    records: List[WalRecord] = []
    off, n = 0, len(data)
    while off < n:
        ok = False
        if (data[off:off + len(WAL_MAGIC)] == WAL_MAGIC
                and off + _WAL_HDR <= n):
            crc = int.from_bytes(data[off + 4:off + 8], "little")
            ln = int.from_bytes(data[off + 8:off + 16], "little")
            payload = data[off + _WAL_HDR:off + _WAL_HDR + ln]
            ok = (len(payload) == ln
                  and (zlib.crc32(payload) & 0xFFFFFFFF) == crc)
        if not ok:
            if data.find(WAL_MAGIC, off + 1) != -1:
                raise PersistError(
                    f"WAL {path!r}: corrupt interior record at byte {off} "
                    "(intact records follow it); the log cannot be "
                    "trusted — rebuild from the master copy")
            log.warning("WAL %s: torn tail at byte %d of %d; accepting "
                        "the durable prefix of %d record(s)",
                        path, off, n, len(records))
            break
        rec = _decode_wal_payload(payload)
        if rec.seq != len(records):
            raise PersistError(
                f"WAL {path!r}: sequence gap (record #{len(records)} "
                f"carries seq {rec.seq}); rebuild from the master copy")
        if rec.op not in WAL_OPS:
            raise PersistError(f"WAL {path!r}: unknown op {rec.op!r}")
        records.append(rec)
        off += _WAL_HDR + ln
    return records


# --------------------------------------------------------------- persister
class IndexPersister:
    """Owns one durability directory: spills snapshots atomically and
    appends mutation records to the current WAL (module doc).

    Writes are serialized by the engine's mutation lock in normal use; an
    internal lock makes direct use safe too. `spill` ROTATES the WAL —
    mutations recorded before the spill are superseded by it, records
    after it land in the fresh log — which is why the engine spills
    inside the rebuild's locked swap section: no mutation can fall
    between the publish and the rotation.
    """

    def __init__(self, path, *, keep_spills: int = 2,
                 registry: Optional[obs.MetricsRegistry] = None):
        if keep_spills < 1:
            raise ValueError(f"keep_spills must be >= 1; got {keep_spills}")
        self.dir = str(path)
        os.makedirs(self.dir, exist_ok=True)
        self.keep_spills = int(keep_spills)
        self._lock = threading.Lock()
        self._wal = None
        self._wal_broken = False
        self._seq = 0
        reg = registry if registry is not None else obs.get_default()
        self._m_spills = reg.counter(
            "persist_spills_total", "atomic snapshot spills written")
        self._m_wal_records = reg.counter(
            "persist_wal_records_total", "mutation records appended")
        self._m_wal_errors = reg.counter(
            "persist_wal_errors_total",
            "WAL appends that failed (durability degraded to last spill)")
        self._m_spill_bytes = reg.gauge(
            "persist_spill_bytes", "size of the most recent spill file")

    # ------------------------------------------------------------- writing
    def spill(self, snap: IndexSnapshot, *, next_item_id: int,
              build_key) -> str:
        """Write ``spill-<epoch>`` atomically, rotate the WAL to a fresh
        ``wal-<epoch>.log``, prune durable points beyond `keep_spills`.
        Returns the spill path."""
        blob = _frame(SPILL_MAGIC,
                      _spill_payload(snap, next_item_id, build_key))
        if faults.ACTIVE is not None and faults.should_fire("persist.spill"):
            # torn-write chaos: persist a deliberately truncated file
            # (as a crash mid-spill would) — recovery must detect it by
            # checksum and fall back, never load it
            blob = blob[:max(len(blob) // 2, len(SPILL_MAGIC))]
        path = os.path.join(self.dir, f"spill-{snap.epoch:016d}")
        tmp = path + ".tmp"
        with self._lock:
            with open(tmp, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            self._fsync_dir()
            if self._wal is not None:
                self._wal.close()
            self._wal = open(
                os.path.join(self.dir, f"wal-{snap.epoch:016d}.log"), "wb")
            self._wal_broken = False    # a fresh baseline re-arms the WAL
            self._seq = 0
            self._m_spills.inc()
            self._m_spill_bytes.set(len(blob))
            self._prune()
        return path

    def append(self, op: str, arrays: Dict[str, Any]) -> bool:
        """Append one fsynced mutation record to the current WAL. Returns
        False (serving continues, durability degraded to the last spill)
        when no WAL is open or a write ever failed since the last spill."""
        if op not in WAL_OPS:
            raise ValueError(f"unknown WAL op {op!r}; one of {WAL_OPS}")
        with self._lock:
            if self._wal is None or self._wal_broken:
                return False
            frame = _frame(WAL_MAGIC, _wal_payload(op, self._seq, arrays))
            try:
                if (faults.ACTIVE is not None
                        and faults.should_fire("persist.wal_write")):
                    raise OSError(
                        "injected WAL write failure (persist.wal_write)")
                self._wal.write(frame)
                self._wal.flush()
                os.fsync(self._wal.fileno())
            except OSError:
                self._wal_broken = True
                self._m_wal_errors.inc()
                log.exception(
                    "WAL append failed; serving continues with durability "
                    "degraded to the last spill until the next rebuild "
                    "spills a fresh baseline")
                return False
            self._seq += 1
            self._m_wal_records.inc()
            return True

    def close(self) -> None:
        with self._lock:
            if self._wal is not None:
                self._wal.close()
                self._wal = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ----------------------------------------------------------- internals
    def _fsync_dir(self) -> None:
        try:        # the rename itself must be durable, where supported
            fd = os.open(self.dir, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        except OSError:
            pass

    def _prune(self) -> None:
        for ep in _spill_epochs(self.dir)[:-self.keep_spills]:
            for fn in (f"spill-{ep:016d}", f"wal-{ep:016d}.log"):
                try:
                    os.remove(os.path.join(self.dir, fn))
                except OSError:
                    pass


# --------------------------------------------------------------- recovery
def _spill_epochs(path: str) -> List[int]:
    out = []
    for fn in os.listdir(path):
        m = re.fullmatch(r"spill-(\d{16})", fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def load_latest(path) -> RestoredState:
    """Load the newest valid durable point from a persistence directory:
    newest checksum-valid spill + its WAL records. A torn newest spill
    falls back to the previous one (warned); no valid spill at all, or a
    corrupt WAL interior, raises `PersistError`."""
    path = str(path)
    candidates = _spill_epochs(path)
    if not candidates:
        raise PersistError(f"no spill files in {path!r}")
    last_err: Optional[PersistError] = None
    for ep in reversed(candidates):
        spill_path = os.path.join(path, f"spill-{ep:016d}")
        try:
            arrays = _read_spill(spill_path)
        except PersistError as e:
            log.warning("%s; falling back to the previous durable point",
                        e)
            last_err = e
            continue
        wal_path = os.path.join(path, f"wal-{ep:016d}.log")
        records = _read_wal(wal_path) if os.path.exists(wal_path) else []
        snap, meta, key = _snapshot_from_payload(arrays)
        return RestoredState(snapshot=snap, config=snap.config,
                             build_key=key,
                             next_item_id=int(meta["next_item_id"]),
                             wal=records, spill_path=spill_path)
    raise PersistError(
        f"no valid spill in {path!r}; rebuild from the master copy"
    ) from last_err


def replay_record(engine, rec: WalRecord) -> None:
    """Apply one WAL record through the engine's NORMAL mutation API
    (module doc); insert-id divergence raises `PersistError`."""
    a = rec.arrays
    if rec.op == "insert_items":
        got = engine.insert_items(jnp.asarray(a["vectors"]))
        want = np.asarray(a["ids"], np.int64)
        if not np.array_equal(np.asarray(got, np.int64), want):
            raise PersistError(
                f"WAL replay diverged at record #{rec.seq}: insert_items "
                f"assigned ids {np.asarray(got).tolist()} but the log "
                f"recorded {want.tolist()}")
    elif rec.op == "delete_items":
        engine.delete_items([int(i) for i in a["ids"]])
    elif rec.op == "upsert_users":
        engine.upsert_users(
            jnp.asarray(a["vectors"]),
            indices=([int(i) for i in a["indices"]]
                     if "indices" in a else None))
    elif rec.op == "delete_users":
        engine.delete_users([int(i) for i in a["indices"]])
    else:       # _read_wal already rejects unknown ops; belt and braces
        raise PersistError(f"unknown WAL op {rec.op!r}")
