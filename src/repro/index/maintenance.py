"""Background index maintenance: rebuild policy + off-thread hot-swap.

The delta buffer keeps queries exact-in-expectation while it is small;
past a point the per-query correction cost and the tombstoned-sample
noise grow without bound. `MaintenanceLoop` watches the engine's
`DeltaStats` and, when the policy triggers, runs a FULL Algorithm 1
rebuild on the engine's configured backend (the sharded backend builds
row-sharded end-to-end via `distributed.build_sharded`) off the serving
threads, then hot-swaps the new epoch through the snapshot manager.
Serving never pauses: queries keep executing against the old snapshot
until the swap's single pointer assignment, and mutations that land while
the rebuild is running are re-based onto the new epoch during the swap
(`ReverseKRanksEngine.rebuild`).

Policy knobs:

  max_delta_ratio    — rebuild when (inserts + deletes) / m_base exceeds
                       the ρ bound the query-time correction is budgeted
                       for (both correction cost and clamp slack scale
                       with it).
  max_stale_fraction — rebuild when the tombstoned sample weight
                       (Eq. (1) mass estimated by samples whose item no
                       longer exists — pure noise) exceeds this fraction
                       of m_base: the rank-error budget.
  max_correction_overhead — rebuild when the MEASURED per-query cost of
                       the delta correction (`engine.correction_overhead`
                       — the real serving path timed on this host/backend
                       at rebuild-decision time) exceeds this ratio of
                       the static query. This is the delta-aware COST
                       model: the ratio triggers optimize total serving
                       cost proxies, this one measures it. inf disables
                       the probe entirely (no timing cost per poll).
  compact_dead_above — loop rebuilds pass this to
                       `engine.rebuild(compact_dead_above=)`: past this
                       tombstoned-user fraction, dead rows are compacted
                       out at swap time and the old→new remap published
                       on the snapshot. None leaves dead rows masked.
  reorder_clusters   — loop rebuilds pass this to
                       `engine.rebuild(reorder_clusters=)`: each rebuild
                       re-clusters the (compacted) user matrix and
                       reorders rows so pruned-backend tiles stay tight
                       as streaming upserts erode the build-time layout
                       (PR 6). The permutation COMPOSES onto the
                       lineage's `user_remap` under the same hot-swap
                       that publishes the rebuilt table — readers never
                       observe rows and coordinates from different
                       layouts.
  min_interval_s     — floor between rebuilds, so a mutation storm
                       cannot wedge the loop into back-to-back builds.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import List, Optional

from repro.index.delta import DeltaStats
from repro.obs import registry as obs
from repro.obs import trace


@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    max_delta_ratio: float = 0.05
    max_stale_fraction: float = 0.02
    max_correction_overhead: float = float("inf")
    compact_dead_above: Optional[float] = None
    reorder_clusters: bool = False
    min_interval_s: float = 0.0

    def trigger(self, stats: DeltaStats,
                correction_overhead: Optional[float] = None
                ) -> Optional[str]:
        """Reason string when a rebuild is demanded, else None.
        `correction_overhead` is the measured delta/static query cost
        ratio (None when the caller did not probe it)."""
        if stats.delta_ratio > self.max_delta_ratio:
            return (f"delta_ratio {stats.delta_ratio:.4f} > "
                    f"{self.max_delta_ratio}")
        if stats.stale_fraction > self.max_stale_fraction:
            return (f"stale_fraction {stats.stale_fraction:.4f} > "
                    f"{self.max_stale_fraction}")
        if (correction_overhead is not None
                and correction_overhead > self.max_correction_overhead):
            return (f"correction_overhead {correction_overhead:.2f}x > "
                    f"{self.max_correction_overhead}x")
        return None


@dataclasses.dataclass(frozen=True)
class RebuildRecord:
    """One completed rebuild + swap, as observed by the engine."""

    epoch_before: int       # snapshot the rebuild was captured from
    epoch_after: int        # epoch published by the swap
    reason: str
    build_s: float          # off-lock Algorithm 1 wall time
    swap_s: float           # under-lock re-base + publish wall time
    stats: DeltaStats       # delta accounting at capture time
    users_compacted: int = 0    # tombstoned rows dropped by the swap
    users_reordered: bool = False   # swap published a cluster reorder


class MaintenanceLoop:
    """Poll `engine.delta_stats()` and rebuild when the policy triggers.

    Usage::

        with MaintenanceLoop(eng, policy=MaintenancePolicy(0.05)) as ml:
            ... engine keeps serving; inserts/deletes stream in ...
        print(ml.rebuilds)          # [RebuildRecord, ...]

    One daemon thread; `wake()` forces an immediate policy check (used by
    tests and by callers that know they just crossed a threshold).
    `close()` stops the loop; a rebuild in flight completes its swap.

    A FAILING rebuild must not kill the thread — a dead maintenance loop
    serves an ever-growing delta with zero indication. Exceptions are
    caught, logged, appended to `failures` (bounded: last
    `_MAX_FAILURES`), and the loop keeps polling; after a failure the
    next attempt waits `failure_backoff_s` (a persistently failing build
    must not be retried every poll tick — each doomed attempt is a full
    Algorithm 1 pass).
    """

    _MAX_FAILURES = 32

    def __init__(self, engine, *, policy: MaintenancePolicy = None,
                 poll_ms: float = 50.0, failure_backoff_s: float = 5.0):
        self.engine = engine
        self.policy = policy if policy is not None else MaintenancePolicy()
        self.poll_ms = float(poll_ms)
        self.failure_backoff_s = float(failure_backoff_s)
        self.rebuilds: List[RebuildRecord] = []
        self.failures: List[BaseException] = []
        reg = obs.get_default()
        self._m_rebuilds = reg.counter(
            "maintenance_rebuilds_total", "completed rebuild + hot-swaps")
        self._m_failures = reg.counter(
            "maintenance_failures_total", "rebuild attempts that raised")
        self._m_build = reg.histogram(
            "maintenance_build_ms", "off-lock Algorithm 1 wall time")
        self._m_swap = reg.histogram(
            "maintenance_swap_ms", "under-lock re-base + publish time")
        self._m_delta = reg.gauge(
            "maintenance_delta_ratio", "|delta|/m at the last poll")
        self._m_stale = reg.gauge(
            "maintenance_stale_fraction",
            "tombstoned sample weight fraction at the last poll")
        self._backoff_until = -float("inf")
        self._cond = threading.Condition()
        self._stop = False
        self._last_rebuild_t = -float("inf")
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="index-maintenance")
        self._thread.start()

    def wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _loop(self):
        while True:
            with self._cond:
                if self._stop:
                    return
                self._cond.wait(timeout=self.poll_ms / 1e3)
                if self._stop:
                    return
            now = time.monotonic()
            if (now - self._last_rebuild_t < self.policy.min_interval_s
                    or now < self._backoff_until):
                continue
            cost = None
            if self.policy.max_correction_overhead != float("inf"):
                # measured at rebuild-DECISION time, on the serving
                # backend (cached per correction shape — cheap per poll)
                cost = self.engine.correction_overhead()
            stats = self.engine.delta_stats()
            self._m_delta.set(stats.delta_ratio)
            self._m_stale.set(stats.stale_fraction)
            reason = self.policy.trigger(stats, correction_overhead=cost)
            if reason is None:
                continue
            try:
                with trace.span("maintenance.rebuild", reason=reason):
                    record = self.engine.rebuild(
                        reason=reason,
                        compact_dead_above=self.policy.compact_dead_above,
                        reorder_clusters=self.policy.reorder_clusters)
            except Exception as e:      # keep maintaining; surface it
                self.failures.append(e)
                del self.failures[:-self._MAX_FAILURES]
                self._m_failures.inc()
                self._backoff_until = (time.monotonic()
                                       + self.failure_backoff_s)
                logging.getLogger(__name__).exception(
                    "index rebuild failed (%s); maintenance loop "
                    "continues after %.1fs backoff", reason,
                    self.failure_backoff_s)
                record = None
            self._last_rebuild_t = time.monotonic()
            if record is not None:
                self.rebuilds.append(record)
                self._m_rebuilds.inc()
                self._m_build.observe(record.build_s * 1e3)
                self._m_swap.observe(record.swap_s * 1e3)
