"""Background index maintenance: rebuild policy + off-thread hot-swap.

The delta buffer keeps queries exact-in-expectation while it is small;
past a point the per-query correction cost and the tombstoned-sample
noise grow without bound. `MaintenanceLoop` watches the engine's
`DeltaStats` and, when the policy triggers, runs a FULL Algorithm 1
rebuild on the engine's configured backend (the sharded backend builds
row-sharded end-to-end via `distributed.build_sharded`) off the serving
threads, then hot-swaps the new epoch through the snapshot manager.
Serving never pauses: queries keep executing against the old snapshot
until the swap's single pointer assignment, and mutations that land while
the rebuild is running are re-based onto the new epoch during the swap
(`ReverseKRanksEngine.rebuild`).

Policy knobs:

  max_delta_ratio    — rebuild when (inserts + deletes) / m_base exceeds
                       the ρ bound the query-time correction is budgeted
                       for (both correction cost and clamp slack scale
                       with it).
  max_stale_fraction — rebuild when the tombstoned sample weight
                       (Eq. (1) mass estimated by samples whose item no
                       longer exists — pure noise) exceeds this fraction
                       of m_base: the rank-error budget.
  max_correction_overhead — rebuild when the MEASURED per-query cost of
                       the delta correction (`engine.correction_overhead`
                       — the real serving path timed on this host/backend
                       at rebuild-decision time) exceeds this ratio of
                       the static query. This is the delta-aware COST
                       model: the ratio triggers optimize total serving
                       cost proxies, this one measures it. inf disables
                       the probe entirely (no timing cost per poll).
  compact_dead_above — loop rebuilds pass this to
                       `engine.rebuild(compact_dead_above=)`: past this
                       tombstoned-user fraction, dead rows are compacted
                       out at swap time and the old→new remap published
                       on the snapshot. None leaves dead rows masked.
  reorder_clusters   — loop rebuilds pass this to
                       `engine.rebuild(reorder_clusters=)`: each rebuild
                       re-clusters the (compacted) user matrix and
                       reorders rows so pruned-backend tiles stay tight
                       as streaming upserts erode the build-time layout
                       (PR 6). The permutation COMPOSES onto the
                       lineage's `user_remap` under the same hot-swap
                       that publishes the rebuilt table — readers never
                       observe rows and coordinates from different
                       layouts.
  min_interval_s     — floor between rebuilds, so a mutation storm
                       cannot wedge the loop into back-to-back builds.
"""
from __future__ import annotations

import dataclasses
import logging
import random
import threading
import time
from typing import List, Optional, TYPE_CHECKING

if TYPE_CHECKING:      # annotation-only: a runtime import would close the
    # repro.core.engine → maintenance → delta → repro.core cycle and break
    # cold `import repro.index`
    from repro.index.delta import DeltaStats

from repro.obs import registry as obs
from repro.obs import trace
from repro.serve import faults


@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    max_delta_ratio: float = 0.05
    max_stale_fraction: float = 0.02
    max_correction_overhead: float = float("inf")
    compact_dead_above: Optional[float] = None
    reorder_clusters: bool = False
    min_interval_s: float = 0.0

    def trigger(self, stats: DeltaStats,
                correction_overhead: Optional[float] = None
                ) -> Optional[str]:
        """Reason string when a rebuild is demanded, else None.
        `correction_overhead` is the measured delta/static query cost
        ratio (None when the caller did not probe it)."""
        if stats.delta_ratio > self.max_delta_ratio:
            return (f"delta_ratio {stats.delta_ratio:.4f} > "
                    f"{self.max_delta_ratio}")
        if stats.stale_fraction > self.max_stale_fraction:
            return (f"stale_fraction {stats.stale_fraction:.4f} > "
                    f"{self.max_stale_fraction}")
        if (correction_overhead is not None
                and correction_overhead > self.max_correction_overhead):
            return (f"correction_overhead {correction_overhead:.2f}x > "
                    f"{self.max_correction_overhead}x")
        return None


@dataclasses.dataclass(frozen=True)
class RebuildRecord:
    """One completed rebuild + swap, as observed by the engine."""

    epoch_before: int       # snapshot the rebuild was captured from
    epoch_after: int        # epoch published by the swap
    reason: str
    build_s: float          # off-lock Algorithm 1 wall time
    swap_s: float           # under-lock re-base + publish wall time
    stats: DeltaStats       # delta accounting at capture time
    users_compacted: int = 0    # tombstoned rows dropped by the swap
    users_reordered: bool = False   # swap published a cluster reorder


class MaintenanceLoop:
    """Poll `engine.delta_stats()` and rebuild when the policy triggers.

    Usage::

        with MaintenanceLoop(eng, policy=MaintenancePolicy(0.05)) as ml:
            ... engine keeps serving; inserts/deletes stream in ...
        print(ml.rebuilds)          # [RebuildRecord, ...]

    One daemon thread; `wake()` forces an immediate policy check (used by
    tests and by callers that know they just crossed a threshold).
    `close()` stops the loop; a rebuild in flight completes its swap.

    A FAILING rebuild must not kill the thread — a dead maintenance loop
    serves an ever-growing delta with zero indication. Exceptions are
    caught, logged, appended to `failures` (bounded: last
    `_MAX_FAILURES`), and the loop keeps polling; after a failure the
    next attempt waits a CAPPED EXPONENTIAL backoff with jitter —
    `failure_backoff_s · 2^(consecutive−1)` up to `max_backoff_s`, ±25%
    seeded jitter (a persistently failing build must not be retried every
    poll tick — each doomed attempt is a full Algorithm 1 pass — and a
    fleet of loops must not retry in lockstep). `consecutive_failures`
    resets to 0 on the first success and is exported as the
    `maintenance_consecutive_failures` gauge alongside
    `maintenance_last_failure_unixtime`; recovery therefore reads as the
    gauge returning to 0 WITHOUT a process restart.

    Liveness: the `maintenance_thread_alive` callback gauge reads
    `thread.is_alive()` at scrape time — the watchdog surface for the
    one failure mode the in-loop handling cannot report on its own
    (an exception OUTSIDE the rebuild try/except killing the thread;
    `_run` also logs that traceback once before the thread dies).
    """

    _MAX_FAILURES = 32

    def __init__(self, engine, *, policy: MaintenancePolicy = None,
                 poll_ms: float = 50.0, failure_backoff_s: float = 5.0,
                 max_backoff_s: float = 60.0, backoff_seed: int = 0):
        self.engine = engine
        self.policy = policy if policy is not None else MaintenancePolicy()
        self.poll_ms = float(poll_ms)
        self.failure_backoff_s = float(failure_backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.rebuilds: List[RebuildRecord] = []
        self.failures: List[BaseException] = []
        self.consecutive_failures = 0
        self._jitter = random.Random(backoff_seed)
        reg = obs.get_default()
        self._m_rebuilds = reg.counter(
            "maintenance_rebuilds_total", "completed rebuild + hot-swaps")
        self._m_failures = reg.counter(
            "maintenance_failures_total", "rebuild attempts that raised")
        self._m_build = reg.histogram(
            "maintenance_build_ms", "off-lock Algorithm 1 wall time")
        self._m_swap = reg.histogram(
            "maintenance_swap_ms", "under-lock re-base + publish time")
        self._m_delta = reg.gauge(
            "maintenance_delta_ratio", "|delta|/m at the last poll")
        self._m_stale = reg.gauge(
            "maintenance_stale_fraction",
            "tombstoned sample weight fraction at the last poll")
        self._m_consec = reg.gauge(
            "maintenance_consecutive_failures",
            "rebuild failures since the last success (0 = healthy)")
        self._m_last_fail = reg.gauge(
            "maintenance_last_failure_unixtime",
            "wall-clock time of the last rebuild failure (0 = never)")
        self._backoff_until = -float("inf")
        self._cond = threading.Condition()
        self._stop = False
        self._last_rebuild_t = -float("inf")
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="index-maintenance")
        # Liveness at scrape time, not at set time: a dead thread cannot
        # lie through a callback gauge the way it can through a stale
        # last-written value.
        self._m_alive = reg.gauge(
            "maintenance_thread_alive",
            "1 while the maintenance loop thread is running",
            set_fn=self._thread.is_alive)
        self._thread.start()

    def wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _run(self):
        """Thread body: `_loop` + last-resort visibility. An exception
        escaping `_loop` (i.e. raised OUTSIDE the rebuild try/except)
        kills the thread — that is unavoidable, but it must be LOUD: log
        the traceback once, then die so the `maintenance_thread_alive`
        callback gauge flips to 0 at the next scrape."""
        try:
            self._loop()
        except Exception:
            logging.getLogger(__name__).exception(
                "maintenance loop thread died; rebuilds have STOPPED "
                "(maintenance_thread_alive gauge is now 0)")
            raise

    def _loop(self):
        while True:
            with self._cond:
                if self._stop:
                    return
                self._cond.wait(timeout=self.poll_ms / 1e3)
                if self._stop:
                    return
            if faults.ACTIVE is not None:
                # chaos site OUTSIDE the rebuild try/except: a raise here
                # kills the thread, which is exactly what the liveness-
                # gauge regression test provokes
                faults.fire("maintenance.loop")
            now = time.monotonic()
            if (now - self._last_rebuild_t < self.policy.min_interval_s
                    or now < self._backoff_until):
                continue
            cost = None
            if self.policy.max_correction_overhead != float("inf"):
                # measured at rebuild-DECISION time, on the serving
                # backend (cached per correction shape — cheap per poll)
                cost = self.engine.correction_overhead()
            stats = self.engine.delta_stats()
            self._m_delta.set(stats.delta_ratio)
            self._m_stale.set(stats.stale_fraction)
            reason = self.policy.trigger(stats, correction_overhead=cost)
            if reason is None:
                continue
            try:
                with trace.span("maintenance.rebuild", reason=reason):
                    record = self.engine.rebuild(
                        reason=reason,
                        compact_dead_above=self.policy.compact_dead_above,
                        reorder_clusters=self.policy.reorder_clusters)
            except Exception as e:      # keep maintaining; surface it
                self.failures.append(e)
                del self.failures[:-self._MAX_FAILURES]
                self.consecutive_failures += 1
                self._m_failures.inc()
                self._m_consec.set(self.consecutive_failures)
                self._m_last_fail.set(time.time())
                # capped exponential backoff with ±25% jitter: doubles
                # per consecutive failure so a wedged build is not
                # retried at poll cadence, capped so recovery after a
                # long outage is not deferred for minutes, jittered so
                # replicas sharing a failing dependency do not retry in
                # lockstep
                backoff = min(
                    self.failure_backoff_s
                    * 2.0 ** (self.consecutive_failures - 1),
                    self.max_backoff_s)
                backoff *= 1.0 + 0.25 * (2.0 * self._jitter.random() - 1.0)
                self._backoff_until = time.monotonic() + backoff
                logging.getLogger(__name__).exception(
                    "index rebuild failed (%s; failure #%d in a row); "
                    "maintenance loop continues after %.1fs backoff",
                    reason, self.consecutive_failures, backoff)
                record = None
            self._last_rebuild_t = time.monotonic()
            if record is not None:
                if self.consecutive_failures:
                    # recovery: the health gauge returns to 0 without a
                    # process restart (the PR 9 acceptance criterion)
                    self.consecutive_failures = 0
                    self._m_consec.set(0)
                self.rebuilds.append(record)
                self._m_rebuilds.inc()
                self._m_build.observe(record.build_s * 1e3)
                self._m_swap.observe(record.swap_s * 1e3)
