"""Delta buffer: streaming index mutations absorbed without a rebuild.

Algorithm 1 freezes an item set P₀ (and a user set U₀) into the rank
table; real item-centric workloads churn both. The delta buffer holds the
difference between the frozen base and the LIVE sets, small enough
(|delta| / m ≤ ρ, enforced by the maintenance policy) that it can be
fused into every query as a bounded additive correction instead of
forcing a rebuild:

  * inserted items are scored EXACTLY against each user at query time —
    the step-1 pass gains one small (n, n_add)-vs-(n, B) counting pass
    over pre-sorted per-user scores (`DeltaCorrection.add_scores`);
  * deleted items get a TOMBSTONE over the base: their exact per-user
    score sets are subtracted the same way, and the sampled positions
    they occupied are tracked (`DeltaStats.stale_weight`) because those
    positions keep contributing Eq. (1) sampling noise for mass that no
    longer exists — the error-budget half of the rebuild policy;
  * user upserts re-estimate JUST the touched table rows against the
    retained build sample (`rank_table.recompute_user_rows` — bit-
    consistent with a from-scratch build), and user deletions are a live
    mask that forces the row past every admissible selection key.

Error accounting: both correction terms are exact counts, so the Eq. (1)
estimator's guarantee is SHIFTED, not degraded — E[est'] = r(q,u,P')
whenever E[est] = r(q,u,P₀). The only delta-induced slack is the stale
sampling noise of tombstoned positions, bounded by their stratum weight
Σ w_s (≤ |D|·max_l |P_l|/s); `DeltaStats.stale_fraction` surfaces it and
`MaintenancePolicy.max_stale_fraction` bounds it.

Everything here is immutable and functionally updated: a `DeltaState` is
owned by exactly one `IndexSnapshot` generation, so in-flight queries
against an older snapshot are never perturbed by new mutations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rank_table as rt_mod
from repro.core.types import DeltaCorrection, RankTableConfig, StorageSpec


@dataclasses.dataclass(frozen=True)
class BaseIndex:
    """The frozen substrate a rank table was built over, retained so the
    index can be mutated and rebuilt without the caller re-supplying it.

    items:        (m_base, d) base item vectors, ORIGINAL insertion order.
    item_ids:     (m_base,) ascending stable ids (survive rebuilds).
    samples:      (ω·s, d) the build's stratified sample vectors.
    weights:      (ω·s,) device stratum weights |P_l| / s.
    weights_host: host copy of `weights` for the (tiny) stats math.
    sample_ids:   (ω·s,) item id at each sampled position — the tombstone
                  join key for deletions.
    max_norm:     () float32 max ‖p‖ (threshold_mode="norm_bound").
    """

    items: jax.Array
    item_ids: np.ndarray
    samples: jax.Array
    weights: jax.Array
    weights_host: np.ndarray
    sample_ids: np.ndarray
    max_norm: jax.Array

    @classmethod
    def create(cls, items: jax.Array, item_ids: np.ndarray,
               cfg: RankTableConfig, key: jax.Array) -> "BaseIndex":
        """Re-derive the sampling state of `build_rank_table(items, …, key)`
        (deterministic in (items, cfg, key) — shared by the dense and the
        sharded build, see `rank_table.sampling_artifacts`).

        This repeats the build's O(m·d + m log m) norm/sort/sample pass
        — deliberately: it keeps `QueryBackend.build_index` a plain
        `(users, items, cfg, key) → RankTable` hook instead of threading
        artifacts through every backend, and the duplicate m-pass is
        noise next to the O(n·ω·s·d) table build (n ≫ m here)."""
        art = rt_mod.sampling_artifacts(items, cfg, key)
        order = np.asarray(art.order)
        positions = np.asarray(art.positions)
        return cls(items=items, item_ids=np.asarray(item_ids, np.int64),
                   samples=art.samples, weights=art.weights,
                   weights_host=np.asarray(art.weights),
                   sample_ids=np.asarray(item_ids,
                                         np.int64)[order[positions]],
                   max_norm=art.max_norm)

    @property
    def m_base(self) -> int:
        return int(self.item_ids.size)

    def positions_of(self, ids: np.ndarray) -> np.ndarray:
        """Base positions of `ids` (item_ids is ascending); -1 if absent."""
        ids = np.asarray(ids, np.int64)
        pos = np.searchsorted(self.item_ids, ids)
        pos = np.clip(pos, 0, self.item_ids.size - 1)
        return np.where(self.item_ids[pos] == ids, pos, -1)


@dataclasses.dataclass(frozen=True)
class DeltaStats:
    """Delta-buffer accounting driving the rebuild policy."""

    n_added: int            # live inserted items
    n_deleted: int          # tombstoned base items
    n_dead_users: int
    n_touched_users: int    # rows re-estimated in place since base epoch
    m_base: int
    m_live: int             # m_base − n_deleted + n_added
    delta_ratio: float      # (n_added + n_deleted) / m_base
    stale_weight: float     # Σ stratum weights of tombstoned sample slots
    stale_fraction: float   # stale_weight / m_base

    def __str__(self):
        return (f"+{self.n_added}/-{self.n_deleted} items "
                f"({self.delta_ratio:.3f} of m={self.m_base}), "
                f"{self.n_dead_users} dead users, "
                f"stale {self.stale_fraction:.4f}")


@dataclasses.dataclass(frozen=True)
class DeltaState:
    """Immutable mutation set relative to one `BaseIndex` generation.

    base_live:     (m_base,) bool — False marks tombstoned base items.
    added_ids:     (A,) int64 ids of LIVE inserted items (an item inserted
                   then deleted simply leaves the buffer).
    added_items:   (A, d) their vectors, or None when A == 0.
    user_live:     (n,) bool — False marks deleted users.
    touched_users: user indices whose table rows were re-estimated since
                   the base epoch (consumed by the rebuild re-base).
    """

    base_live: np.ndarray
    added_ids: np.ndarray
    added_items: Optional[jax.Array]
    user_live: np.ndarray
    touched_users: frozenset

    @classmethod
    def empty(cls, m_base: int, n_users: int) -> "DeltaState":
        return cls(base_live=np.ones(m_base, bool),
                   added_ids=np.empty(0, np.int64), added_items=None,
                   user_live=np.ones(n_users, bool),
                   touched_users=frozenset())

    # ------------------------------------------------------------ queries
    @property
    def n_added(self) -> int:
        return int(self.added_ids.size)

    @property
    def n_deleted(self) -> int:
        return int((~self.base_live).sum())

    @property
    def is_empty(self) -> bool:
        return (self.n_added == 0 and self.n_deleted == 0
                and bool(self.user_live.all()))

    def stats(self, base: Optional[BaseIndex]) -> DeltaStats:
        m_base = base.m_base if base is not None else int(self.base_live.size)
        stale = 0.0
        if base is not None and self.n_deleted:
            dead_ids = base.item_ids[~self.base_live]
            stale = float(base.weights_host[
                np.isin(base.sample_ids, dead_ids)].sum())
        return DeltaStats(
            n_added=self.n_added, n_deleted=self.n_deleted,
            n_dead_users=int((~self.user_live).sum()),
            n_touched_users=len(self.touched_users),
            m_base=m_base, m_live=m_base - self.n_deleted + self.n_added,
            delta_ratio=(self.n_added + self.n_deleted) / max(m_base, 1),
            stale_weight=stale, stale_fraction=stale / max(m_base, 1))

    # ------------------------------------------------- functional updates
    def with_inserted(self, ids: np.ndarray, vectors: jax.Array
                      ) -> "DeltaState":
        added = (vectors if self.added_items is None
                 else jnp.concatenate([self.added_items, vectors]))
        return dataclasses.replace(
            self, added_ids=np.concatenate([self.added_ids,
                                            np.asarray(ids, np.int64)]),
            added_items=added)

    def with_deleted(self, ids: np.ndarray, base: Optional[BaseIndex]
                     ) -> "DeltaState":
        """Tombstone base items / drop inserted items by id."""
        ids = np.unique(np.asarray(ids, np.int64))
        in_added = np.isin(ids, self.added_ids)
        base_live = self.base_live.copy()
        if base is not None:
            pos = base.positions_of(ids[~in_added])
        else:
            pos = np.full((~in_added).sum(), -1)
        unknown = ids[~in_added][pos < 0]
        if unknown.size:
            raise KeyError(f"unknown item ids {unknown.tolist()}")
        dead_already = ~base_live[pos]
        if dead_already.any():
            raise KeyError(f"item ids already deleted: "
                           f"{ids[~in_added][dead_already].tolist()}")
        base_live[pos] = False
        keep = ~np.isin(self.added_ids, ids)
        added_items = self.added_items
        if added_items is not None and not keep.all():
            added_items = (added_items[jnp.asarray(np.flatnonzero(keep))]
                           if keep.any() else None)
        return dataclasses.replace(self, base_live=base_live,
                                   added_ids=self.added_ids[keep],
                                   added_items=added_items)

    def with_users(self, *, touched: Tuple[int, ...] = (),
                   dead: Tuple[int, ...] = (), n_users: Optional[int] = None
                   ) -> "DeltaState":
        """Record upserted rows and/or user deletions; `n_users` grows the
        live mask when rows were appended."""
        user_live = self.user_live
        if n_users is not None and n_users > user_live.size:
            user_live = np.concatenate(
                [user_live, np.ones(n_users - user_live.size, bool)])
        else:
            user_live = user_live.copy()
        user_live[list(dead)] = False
        # an upsert resurrects nothing: dead rows stay dead unless the
        # caller re-appends; touched only drives the rebuild re-base
        return dataclasses.replace(
            self, user_live=user_live,
            touched_users=self.touched_users | frozenset(touched))


def _bucket(width: int) -> int:
    """Round a delta width up to a power-of-two bucket (min 8).

    Query programs are compiled per correction SHAPE; a streaming
    workload that grows the delta by a few items per batch would retrace
    on every mutation. Bucketing pads the sorted score sets LEFT with
    -inf — which counts as exactly zero in `_count_above` (strict >), so
    results are bit-identical — and caps recompiles at O(log |delta|)
    per epoch lineage.
    """
    if width == 0:
        return 0
    b = 8
    while b < width:
        b *= 2
    return b


def _sorted_padded(scores: jax.Array, width: int) -> jax.Array:
    """f32 sort + bucket-pad (the pre-spec correction rows; kept for
    tests building hand-rolled corrections)."""
    out, _, _ = StorageSpec().pack_scores(
        jnp.sort(scores.astype(jnp.float32), axis=1),
        _bucket(width) - width)
    return out


def _packed_scores(users: jax.Array, items: jax.Array, width: int,
                   spec: StorageSpec):
    """Score `items` against every user, sort per row, materialize in
    spec space, left-pad to the power-of-two bucket with the absent
    sentinel (−inf; −128 for int8 — `rank_table._count_above_range`
    guarantees the sentinel is never counted)."""
    raw = jnp.sort((users @ items.T).astype(jnp.float32), axis=1)
    return spec.pack_scores(raw, _bucket(width) - width)


def build_correction(users: jax.Array, base: Optional[BaseIndex],
                     delta: DeltaState, m_base: int,
                     spec: Optional[StorageSpec] = None
                     ) -> Optional[DeltaCorrection]:
    """Materialize the query-time `DeltaCorrection` for one snapshot.

    O(n · |delta| · d) once per mutation batch (the per-user delta scores
    are sorted here so every query pays only a searchsorted) — None when
    the delta is empty, which keeps the static fast path untouched. Score
    sets are padded to power-of-two buckets (`_bucket`) so streaming
    mutations reuse compiled query programs instead of retracing per
    delta size.

    `spec` (PR 5): the engine's storage spec — correction rows are
    QUANTIZED ON INSERT (scored in f32 against the f32 system of record,
    then packed), so the whole delta path streams spec-space bytes; the
    query-time count becomes a certified range that
    `apply_delta_corrections` folds into the widened bounds. The f32 spec
    stores exactly the pre-spec f32 rows (bit-identity).
    """
    if delta.is_empty:
        return None
    spec = StorageSpec() if spec is None else spec
    n = users.shape[0]
    add_sc = add_off = del_sc = del_off = None
    if delta.n_added:
        add, add_sc, add_off = _packed_scores(users, delta.added_items,
                                              delta.n_added, spec)
    else:
        add = jnp.zeros((n, 0), jnp.float32)
    if delta.n_deleted:
        dead = base.items[jnp.asarray(np.flatnonzero(~delta.base_live))]
        dele, del_sc, del_off = _packed_scores(users, dead,
                                               delta.n_deleted, spec)
    else:
        dele = jnp.zeros((n, 0), jnp.float32)
    m_new = m_base - delta.n_deleted + delta.n_added
    return DeltaCorrection(add_scores=add, del_scores=dele,
                           user_live=jnp.asarray(delta.user_live),
                           m_new=jnp.asarray(m_new, jnp.int32),
                           add_scale=add_sc, add_off=add_off,
                           del_scale=del_sc, del_off=del_off)


def residual_after_rebuild(old_base: BaseIndex, delta_now: DeltaState,
                           new_ids: np.ndarray) -> DeltaState:
    """Re-base `delta_now` onto a rebuild that snapshotted an OLDER delta.

    The rebuild ran Algorithm 1 over the items live at capture time
    (`new_ids`); mutations that landed while it was building must survive
    the swap. Relative to the new base: an id in `new_ids` that is no
    longer live is a residual tombstone; a live inserted id not in
    `new_ids` is a residual insert. `touched_users` resets — the swap
    recomputes those rows against the new sample.
    """
    live_now = np.concatenate(
        [old_base.item_ids[delta_now.base_live], delta_now.added_ids])
    base_live = np.isin(np.asarray(new_ids, np.int64), live_now)
    keep = ~np.isin(delta_now.added_ids, new_ids)
    added_items = None
    if delta_now.added_items is not None and keep.any():
        added_items = delta_now.added_items[jnp.asarray(
            np.flatnonzero(keep))]
    return DeltaState(base_live=base_live,
                      added_ids=delta_now.added_ids[keep],
                      added_items=added_items,
                      user_live=delta_now.user_live.copy(),
                      touched_users=frozenset())
