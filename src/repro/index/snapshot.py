"""Epoch-versioned index snapshots with atomic hot-swap under live serving.

A snapshot is ONE immutable, internally consistent generation of the
index: (users, rank table, delta buffer, pre-built query correction). The
manager holds the current generation behind an atomic pointer; mutations
and rebuilds PUBLISH a new generation, they never edit a live one.

Concurrency contract (the seam between core and serve):

  * readers — `engine.query_batch` and every `MicroBatcher` tick — grab
    the pointer ONCE (`current()`) and execute entirely against that
    snapshot object. A swap during execution is invisible: the old
    generation's arrays are immutable and stay alive until the last
    reader drops them, so in-flight futures are never torn;
  * writers serialize on the engine's mutation lock and publish strictly
    increasing epochs; `publish` is a single reference assignment (atomic
    under the GIL), so there is no window where a reader can observe a
    half-installed generation;
  * the serving cache keys its generation on the snapshot's array
    identities (table/users/delta), so a swap invalidates every cached
    entry from older epochs — stale-epoch hits are structurally
    impossible, not merely unlikely.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, TYPE_CHECKING, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import DeltaCorrection, RankTable, RankTableConfig, \
    StoredUsers
from repro.serve import faults

if TYPE_CHECKING:      # annotation-only: a runtime import would close the
    # repro.core.engine → snapshot → delta → repro.core cycle and break
    # cold `import repro.index`
    from repro.index.delta import BaseIndex, DeltaState


def compose_remaps(first: Optional[np.ndarray],
                   second: Optional[np.ndarray]) -> Optional[np.ndarray]:
    """Compose two old→new user-row maps into one (PR 6).

    `first` maps lineage-original ids → intermediate coordinates,
    `second` maps intermediate → current; the result maps original →
    current, with −1 (dropped by a compaction) absorbing: once a row is
    gone it stays gone through any later reorder or compaction. None is
    the identity segment (no remap on that step), so compose(None, r) is
    r and compose(r, None) is r — a rebuild that neither compacts nor
    reorders CARRIES the lineage's remap instead of clearing it.
    """
    if first is None:
        return second
    if second is None:
        return first
    out = np.full(first.shape[0], -1, np.int64)
    alive = first >= 0
    out[alive] = second[first[alive]]
    return out


@dataclasses.dataclass(frozen=True)
class IndexSnapshot:
    """One immutable index generation (see module docstring).

    `corr` is the pre-materialized query-time correction for `delta`
    (None when the delta is empty — the static fast path); `base` is None
    for engines constructed without their item set, which can serve and
    mask users but not mutate items.

    `user_remap` surfaces the COMPOSED user-row coordinate change of the
    whole lineage (PR 4 compaction, PR 6 cluster reorder): a compacting
    rebuild drops tombstoned rows, a reordering build/rebuild permutes
    them, and either changes the coordinates queries answer in.
    `user_remap[old] = new` (−1 for rows a compaction dropped) maps
    LINEAGE-ORIGINAL ids to this snapshot's coordinates; successive
    remapping rebuilds COMPOSE onto it (`compose_remaps`) — never
    replace it — and ordinary mutations carry it unchanged. None means
    coordinates still equal the lineage's original ones. Current→original
    translation (query indices back to client ids) is `client_user_ids`.

    `stored_users` (PR 5) is the storage-spec materialization of `users`
    (bf16/int8 rows + per-user scales); None on the exact f32 spec, where
    backends receive the raw array (the bit-identical no-op path). It is
    re-packed whenever a mutation changes `users`, so it is always the
    spec-space image of this generation's user matrix; `users` itself
    stays the f32 system of record (mutations, delta scoring, rebuilds).
    """

    epoch: int
    users: jax.Array
    rank_table: RankTable
    config: RankTableConfig
    base: Optional[BaseIndex]
    delta: DeltaState
    corr: Optional[DeltaCorrection]
    user_remap: Optional[np.ndarray] = None
    stored_users: Optional[StoredUsers] = None

    def query_users(self):
        """What backends scan: the spec-space storage, or the raw f32
        matrix on the exact spec."""
        return self.users if self.stored_users is None else self.stored_users

    def client_user_ids(self, indices) -> np.ndarray:
        """Translate CURRENT-coordinate user indices (what `query_batch`
        returns on this snapshot) back to lineage-original ids — the
        coordinates a client that never observed a compaction/reorder
        holds. Identity when the lineage never remapped."""
        idx = np.asarray(indices)
        if self.user_remap is None:
            return idx
        inv = np.full(self.n, -1, np.int64)
        src = np.flatnonzero(self.user_remap >= 0)
        inv[self.user_remap[src]] = src
        return inv[idx]

    @property
    def n(self) -> int:
        return self.users.shape[0]

    @property
    def m_live(self) -> int:
        if self.corr is not None:
            return int(self.corr.m_new)
        return int(self.rank_table.m)

    def live_item_ids(self) -> np.ndarray:
        """Stable ids of the live item set, base-then-inserted order."""
        if self.base is None:
            raise ValueError("engine was constructed without its item set; "
                             "build it with ReverseKRanksEngine.build(...) "
                             "to enable item-level operations")
        return np.concatenate([self.base.item_ids[self.delta.base_live],
                               self.delta.added_ids])

    def live_items(self) -> jax.Array:
        """The live item vectors, ordered like `live_item_ids` — exactly
        the array a from-scratch rebuild runs Algorithm 1 over."""
        if self.base is None:
            raise ValueError("engine was constructed without its item set; "
                             "build it with ReverseKRanksEngine.build(...) "
                             "to enable item-level operations")
        kept = self.base.items[jnp.asarray(
            np.flatnonzero(self.delta.base_live))]
        if self.delta.added_items is None:
            return kept
        return jnp.concatenate([kept, self.delta.added_items])


class SnapshotManager:
    """Atomic holder of the current `IndexSnapshot` generation."""

    def __init__(self, initial: IndexSnapshot):
        self._current = initial
        self._lock = threading.Lock()
        self._swap_log: List[Tuple[int, float]] = []

    def current(self) -> IndexSnapshot:
        """The live generation — a single atomic reference read; callers
        use the returned object for a whole operation (never re-read
        mid-flight)."""
        return self._current

    def publish(self, snap: IndexSnapshot) -> IndexSnapshot:
        """Install a new generation. Epochs must strictly increase —
        writers are expected to serialize on the engine mutation lock;
        this assertion catches a lost-update race instead of silently
        rolling the index back."""
        if faults.ACTIVE is not None:
            # chaos site: a hot-swap dying between build and pointer
            # install — the old generation must keep serving untorn
            faults.fire("index.publish")
        with self._lock:
            if snap.epoch <= self._current.epoch:
                raise RuntimeError(
                    f"stale publish: epoch {snap.epoch} <= current "
                    f"{self._current.epoch} (concurrent writers must "
                    "serialize on the engine mutation lock)")
            self._swap_log.append((snap.epoch, time.monotonic()))
            self._current = snap
        return snap

    @property
    def swaps(self) -> int:
        with self._lock:
            return len(self._swap_log)
