"""Dynamic index maintenance: streaming updates under live serving.

Three pieces turn the static Algorithm-1 index into a mutable one that
serves while it changes (see ROADMAP "Dynamic index maintenance"):

  delta       — `DeltaState` / `build_correction`: inserts, item
                tombstones, user upserts/deletions absorbed WITHOUT a
                rebuild and fused into every query as an exact additive
                correction, with stale-sample error accounting.
  snapshot    — `IndexSnapshot` / `SnapshotManager`: immutable
                epoch-versioned generations behind an atomic pointer, so
                scheduler ticks and in-flight futures are never torn by
                a swap.
  maintenance — `MaintenancePolicy` / `MaintenanceLoop`: background
                rebuild (on the engine's configured backend) when the
                delta ratio or the stale-sample error budget is
                exceeded, hot-swapped without pausing serving.
  persist     — `IndexPersister` (PR 9): crash-safe durability — atomic
                checksummed snapshot spills per rebuild epoch + an
                append-only mutation WAL; `ReverseKRanksEngine.restore`
                recovers bitwise-equal state, `PersistError` means
                rebuild from the master copy.

The mutation API itself lives on `ReverseKRanksEngine`
(insert_items / delete_items / upsert_users / delete_users / rebuild).
"""
from repro.index.delta import (BaseIndex, DeltaState, DeltaStats,
                               build_correction, residual_after_rebuild)
from repro.index.maintenance import (MaintenanceLoop, MaintenancePolicy,
                                     RebuildRecord)
from repro.index.persist import (IndexPersister, PersistError, WalRecord,
                                 load_latest)
from repro.index.snapshot import IndexSnapshot, SnapshotManager

__all__ = [
    "BaseIndex", "DeltaState", "DeltaStats", "build_correction",
    "residual_after_rebuild", "IndexSnapshot", "SnapshotManager",
    "MaintenanceLoop", "MaintenancePolicy", "RebuildRecord",
    "IndexPersister", "PersistError", "WalRecord", "load_latest",
]
