"""Backbone assembly: scanned layer stacks for all 10 architectures.

Parameters, caches and activations are plain dict pytrees. Layers are
stacked per `cfg.segments()` (see ModelConfig): each segment holds its
pattern's blocks with a leading `repeats` axis and is applied with
jax.lax.scan, so traced HLO size is O(#segments), not O(n_layers) — this
is what keeps 512-device dry-run compiles tractable.

Public surface:
  init_params / abstract_params      — real or ShapeDtypeStruct pytrees
  forward_logits(params, tokens)     — train/prefill logits
  lm_loss(params, batch)             — masked CE (+ optional z-loss)
  init_cache / decode_step           — one-token serving with caches
  encode(params, frames)             — enc-dec encoder (whisper stub input)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import recurrent as R
from repro.models.config import ModelConfig
from repro.models.sharding import shard


# ------------------------------------------------------------------ blocks
def _init_block(block: str, key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    if block == "attn_mlp":
        return {"ln1": L.init_norm(cfg.d_model),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "mlp": L.init_mlp(ks[1], cfg)}
    if block == "attn_moe":
        return {"ln1": L.init_norm(cfg.d_model),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "moe": L.init_moe(ks[1], cfg)}
    if block == "rwkv":
        return {"ln1": L.init_norm(cfg.d_model),
                "tmix": R.init_rwkv_tmix(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "cmix": R.init_rwkv_cmix(ks[1], cfg)}
    if block == "rglru":
        return {"ln1": L.init_norm(cfg.d_model),
                "rglru": R.init_rglru_block(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "mlp": L.init_mlp(ks[1], cfg)}
    if block == "local_attn":
        return {"ln1": L.init_norm(cfg.d_model),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "mlp": L.init_mlp(ks[1], cfg)}
    if block == "enc_block":
        return {"ln1": L.init_norm(cfg.d_model),
                "attn": L.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "mlp": L.init_mlp(ks[1], cfg)}
    if block == "dec_block":
        return {"ln1": L.init_norm(cfg.d_model),
                "attn": L.init_attention(ks[0], cfg),
                "lnx": L.init_norm(cfg.d_model),
                "xattn": L.init_cross_attention(ks[1], cfg),
                "ln2": L.init_norm(cfg.d_model),
                "mlp": L.init_mlp(ks[2], cfg)}
    raise ValueError(f"unknown block {block!r}")


def _apply_block(block: str, p: dict, x: jax.Array, cfg: ModelConfig,
                 positions, *, enc_kv=None) -> jax.Array:
    """Full-sequence (train/prefill) application of one block."""
    norm = L.layer_norm if cfg.family == "encdec" else L.rms_norm
    if block in ("attn_mlp", "attn_moe", "local_attn"):
        window = cfg.local_window if block == "local_attn" else None
        x = x + L.attention(p["attn"], norm(p["ln1"], x, cfg.norm_eps), cfg,
                            positions, causal=True, window=window)
        h = norm(p["ln2"], x, cfg.norm_eps)
        ff = L.moe(p["moe"], h, cfg) if block == "attn_moe" else \
            L.mlp(p["mlp"], h, cfg)
        return x + ff
    if block == "rwkv":
        x = x + R.rwkv_tmix(p["tmix"], norm(p["ln1"], x, cfg.norm_eps), cfg)
        return x + R.rwkv_cmix(p["cmix"], norm(p["ln2"], x, cfg.norm_eps),
                               cfg)
    if block == "rglru":
        x = x + R.rglru_block(p["rglru"], norm(p["ln1"], x, cfg.norm_eps),
                              cfg)
        return x + L.mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_eps), cfg)
    if block == "enc_block":
        x = x + L.attention(p["attn"], norm(p["ln1"], x, cfg.norm_eps), cfg,
                            None, causal=False, use_rope=False)
        return x + L.mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_eps), cfg)
    if block == "dec_block":
        x = x + L.attention(p["attn"], norm(p["ln1"], x, cfg.norm_eps), cfg,
                            None, causal=True, use_rope=False)
        # enc_kv carries the raw encoder output; each decoder layer projects
        # it with its own wk/wv (whisper-style per-layer cross attention).
        ek, ev = L.encoder_kv(p["xattn"], enc_kv, cfg)
        x = x + L.cross_attention(p["xattn"], norm(p["lnx"], x,
                                                   cfg.norm_eps), cfg, ek, ev)
        return x + L.mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_eps), cfg)
    raise ValueError(f"unknown block {block!r}")


# ---------------------------------------------------------------- stacking
def _init_segment(key, pattern, repeats, cfg) -> dict:
    keys = jax.random.split(key, repeats)

    def one(k):
        sub = jax.random.split(k, len(pattern))
        return {f"b{i}": _init_block(b, sub[i], cfg)
                for i, b in enumerate(pattern)}

    return jax.vmap(one)(keys)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "nothing"
              else jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn, policy=policy)


def _apply_segments(params_segs, segments, x, cfg, positions, *,
                    enc_kv=None) -> jax.Array:
    for seg_params, (pattern, repeats) in zip(params_segs, segments):
        def body(h, layer_p, pattern=pattern):
            for i, b in enumerate(pattern):
                h = _apply_block(b, layer_p[f"b{i}"], h, cfg, positions,
                                 enc_kv=enc_kv)
            return h, None

        body = _remat(body, cfg)
        x, _ = jax.lax.scan(body, x, seg_params)
    return x


# ------------------------------------------------------------------ params
def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    p = {"embed": {"tok": jax.random.normal(
        ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02}}
    p["segments"] = [
        _init_segment(jax.random.fold_in(ks[1], i), pattern, repeats, cfg)
        for i, (pattern, repeats) in enumerate(cfg.segments())]
    p["final_norm"] = L.init_norm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": jax.random.normal(
            ks[2], (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model ** -0.5}
    if cfg.family == "encdec":
        p["enc_in"] = {"w": jax.random.normal(
            ks[3], (cfg.d_model, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5}
        p["enc_segments"] = [
            _init_segment(jax.random.fold_in(ks[4], i), pattern, repeats,
                          cfg)
            for i, (pattern, repeats) in enumerate(cfg.enc_segments())]
        p["enc_norm"] = L.init_norm(cfg.d_model)
    return p


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ----------------------------------------------------------------- forward
def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    dt = L.cdtype(cfg)
    x = frames.astype(dt) @ params["enc_in"]["w"].astype(dt)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    x = _apply_segments(params["enc_segments"], cfg.enc_segments(), x, cfg,
                        None)
    return L.layer_norm(params["enc_norm"], x, cfg.norm_eps)


def _embed(params, tokens, cfg, pos_offset=0) -> jax.Array:
    dt = L.cdtype(cfg)
    x = params["embed"]["tok"].astype(dt)[tokens]
    if cfg.family == "encdec":
        # absolute (sinusoidal) decoder positions; decode offsets by the
        # cache length so step t uses position t, not 0.
        S = tokens.shape[1]
        pos = pos_offset + jnp.arange(S)
        half = cfg.d_model // 2
        dim = jnp.arange(half, dtype=jnp.float32)[None, :]
        ang = pos[:, None].astype(jnp.float32) / (10_000.0 ** (2 * dim /
                                                               cfg.d_model))
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe.astype(dt)
    return shard(x, "batch", None, None)


def _logits(params, x, cfg) -> jax.Array:
    dt = x.dtype
    head = params["embed"]["tok"].T if cfg.tie_embeddings else \
        params["lm_head"]["w"]
    logits = x @ head.astype(dt)
    return shard(logits, "batch", None, "vocab")


def forward_hidden(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
                   frames: Optional[jax.Array] = None) -> jax.Array:
    """Final normed hidden states (B, S, D)."""
    x = _embed(params, tokens, cfg)
    positions = jnp.arange(tokens.shape[1])
    enc_kv = None
    if cfg.family == "encdec":
        # raw encoder output; each decoder block projects it with its own
        # wk/wv (whisper-style per-layer cross attention)
        enc_kv = encode(params, frames, cfg)
    x = _apply_segments(params["segments"], cfg.segments(), x, cfg,
                        positions, enc_kv=enc_kv)
    return (L.layer_norm if cfg.family == "encdec" else L.rms_norm)(
        params["final_norm"], x, cfg.norm_eps)


def forward_logits(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
                   frames: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence logits (training / prefill). tokens: (B, S) int32."""
    return _logits(params, forward_hidden(params, tokens, cfg,
                                          frames=frames), cfg)


def _chunked_ce(x: jax.Array, head: jax.Array, labels: jax.Array,
                n_chunks: int) -> tuple[jax.Array, jax.Array]:
    """Online-logsumexp cross-entropy over vocab chunks (§Perf H5).

    Never materializes the full (B, S, V) f32 logits: each chunk's
    (B, S, V/n) logits are folded into running (max, sumexp, label-logit)
    reductions and freed. Returns (lse, label_logit), both (B, S) f32.
    """
    D, V = head.shape
    Vc = V // n_chunks
    hc = head.T.reshape(n_chunks, Vc, D)                     # (n, Vc, D)

    def step(carry, xs):
        m, se, ll = carry
        h_chunk, ci = xs
        logits = jax.lax.dot_general(
            x, h_chunk, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (B, S, Vc)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        se = se * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(axis=-1)
        local = labels - ci * Vc
        inside = (local >= 0) & (local < Vc)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, Vc - 1)[..., None], axis=-1)[..., 0]
        ll = ll + jnp.where(inside, picked, 0.0)
        return (m_new, se, ll), None

    B, S = labels.shape
    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, se, ll), _ = jax.lax.scan(step, init,
                                  (hc, jnp.arange(n_chunks)))
    return m + jnp.log(jnp.maximum(se, 1e-30)), ll


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            z_loss: float = 1e-4) -> jax.Array:
    """Masked next-token cross-entropy. batch: tokens/labels (B,S) int32,
    labels < 0 are masked; encdec adds frames (B,enc_seq,D). With
    cfg.vocab_chunks > 1 the (B,S,V) f32 logits never materialize."""
    x = forward_hidden(params, batch["tokens"], cfg,
                       frames=batch.get("frames"))
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    head = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["lm_head"]["w"])
    if cfg.vocab_chunks > 1 and cfg.vocab % cfg.vocab_chunks == 0:
        lse, ll = _chunked_ce(x.astype(jnp.bfloat16),
                              head.astype(jnp.bfloat16), safe,
                              cfg.vocab_chunks)
    else:
        logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * ((lse * mask) ** 2).sum() / \
            jnp.maximum(mask.sum(), 1.0)
    return loss


# ------------------------------------------------------------------ decode
def _init_block_cache(block: str, batch: int, cache_len: int,
                      cfg: ModelConfig, dt) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.hd
    if block in ("attn_mlp", "attn_moe", "enc_block"):
        T = cache_len
        return {"k": jnp.zeros((batch, T, kv, hd), dt),
                "v": jnp.zeros((batch, T, kv, hd), dt)}
    if block == "dec_block":
        # self-attn KV plus per-layer cross-attention KV over encoder frames
        T = cache_len
        return {"k": jnp.zeros((batch, T, kv, hd), dt),
                "v": jnp.zeros((batch, T, kv, hd), dt),
                "xk": jnp.zeros((batch, cfg.enc_seq, kv, hd), dt),
                "xv": jnp.zeros((batch, cfg.enc_seq, kv, hd), dt)}
    if block == "local_attn":
        T = min(cache_len, cfg.local_window)
        return {"k": jnp.zeros((batch, T, kv, hd), dt),
                "v": jnp.zeros((batch, T, kv, hd), dt)}
    if block == "rwkv":
        return {"s": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
                "xt": jnp.zeros((batch, cfg.d_model), dt),
                "xc": jnp.zeros((batch, cfg.d_model), dt)}
    if block == "rglru":
        return {"h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1,
                                   cfg.lru_width), dt)}
    raise ValueError(block)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Decode cache pytree mirroring the segment structure, plus scalars."""
    dt = L.cdtype(cfg)
    segs = []
    for pattern, repeats in cfg.segments():
        one = {f"b{i}": _init_block_cache(b, batch, cache_len, cfg, dt)
               for i, b in enumerate(pattern)}
        segs.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (repeats,) + x.shape), one))
    return {"segments": segs, "len": jnp.zeros((), jnp.int32)}


def fill_cross_kv(params: dict, cache: dict, enc_out: jax.Array,
                  cfg: ModelConfig) -> dict:
    """Project encoder output into every decoder layer's cross-KV cache
    (run once per request before decoding)."""
    new_segs = []
    for seg_params, seg_cache, (pattern, _) in zip(
            params["segments"], cache["segments"], cfg.segments()):
        def per_layer(layer_p, layer_c, pattern=pattern):
            out = dict(layer_c)
            for i, b in enumerate(pattern):
                if b == "dec_block":
                    k, v = L.encoder_kv(layer_p[f"b{i}"]["xattn"], enc_out,
                                        cfg)
                    out[f"b{i}"] = dict(layer_c[f"b{i}"],
                                        xk=k.astype(cache_dtype(layer_c)),
                                        xv=v.astype(cache_dtype(layer_c)))
            return out

        new_segs.append(jax.vmap(per_layer)(seg_params, seg_cache))
    return dict(cache, segments=new_segs)


def cache_dtype(layer_c) -> jnp.dtype:
    leaves = jax.tree.leaves(layer_c)
    return leaves[0].dtype if leaves else jnp.bfloat16


def _decode_block(block: str, p: dict, x: jax.Array, cfg, cache: dict,
                  cache_len, enc_kv):
    norm = L.layer_norm if cfg.family == "encdec" else L.rms_norm
    if block in ("attn_mlp", "attn_moe", "local_attn", "dec_block"):
        h = norm(p["ln1"], x, cfg.norm_eps)
        out, nk, nv = L.attention_decode(
            p["attn"], h, cfg, cache["k"], cache["v"], cache_len,
            use_rope=(cfg.family != "encdec"))
        x = x + out
        new_cache = dict(cache, k=nk, v=nv)
        if block == "dec_block":
            x = x + L.cross_attention(
                p["xattn"], norm(p["lnx"], x, cfg.norm_eps), cfg,
                cache["xk"], cache["xv"])
        h2 = norm(p["ln2"], x, cfg.norm_eps)
        ff = L.moe(p["moe"], h2, cfg) if block == "attn_moe" else \
            L.mlp(p["mlp"], h2, cfg)
        return x + ff, new_cache
    if block == "rwkv":
        h = norm(p["ln1"], x, cfg.norm_eps)
        out, s_new, xt_new = R.rwkv_tmix_decode(p["tmix"], h, cfg,
                                                cache["s"], cache["xt"])
        x = x + out
        h2 = norm(p["ln2"], x, cfg.norm_eps)
        out2 = R.rwkv_cmix(p["cmix"], h2, cfg, x_prev=cache["xc"])
        return x + out2, dict(cache, s=s_new, xt=xt_new, xc=h2[:, 0])
    if block == "rglru":
        h = norm(p["ln1"], x, cfg.norm_eps)
        out, st = R.rglru_decode(p["rglru"], h, cfg, cache)
        x = x + out
        x = x + L.mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_eps), cfg)
        return x, dict(cache, **st)
    raise ValueError(block)


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: ModelConfig):
    """One serving step: tokens (B, 1) int32 → (logits (B,1,V), new cache).

    The per-segment scan threads each layer's cache slice alongside its
    stacked params, so decode HLO is also O(#segments).
    """
    cache_len = cache["len"]
    x = _embed(params, tokens, cfg, pos_offset=cache_len)
    enc_kv = None       # cross-KV lives per-layer in the cache (fill_cross_kv)
    new_segs = []
    for seg_params, seg_cache, (pattern, _) in zip(
            params["segments"], cache["segments"], cfg.segments()):
        def body(h, xs, pattern=pattern):
            layer_p, layer_c = xs
            new_c = {}
            for i, b in enumerate(pattern):
                h, new_c[f"b{i}"] = _decode_block(
                    b, layer_p[f"b{i}"], h, cfg, layer_c[f"b{i}"],
                    cache_len, enc_kv)
            return h, new_c

        x, seg_cache_new = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segs.append(seg_cache_new)
    x = (L.layer_norm if cfg.family == "encdec" else L.rms_norm)(
        params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, x, cfg)
    new_cache = dict(cache, segments=new_segs, len=cache_len + 1)
    return logits, new_cache
