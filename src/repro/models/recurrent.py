"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV-6 (Finch).

TPU adaptation notes (see DESIGN.md §3/§4):
  * RG-LRU's linear recurrence h_t = a_t·h_{t-1} + b_t runs as a
    jax.lax.associative_scan (log-depth, parallel) for train/prefill and a
    single fused step for decode. Gate projections are dense (R, R) rather
    than Griffin's block-diagonal — noted adaptation.
  * RWKV-6's data-dependent-decay WKV runs CHUNKED (GLA-style): intra-chunk
    pairwise decays are exact in log space (all exponents ≤ 0 ⇒ stable),
    inter-chunk state flows through a lax.scan over chunks. Decode is the
    exact O(1) recurrence. The sequential-scan reference lives in
    tests/test_models.py and must match to float tolerance.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import init_norm, rms_norm
from repro.models.sharding import shard


# ------------------------------------------------------------------- RG-LRU
_RG_C = 8.0     # Griffin's fixed temperature on the recurrence gate


def init_rglru_block(key, cfg) -> dict:
    d, r, cw = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 7)
    si, sr = d ** -0.5, r ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, r), jnp.float32) * si,
        "w_gate": jax.random.normal(ks[1], (d, r), jnp.float32) * si,
        "w_out": jax.random.normal(ks[2], (r, d), jnp.float32) * sr
                 / max(2 * cfg.n_layers, 1) ** 0.5,
        "conv_w": jax.random.normal(ks[3], (cw, r), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((r,), jnp.float32),
        "w_a": jax.random.normal(ks[4], (r, r), jnp.float32) * sr,
        "b_a": jnp.zeros((r,), jnp.float32),
        "w_i": jax.random.normal(ks[5], (r, r), jnp.float32) * sr,
        "b_i": jnp.zeros((r,), jnp.float32),
        # Λ init so σ(Λ) ∈ ~(0.9, 0.999): a stable long-memory band.
        "lam": jax.random.uniform(ks[6], (r,), jnp.float32, 2.0, 6.0),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. u (B,S,R), w (cw,R)."""
    cw = w.shape[0]
    upad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(cw):                      # cw = 4: unrolled taps
        out = out + upad[:, i:i + u.shape[1], :] * w[cw - 1 - i]
    return out + b


def _rglru_coeffs(p: dict, u: jax.Array, dt):
    """Per-step (a_t, b_t) of the RG-LRU recurrence (float32)."""
    uf = u.astype(jnp.float32)
    rec_gate = jax.nn.sigmoid(uf @ p["w_a"] + p["b_a"])
    in_gate = jax.nn.sigmoid(uf @ p["w_i"] + p["b_i"])
    log_a = -_RG_C * jax.nn.softplus(p["lam"]) * rec_gate     # ≤ 0
    a = jnp.exp(log_a)
    # sqrt(1 - a²) via expm1 for precision near a ≈ 1
    scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = scale * (in_gate * uf)
    return a, b


def rglru_block(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Full-sequence RG-LRU block (train/prefill). x: (B,S,D)."""
    dt = x.dtype
    u = x @ p["w_x"].astype(dt)
    u = shard(u, "batch", None, "rnn")
    u = _causal_conv(u, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    a, b = _rglru_coeffs(p, u, dt)

    def op(ca, cb):
        (a1, b1), (a2, b2) = ca, cb
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)       # (B,S,R) f32
    gate = jax.nn.gelu((x @ p["w_gate"].astype(dt)).astype(jnp.float32),
                       approximate=True)
    y = (h * gate).astype(dt)
    y = shard(y, "batch", None, "rnn")
    return y @ p["w_out"].astype(dt)


def rglru_decode(p: dict, x: jax.Array, cfg, state: dict
                 ) -> Tuple[jax.Array, dict]:
    """One-token step. x: (B,1,D); state: {h: (B,R) f32, conv: (B,cw-1,R)}."""
    dt = x.dtype
    cw = cfg.conv_width
    u = (x @ p["w_x"].astype(dt))[:, 0]                       # (B,R)
    hist = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B,cw,R)
    # _causal_conv convention: out[t] = Σ_j u[t-j]·w[j], i.e. w[0] applies
    # to the CURRENT token. hist is oldest-first, so flip the taps.
    w = p["conv_w"].astype(dt)[::-1]
    conv = jnp.einsum("bcr,cr->br", hist, w) + p["conv_b"].astype(dt)
    a, b = _rglru_coeffs(p, conv[:, None], dt)
    h = a[:, 0] * state["h"] + b[:, 0]                        # (B,R) f32
    gate = jax.nn.gelu((x[:, 0] @ p["w_gate"].astype(dt)).astype(
        jnp.float32), approximate=True)
    y = ((h * gate).astype(dt) @ p["w_out"].astype(dt))[:, None]
    return y, {"h": h, "conv": hist[:, 1:]}


def init_rglru_state(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    r, cw = cfg.lru_width, cfg.conv_width
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, r), dtype)}


# -------------------------------------------------------------------- RWKV-6
def init_rwkv_tmix(key, cfg) -> dict:
    d, lora = cfg.d_model, cfg.rwkv_lora_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    p = {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "w0": jnp.full((d,), -1.0, jnp.float32),   # base decay logits
        "u": jax.random.normal(ks[0], (d,), jnp.float32) * 0.3,  # bonus
        "lora_a": jax.random.normal(ks[1], (d, lora), jnp.float32) * s,
        "lora_b": jax.random.normal(ks[2], (lora, d), jnp.float32) * 0.01,
        "w_r": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "w_k": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        "w_v": jax.random.normal(ks[5], (d, d), jnp.float32) * s,
        "w_g": jax.random.normal(ks[6], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[7], (d, d), jnp.float32) * s
               / max(2 * cfg.n_layers, 1) ** 0.5,
        "ln_out": init_norm(d),
    }
    return p


def init_rwkv_cmix(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "w_k": jax.random.normal(k1, (d, f), jnp.float32) * d ** -0.5,
        "w_v": jax.random.normal(k2, (f, d), jnp.float32) * f ** -0.5
               / max(2 * cfg.n_layers, 1) ** 0.5,
        "w_r": jax.random.normal(k3, (d, d), jnp.float32) * d ** -0.5,
    }


def _shift(x: jax.Array) -> jax.Array:
    """Token shift: x_{t-1} (zeros at t=0). x: (B,S,D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _head_group_norm(scale: jax.Array, y: jax.Array, H: int, eps: float
                     ) -> jax.Array:
    """RWKV's GroupNorm(H groups): RMS-normalize each head's hd channels,
    then apply the per-channel (d,) scale. y: (..., d)."""
    shp = y.shape
    yh = y.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    var = jnp.mean(yh * yh, axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + eps)
    return (yh.reshape(shp) * scale).astype(y.dtype)


def _tmix_inputs(p: dict, x: jax.Array, xx: jax.Array, cfg):
    """r,k,v,g projections + per-step log-decay (B,S,H,hd) from ddlerp."""
    dt = x.dtype
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    mix = lambda mu: x + (xx - x) * mu.astype(dt)
    r = (mix(p["mu_r"]) @ p["w_r"].astype(dt)).reshape(B, S, H, hd)
    k = (mix(p["mu_k"]) @ p["w_k"].astype(dt)).reshape(B, S, H, hd)
    v = (mix(p["mu_v"]) @ p["w_v"].astype(dt)).reshape(B, S, H, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["w_g"].astype(dt))
    xw = mix(p["mu_w"]).astype(jnp.float32)
    dlog = jnp.tanh(xw @ p["lora_a"]) @ p["lora_b"]           # (B,S,d)
    logw = -jnp.exp(p["w0"] + dlog)                            # ≤ 0, f32
    logw = logw.reshape(B, S, H, hd)
    return r, k, v, g, logw


def _wkv_chunk(r, k, v, logw, u, s0):
    """Exact WKV for one chunk, log-space-stable.

    r,k,v: (B,H,L,hd) f32; logw: (B,H,L,hd) ≤ 0; u: (H,hd); s0: (B,H,hd,hd).
    Returns (y (B,H,L,hd), s_new). All pairwise decay exponents are ≤ 0.
    """
    B, H, L, hd = r.shape
    cum = jnp.cumsum(logw, axis=2)                             # (B,H,L,hd)
    cum_prev = cum - logw                                      # Σ_{j<t}
    # inter-chunk: y += (r_t ⊙ exp(cum_{t-1})) · S0
    rdec = r * jnp.exp(cum_prev)
    y = jnp.einsum("bhld,bhde->bhle", rdec, s0)
    # intra-chunk: scores_ts = Σ_d r_td k_sd exp(cum_{t-1,d} − cum_{s,d}), s<t
    expo = cum_prev[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,L,L,hd)
    tril = jnp.tril(jnp.ones((L, L), bool), k=-1)
    dec = jnp.where(tril[None, None, :, :, None], jnp.exp(
        jnp.minimum(expo, 0.0)), 0.0)
    scores = jnp.einsum("bhtd,bhsd,bhtsd->bhts", r, k, dec)
    y = y + jnp.einsum("bhts,bhse->bhte", scores, v)
    # current-token bonus: (r_t ⊙ u ⊙ k_t) · v_t
    bonus = jnp.sum(r * u[None, :, None, :] * k, axis=-1, keepdims=True)
    y = y + bonus * v
    # state: S_L = diag(exp(cum_L)) S0 + Σ_s (k_s ⊙ exp(cum_L − cum_s)) v_sᵀ
    kdec = k * jnp.exp(cum[:, :, -1:, :] - cum)
    s_new = jnp.exp(cum[:, :, -1, :, None]) * s0 + jnp.einsum(
        "bhsd,bhse->bhde", kdec, v)
    return y, s_new


def wkv_chunked(r, k, v, logw, u, s0, chunk: int = 64):
    """(B,H,S,hd) inputs → (y, s_final); scans over S/chunk chunks."""
    B, H, S, hd = r.shape
    L = min(chunk, S)
    nc = S // L
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    reshape = lambda t: t.reshape(B, H, nc, L, hd).transpose(2, 0, 1, 3, 4)
    rc, kc, vc, wc = map(reshape, (r, k, v, logw))

    def step(s, xs):
        rb, kb, vb, wb = xs
        y, s_new = _wkv_chunk(rb, kb, vb, wb, u, s)
        return s_new, y

    s_fin, ys = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return y, s_fin


def rwkv_tmix(p: dict, x: jax.Array, cfg, chunk: int = 64) -> jax.Array:
    """Full-sequence RWKV-6 time mix. x: (B,S,D)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    dt = x.dtype
    r, k, v, g, logw = _tmix_inputs(p, x, _shift(x), cfg)
    tr = lambda t: t.transpose(0, 2, 1, 3).astype(jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y, _ = wkv_chunked(tr(r), tr(k), tr(v), tr(logw),
                       p["u"].reshape(H, hd), s0, chunk)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d)               # (B,S,D)
    y = _head_group_norm(p["ln_out"]["scale"], y, H, cfg.norm_eps)
    return (y.astype(dt) * g) @ p["w_o"].astype(dt)


def rwkv_tmix_decode(p: dict, x: jax.Array, cfg, s: jax.Array,
                     x_prev: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token RWKV-6 step — the exact O(1) recurrence.

    x: (B,1,D); s: (B,H,hd,hd) f32 WKV state; x_prev: (B,D) token shift.
    Returns (out, s_new, x_new).
    """
    B, _, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xx = x_prev[:, None, :].astype(x.dtype)
    r, k, v, g, logw = _tmix_inputs(p, x, xx, cfg)
    rf, kf, vf = (t[:, 0].reshape(B, H, hd).astype(jnp.float32)
                  for t in (r, k, v))
    w = jnp.exp(logw[:, 0].reshape(B, H, hd))                  # decay (0,1)
    u = p["u"].reshape(H, hd)
    kv = kf[..., :, None] * vf[..., None, :]                   # (B,H,hd,hd)
    y = jnp.einsum("bhd,bhde->bhe", rf, s + u[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    y = _head_group_norm(p["ln_out"]["scale"], y.reshape(B, 1, d), H,
                         cfg.norm_eps)
    out = (y.astype(x.dtype) * g) @ p["w_o"].astype(x.dtype)
    return out, s_new, x[:, 0]


def rwkv_cmix(p: dict, x: jax.Array, cfg,
              x_prev: Optional[jax.Array] = None):
    """Channel mix. Full-seq when x_prev is None, else one-token decode."""
    dt = x.dtype
    xx = _shift(x) if x_prev is None else x_prev[:, None, :].astype(dt)
    mix = lambda mu: x + (xx - x) * mu.astype(dt)
    kk = jnp.square(jax.nn.relu(mix(p["mu_k"]) @ p["w_k"].astype(dt)))
    kk = shard(kk, "batch", None, "hidden")
    rr = jax.nn.sigmoid(mix(p["mu_r"]) @ p["w_r"].astype(dt))
    return rr * (kk @ p["w_v"].astype(dt))


def init_rwkv_state(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    H, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    return {"s": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "x_prev_t": jnp.zeros((batch, d), dtype),
            "x_prev_c": jnp.zeros((batch, d), dtype)}
