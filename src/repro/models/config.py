"""Model configuration for the 10 assigned architecture families.

One frozen dataclass covers every family via the `family` discriminator and
`block_pattern` (for the hybrid). Exact published hyper-parameters live in
src/repro/configs/<arch>.py; this module owns structure and derived sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | rwkv | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    act: str = "swiglu"         # swiglu | geglu | gelu (plain 2-matrix MLP)
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"     # activation compute dtype
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0           # per-expert hidden width
    capacity_factor: float = 1.25
    moe_group_tokens: int = 65_536   # global tokens per dispatch group
    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","local_attn")
    rnn_width: int = 0          # 0 → d_model
    conv_width: int = 4
    local_window: int = 2048
    # --- RWKV-6 ---
    rwkv_lora_dim: int = 64
    # --- enc-dec (Whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500         # stub audio frames (conv frontend precomputed)
    # --- remat policy for train_step ---
    remat: str = "nothing"      # nothing | dots | none(off)
    # --- §Perf H5: chunked-vocab CE (0/1 = off) ---
    vocab_chunks: int = 0

    def __post_init__(self):
        if self.family not in ("dense", "moe", "hybrid", "rwkv", "encdec"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "moe" and not (self.n_experts and
                                         self.experts_per_tok):
            raise ValueError("moe family needs n_experts/experts_per_tok")
        if self.family == "hybrid" and not self.block_pattern:
            raise ValueError("hybrid family needs block_pattern")

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def lru_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token contexts (SSM/linear/local)."""
        return self.family in ("rwkv", "hybrid")

    @property
    def has_decoder(self) -> bool:
        """Decode shapes apply (everything here autoregresses; encoder-only
        archs would return False)."""
        return True

    def segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Layer stacking plan: ((pattern, repeats), ...). Each segment scans
        `repeats` times over a body applying `pattern` blocks in order, so
        the traced HLO is O(#segments), not O(n_layers)."""
        if self.family == "hybrid":
            p = len(self.block_pattern)
            full, rem = divmod(self.n_layers, p)
            segs = []
            if full:
                segs.append((tuple(self.block_pattern), full))
            if rem:
                segs.append((tuple(self.block_pattern[:rem]), 1))
            return tuple(segs)
        block = {"dense": "attn_mlp", "moe": "attn_moe", "rwkv": "rwkv",
                 "encdec": "dec_block"}[self.family]
        return (((block,), self.n_layers),)

    def enc_segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        if self.family != "encdec":
            return ()
        return ((("enc_block",), self.n_enc_layers),)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""
    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                   # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPE_CELLS = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic " \
                      "attention (skip noted in DESIGN.md)"
    if cell.is_decode and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    return True, ""
