"""Logical-axis sharding: one place that maps model-internal axis names to
mesh axes, so layer code stays mesh-agnostic.

Layer code calls `shard(x, "batch", None, "hidden")`; under an active
`axis_rules` context this becomes `with_sharding_constraint` with the
resolved PartitionSpec, outside it (CPU unit tests) it is the identity.

Rules are computed per (ModelConfig, mesh) by `rules_for`: tensor-parallel
axes fall back to replication when a dimension is not divisible by the
mesh axis (e.g. gemma-2b's 8 heads on a 16-way model axis) — the roofline
then shows the resharding cost and the hillclimb log records the fix.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class AxisRules:
    """mesh + {logical axis name -> mesh axis (possibly a tuple) or None}."""

    def __init__(self, mesh: Mesh, table: dict):
        self.mesh = mesh
        self.table = dict(table)

    def spec(self, *axes) -> P:
        return P(*[self.table.get(a) if a else None for a in axes])

    def sharding(self, *axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*axes))


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def shard(x: jax.Array, *axes) -> jax.Array:
    """Constrain x's sharding by logical axis names (None = replicated dim)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(*axes))


def _divisible(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in names:
        size *= mesh.shape[a]
    return n % size == 0


def rules_for(cfg, mesh: Mesh, *, data_axes=("pod", "data"),
              model_axis="model", batch_size: Optional[int] = None,
              fsdp: bool = True) -> AxisRules:
    """Resolve logical axes for a ModelConfig on a mesh.

    Logical axes:
      batch   — DP over pod×data
      seq     — sequence sharding (off by default; hillclimb flag)
      embed   — d_model (replicated)
      hidden  — FFN hidden / fused q-dim (TP)
      heads   — attention head axis (TP when divisible)
      kv      — KV head axis (TP when divisible)
      vocab   — embedding/logits vocab dim (TP)
      experts — MoE expert dim (EP on the model axis)
      rnn     — RG-LRU / state width (TP when divisible)
    """
    present = set(mesh.axis_names)
    data = tuple(a for a in (data_axes if isinstance(data_axes, tuple)
                             else (data_axes,)) if a in present)
    model = model_axis if model_axis in present else None
    tp = (lambda n: model if (model and _divisible(n, mesh, model)) else None)
    if batch_size is not None and data and not _divisible(
            batch_size, mesh, data):
        data = ()    # e.g. long_500k's global_batch=1: replicate batch
    table = {
        "batch": data if data else None,
        "seq": None,
        "embed": None,
        "hidden": tp(cfg.d_ff) if cfg.d_ff else None,
        "qdim": tp(cfg.q_dim),
        "heads": tp(cfg.n_heads),
        "kv": tp(cfg.n_kv_heads),
        "kv_dim": tp(cfg.kv_dim),
        "vocab": tp(cfg.vocab),
        "experts": tp(cfg.n_experts) if cfg.n_experts else None,
        "moe_hidden": tp(cfg.moe_d_ff) if cfg.moe_d_ff else None,
        "rnn": tp(cfg.lru_width) if cfg.family == "hybrid" else None,
        # ZeRO/FSDP axis for the huge expert weights: shard d_model over the
        # data axes so params+AdamW state fit HBM (109B-param MoE needs it);
        # GSPMD all-gathers per layer per step — visible in §Roofline.
        "fsdp": (data if (fsdp and data and _divisible(cfg.d_model, mesh,
                                                       data)) else None),
        # Decode-cache sequence sharding (§Perf H1): every dense arch here
        # has n_kv_heads < 16, so head-TP can't shard the KV cache — the
        # baseline replicated it over `model` (≥100 GiB gathers per step).
        # Shard the cache TIME axis over `model` instead; attention over a
        # sharded T reduces via small all-reduces (flash-decode style).
        "kv_seq": (model if (model and not _divisible(cfg.n_kv_heads, mesh,
                                                      model)) else None),
    }
    return AxisRules(mesh, table)
