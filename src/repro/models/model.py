"""Model facade: ties a ModelConfig to init / loss / decode functions plus
the sharding specs the launcher needs (param, cache, batch PartitionSpecs).

Spec resolution is path-pattern based: every parameter path maps to logical
axes, resolved against an AxisRules (mesh-specific) table. Stacked segment
leaves get a leading None (the scan axis is never sharded).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeCell
from repro.models.sharding import AxisRules

# (path regex, logical axes per dim) — first match wins. Paths are
# "/"-joined key sequences, e.g. "segments/0/b0/attn/wq".
_PARAM_RULES = [
    (r"embed/tok$",            ("vocab", None)),
    (r"lm_head/w$",            (None, "vocab")),
    (r"enc_in/w$",             (None, None)),
    (r"(attn|xattn)/wq$",      (None, "qdim")),
    (r"(attn|xattn)/w[kv]$",   (None, "kv_dim")),
    (r"(attn|xattn)/wo$",      ("qdim", None)),
    (r"mlp/w_(gate|up)$",      (None, "hidden")),
    (r"mlp/w_down$",           ("hidden", None)),
    (r"moe/router$",           (None, "experts")),
    (r"moe/w_(gate|up)$",      ("experts", "fsdp", None)),
    (r"moe/w_down$",           ("experts", None, "fsdp")),
    (r"rglru/w_(x|gate)$",     (None, "rnn")),
    (r"rglru/w_out$",          ("rnn", None)),
    (r"rglru/conv_w$",         (None, "rnn")),
    (r"rglru/(conv_b|b_a|b_i|lam)$", ("rnn",)),
    (r"rglru/w_[ai]$",         (None, "rnn")),
    (r"tmix/w_[rkvg]$",        (None, "qdim")),
    (r"tmix/w_o$",             ("qdim", None)),
    (r"tmix/lora_[ab]$",       (None, None)),
    (r"cmix/w_k$",             (None, "hidden")),
    (r"cmix/w_v$",             ("hidden", None)),
    (r"cmix/w_r$",             (None, "qdim")),
]

_CACHE_RULES = [
    (r"/x?[kv]$",              ("batch", "kv_seq", "kv", None)),
    (r"/s$",                   ("batch", "heads", None, None)),
    (r"/(xt|xc)$",             ("batch", None)),
    (r"/h$",                   ("batch", "rnn")),
    (r"/conv$",                ("batch", None, "rnn")),
    (r"len$",                  ()),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_tree(tree, rules_table, ax: AxisRules, stacked_prefixes=()):
    def leaf_spec(path, leaf):
        s = _path_str(path)
        for pat, axes in rules_table:
            if re.search(pat, s):
                spec = ax.spec(*axes)
                if leaf.ndim == len(axes) + 1 and any(
                        s.startswith(p) for p in stacked_prefixes):
                    spec = P(None, *spec)       # leading scan-stack axis
                elif leaf.ndim != len(axes) and not any(
                        s.startswith(p) for p in stacked_prefixes):
                    return P()                  # rank mismatch → replicate
                return spec
        return P(*([None] * 0))                # default: fully replicated

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


class Model:
    """Facade over the functional transformer API for one architecture."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def init_params(self, key) -> dict:
        return T.init_params(key, self.cfg)

    def abstract_params(self) -> dict:
        return T.abstract_params(self.cfg)

    def param_specs(self, ax: AxisRules):
        return _spec_tree(self.abstract_params(), _PARAM_RULES, ax,
                          stacked_prefixes=("segments", "enc_segments"))

    # --------------------------------------------------------------- loss
    def loss_fn(self, params, batch) -> jax.Array:
        return T.lm_loss(params, batch, self.cfg)

    def forward_logits(self, params, tokens, frames=None):
        return T.forward_logits(params, tokens, self.cfg, frames=frames)

    # ------------------------------------------------------------- decode
    def init_cache(self, batch: int, cache_len: int) -> dict:
        return T.init_cache(self.cfg, batch, cache_len)

    def abstract_cache(self, batch: int, cache_len: int) -> dict:
        return jax.eval_shape(lambda: T.init_cache(self.cfg, batch,
                                                   cache_len))

    def cache_specs(self, ax: AxisRules, batch: int, cache_len: int):
        return _spec_tree(self.abstract_cache(batch, cache_len),
                          _CACHE_RULES, ax, stacked_prefixes=("segments",))

    def decode_step(self, params, cache, tokens):
        return T.decode_step(params, cache, tokens, self.cfg)

    # -------------------------------------------------------------- shapes
    def input_specs(self, cell: ShapeCell) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        if cell.kind == "train":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                     "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.enc_seq, cfg.d_model), jnp.float32)
            return batch
        if cell.kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.enc_seq, cfg.d_model), jnp.float32)
            return batch
        # decode: one new token against a cache holding S-1 tokens
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    def batch_specs(self, ax: AxisRules, cell: ShapeCell):
        spec3 = ax.spec("batch", None, None)
        spec2 = ax.spec("batch", None)
        out = {}
        for name, sds in self.input_specs(cell).items():
            out[name] = spec3 if len(sds.shape) == 3 else spec2
        return out
