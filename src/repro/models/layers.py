"""Shared layer library: norms, RoPE, GQA attention (direct / KV-chunked /
cached decode), gated MLPs, and capacity-based top-k MoE.

All functions are pure (params, inputs) → outputs; parameters are plain
dict pytrees created by the matching `init_*` function. Matmuls run in
cfg.dtype (bf16 by default) with float32 accumulation; norms and softmax
stay float32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- norms
def init_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def layer_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) rotated pairwise; positions: broadcastable (.., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style fixed positional embeddings (learned-pos stand-in)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, qd), jnp.float32) * scale,
        "wk": jax.random.normal(k2, (d, kvd), jnp.float32) * scale,
        "wv": jax.random.normal(k3, (d, kvd), jnp.float32) * scale,
        "wo": jax.random.normal(k4, (qd, d), jnp.float32) * scale
              / max(2 * cfg.n_layers, 1) ** 0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def _qkv(p: dict, x: jax.Array, cfg, positions, *, use_rope: bool = True):
    """Project + reshape + (qk-norm) + RoPE. Returns q (B,S,KV,G,hd),
    k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, kv * g, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv", None)
    v = shard(v, "batch", None, "kv", None)
    return q.reshape(B, S, kv, g, hd), k, v


def _attend_direct(q, k, v, mask) -> jax.Array:
    """q (B,S,KV,G,hd), k/v (B,T,KV,hd), mask (S,T) or None → (B,S,KV,G,hd).

    Grouped-head einsum keeps GQA KV unreplicated (bandwidth saving)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _attend_chunked(q, k, v, *, causal: bool, window: Optional[int],
                    chunk: int) -> jax.Array:
    """Online-softmax over KV chunks (flash-style streaming): memory is
    O(S·chunk) instead of O(S·T). Used whenever T > chunk (32k prefill).
    Ragged T pads KV to a chunk multiple; padded keys are masked out."""
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (T + pad) // chunk
    kc = k.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        kpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgh,btkh->bkgst", q, kb,
                       preferred_element_type=jnp.float32) * hd ** -0.5
        mask = jnp.broadcast_to((kpos < T)[None, :], (S, chunk))
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pr.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", pr.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)     # (B,S,KV,G,hd)


def attention(p: dict, x: jax.Array, cfg, positions, *, causal: bool = True,
              window: Optional[int] = None, chunk: int = 1024,
              use_rope: bool = True) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, use_rope=use_rope)
    if S <= chunk:
        mask = None
        if causal:
            pos = jnp.arange(S)
            mask = pos[:, None] >= pos[None, :]
            if window is not None:
                mask &= pos[:, None] - pos[None, :] < window
        out = _attend_direct(q, k, v, mask)
    else:
        out = _attend_chunked(q, k, v, causal=causal, window=window,
                              chunk=chunk)
    out = out.reshape(B, S, cfg.q_dim)
    out = shard(out, "batch", None, "qdim")
    return out @ p["wo"].astype(x.dtype)


def attention_decode(p: dict, x: jax.Array, cfg, cache_k, cache_v,
                     cache_len, *, use_rope: bool = True):
    """One-token decode against a (ring-buffered) KV cache.

    x: (B, 1, d); cache_k/v: (B, T, KV, hd). `cache_len` (scalar int32) is
    the number of tokens written BEFORE this step; the new token lands at
    slot `cache_len % T`. For sliding-window layers the cache is sized
    T = window, and once wrapped every slot holds one of the last T
    positions — so validity is simply `slot ≤ cache_len or wrapped`.
    Keys were RoPE'd at write time with absolute positions, so ring
    rotation never re-rotates. Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions, use_rope=use_rope)
    slot = (cache_len % T).astype(jnp.int32)
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    # §Perf H1: when kv-heads can't span the model axis the cache TIME dim
    # is sharded over it ("kv_seq"); scores are then computed on local T
    # slices and the softmax/contraction reduce via small all-reduces
    # (distributed flash-decode) instead of gathering the cache.
    new_k = shard(new_k, "batch", "kv_seq", "kv", None)
    new_v = shard(new_v, "batch", "kv_seq", "kv", None)
    tpos = jnp.arange(T)
    valid = (tpos <= cache_len) | (cache_len >= T)           # (T,)
    hd = cfg.hd
    scores = jnp.einsum("bskgh,btkh->bkgst", q, new_k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * hd ** -0.5
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    scores = shard(scores, "batch", "kv", None, None, "kv_seq")
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype),
                     new_v.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype), new_k, new_v


def init_cross_attention(key, cfg) -> dict:
    return init_attention(key, cfg)


def cross_attention(p: dict, x: jax.Array, cfg, enc_k, enc_v) -> jax.Array:
    """Decoder→encoder attention; enc_k/v precomputed (B, Te, KV, hd)."""
    B, S, _ = x.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    g = cfg.n_heads // kv
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, kv, g, hd)
    out = _attend_direct(q, enc_k.astype(x.dtype), enc_v.astype(x.dtype),
                         None)
    return out.reshape(B, S, cfg.q_dim) @ p["wo"].astype(x.dtype)


def encoder_kv(p: dict, enc_out: jax.Array, cfg):
    """Precompute cross-attention K/V from encoder output."""
    B, Te, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, Te, kv, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, Te, kv, hd)
    return k, v


# ----------------------------------------------------------------------- MLP
def init_mlp(key, cfg, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    p = {"w_down": jax.random.normal(k3, (f, d), jnp.float32) * scale_out
                   / max(2 * cfg.n_layers, 1) ** 0.5}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k1, (d, f), jnp.float32) * scale_in
        p["w_up"] = jax.random.normal(k2, (d, f), jnp.float32) * scale_in
    else:                                   # plain 2-matrix MLP (whisper)
        p["w_up"] = jax.random.normal(k2, (d, f), jnp.float32) * scale_in
    return p


def mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        actfn = jax.nn.silu if cfg.act == "swiglu" else \
            (lambda z: jax.nn.gelu(z, approximate=True))
        h = actfn(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt), approximate=True)
    h = shard(h, "batch", None, "hidden")
    return h @ p["w_down"].astype(dt)


# ----------------------------------------------------------------------- MoE
# Two dispatch strategies (cfg-independent semantics, same routing):
#   einsum  — t5x-style one-hot (G, E, C) dispatch/combine tensors. Simple,
#             but HBM traffic scales with G·E·C (the §Perf H2 bottleneck).
#   scatter — sort-free positional scatter into an (E·C, d) buffer and
#             gather back: O(G·K·d) traffic, no one-hot tensors.
MOE_DISPATCH = "scatter"


def init_moe(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    si, so = d ** -0.5, f ** -0.5
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * si,
        "w_gate": jax.random.normal(k2, (e, d, f), jnp.float32) * si,
        "w_up": jax.random.normal(k3, (e, d, f), jnp.float32) * si,
        "w_down": jax.random.normal(k4, (e, f, d), jnp.float32) * so
                  / max(2 * cfg.n_layers, 1) ** 0.5,
    }


def _moe_group(p: dict, xg: jax.Array, cfg) -> jax.Array:
    """Capacity-based top-k dispatch for one token group xg (G, d).

    t5x-style: per assignment slot, cumsum positions within each expert,
    drop overflow beyond capacity C, dispatch/combine via one-hot einsum.
    EP: the expert axis of w_* is sharded over `model`, so the dispatch
    einsum lowers to the expected all-to-all pattern under GSPMD.
    """
    G = xg.shape[0]
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = max(int(G * K * cfg.capacity_factor / E), 1)
    dt = xg.dtype
    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)         # (G, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # renormalize

    dispatch = jnp.zeros((G, E, C), dt)
    combine = jnp.zeros((G, E, C), jnp.float32)
    fill = jnp.zeros((E,), jnp.int32)                       # per-expert count
    for slot in range(K):                                   # K ≤ 6, unrolled
        onehot = jax.nn.one_hot(expert_idx[:, slot], E, dtype=jnp.int32)
        pos = fill[None, :] + jnp.cumsum(onehot, axis=0) - onehot  # (G, E)
        keep = (onehot > 0) & (pos < C)
        poshot = jax.nn.one_hot(pos, C, dtype=dt) * keep[..., None]
        dispatch = dispatch + poshot
        combine = combine + poshot.astype(jnp.float32) * \
            gate_vals[:, slot][:, None, None]
        fill = fill + jnp.sum(onehot * keep, axis=0)

    xin = jnp.einsum("gec,gd->ecd", dispatch, xg,
                     preferred_element_type=jnp.float32).astype(dt)
    xin = shard(xin, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"].astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", xin, p["w_up"].astype(dt))
    h = shard(h, "experts", None, None)   # EP owns the axis; hidden stays
    # local (experts and moe_hidden both resolve to `model` — a spec may
    # use a mesh axis once)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    out = jnp.einsum("gec,ecd->gd", combine.astype(dt), y,
                     preferred_element_type=jnp.float32)
    return out.astype(dt)


def _moe_group_scatter(p: dict, xg: jax.Array, cfg) -> jax.Array:
    """§Perf H2: capacity-based top-k dispatch WITHOUT one-hot tensors.

    Routing is identical to `_moe_group` (same capacity, same renormalized
    gates); the data movement differs: each (token, slot) assignment
    scatters its row into an (E·C, d) expert buffer at `expert·C + pos`
    (overflow positions scatter out-of-bounds and are DROPPED, matching the
    one-hot path's capacity semantics), experts run batched matmuls on the
    (E, C, d) buffer, and tokens gather their outputs back. HBM traffic is
    O(G·K·d + E·C·d) versus the einsum path's O(G·E·C) one-hot tensors —
    the difference is ~E× at moonshot's E = 64.
    """
    G = xg.shape[0]
    E, K = cfg.n_experts, cfg.experts_per_tok
    C = max(int(G * K * cfg.capacity_factor / E), 1)
    dt = xg.dtype
    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (G, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert, over slot-major order
    # (same order the einsum path fills capacity in — slot 0 first).
    pos = jnp.zeros((G, K), jnp.int32)
    fill = jnp.zeros((E,), jnp.int32)
    for slot in range(K):                                    # K ≤ 6 unrolled
        onehot = jax.nn.one_hot(expert_idx[:, slot], E, dtype=jnp.int32)
        p_slot = fill[None, :] + jnp.cumsum(onehot, axis=0) - onehot
        pos = pos.at[:, slot].set(
            jnp.sum(p_slot * onehot, axis=1))
        fill = fill + jnp.sum(
            onehot * ((p_slot < C) & (onehot > 0)), axis=0)
    keep = pos < C
    dest = jnp.where(keep, expert_idx * C + pos, E * C)      # OOB ⇒ dropped

    buf = jnp.zeros((E * C, xg.shape[1]), dt)
    buf = buf.at[dest.reshape(-1)].add(
        jnp.repeat(xg, K, axis=0), mode="drop")              # (E·C, d)
    xin = shard(buf.reshape(E, C, xg.shape[1]), "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"].astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", xin, p["w_up"].astype(dt))
    h = shard(h, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    y = y.reshape(E * C, xg.shape[1])
    gathered = jnp.take(y, jnp.minimum(dest, E * C - 1).reshape(-1),
                        axis=0).reshape(G, K, -1)
    w = (gate_vals * keep).astype(dt)                        # dropped ⇒ 0
    return jnp.einsum("gk,gkd->gd", w, gathered)


def moe(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Top-k MoE over (B, S, d): dispatch groups of ≤ moe_group_tokens,
    scanned so the HLO stays one group body.

    Grouping slices the SEQUENCE dim only — (ns, B, Gs, d) — so the scan
    axis is unsharded and the batch dim keeps its DP sharding. (Grouping
    by flattened token blocks makes the scan axis coincide with the
    batch sharding, and XLA must then all-gather the entire activation
    stream to iterate — 3×20 GiB per step on llama4; §Perf H3.)
    """
    B, S, d = x.shape
    group = _moe_group_scatter if MOE_DISPATCH == "scatter" else _moe_group
    Gs = max(min(cfg.moe_group_tokens // max(B, 1), S), 1)
    if S % Gs != 0:
        Gs = S                              # ragged: single group
    ns = S // Gs
    if ns == 1:
        return group(p, x.reshape(B * S, d), cfg).reshape(B, S, d)

    xs = x.reshape(B, ns, Gs, d).transpose(1, 0, 2, 3)      # (ns, B, Gs, d)

    def body(_, xg):
        out = group(p, xg.reshape(B * Gs, d), cfg)
        return None, out.reshape(B, Gs, d)

    _, out = jax.lax.scan(body, None, xs)                   # (ns, B, Gs, d)
    return out.transpose(1, 0, 2, 3).reshape(B, S, d)
